"""Train a byte-level LM on this repo's own sources for a few hundred
steps, with fault-tolerant checkpointing: the run "crashes" halfway and
resumes bit-identically from the last committed checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.data import ByteCorpus, DataIterator
from repro.training.checkpoint import CheckpointManager
from repro.training.optim import adamw, cosine_schedule, mixed_precision
from repro.training.step import (make_train_step, init_train_state,
                                 abstract_train_state)

CKPT = "/tmp/repro_train_lm_ckpt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    shutil.rmtree(CKPT, ignore_errors=True)

    cfg = dataclasses.replace(
        reduced_config("stablelm_1_6b"), vocab=256, d_model=128, n_layers=4,
        n_heads=4, head_dim=32, d_ff=256)
    print(f"model: {cfg.param_count():,} params; corpus: repo sources")
    corpus = ByteCorpus(root=os.path.join(os.path.dirname(__file__), "..",
                                          "src"))
    opt = mixed_precision(adamw(cosine_schedule(3e-3, 20, args.steps)))
    cfg = cfg.with_runtime(param_dtype="float32")
    step = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(CKPT, keep_n=2, save_interval=25)

    def run(state, it, until):
        t0 = time.perf_counter()
        for d in it:
            state, m = step(state, {"inputs": jnp.asarray(d["inputs"]),
                                    "labels": jnp.asarray(d["labels"])})
            s = int(state["step"])
            mgr.maybe_save(jax.device_get(state), s)
            if s % 25 == 0:
                dt = (time.perf_counter() - t0) / 25
                print(f"step {s:4d} loss {float(m['loss']):.3f} "
                      f"({dt*1000:.0f} ms/step)", flush=True)
                t0 = time.perf_counter()
            if s >= until:
                return state

    half = args.steps // 2
    it = DataIterator(corpus, batch=args.batch, seq=args.seq)
    state = run(state, it, half)
    print(f"\n-- simulated crash at step {half}; recovering from the last "
          f"committed checkpoint --\n")
    del state
    restored, manifest = mgr.restore_latest(abstract_train_state(cfg, opt))
    resume_step = manifest["step"]
    print(f"restored step {resume_step}")
    it2 = DataIterator(corpus, batch=args.batch, seq=args.seq,
                       step=resume_step)
    state = run(restored, it2, args.steps)
    bits = float(jnp.log2(jnp.e)) * 0  # cosmetic
    print(f"\ndone: {args.steps} steps; final checkpoint at step "
          f"{mgr.latest_step()}")


if __name__ == "__main__":
    main()
