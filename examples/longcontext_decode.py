"""Why long_500k runs only on the sub-quadratic archs: decode-state size
vs context length for an SSM (mamba2), a hybrid (recurrentgemma) and a
full-attention model (yi), using the reduced configs — plus a live
constant-memory decode of 3x the attention window.

    PYTHONPATH=src python examples/longcontext_decode.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import init_params, init_cache, decode_step
from repro.models.model import prefill
from repro.utils import tree_bytes, human_bytes


def cache_bytes(arch, S):
    cfg = get_config(arch).with_runtime(compute_dtype="bfloat16")
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, S))
    return tree_bytes(cache)


def main():
    print(f"{'arch':22s} {'ctx=32k':>12} {'ctx=512k':>12}  growth")
    for arch in ("yi_9b", "recurrentgemma_2b", "mamba2_2_7b"):
        b32 = cache_bytes(arch, 32768)
        b512 = cache_bytes(arch, 524288)
        print(f"{arch:22s} {human_bytes(b32):>12} {human_bytes(b512):>12}  "
              f"{b512/b32:5.1f}x")
    print("\nfull attention caches grow linearly with context; RG-LRU + "
          "windowed attention and SSD states are (near-)constant -> only "
          "those run long_500k (DESIGN.md §4).\n")

    # Live long decode on the hybrid: 3x its window, constant memory.
    cfg = reduced_config("recurrentgemma_2b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    T = cfg.window * 3
    x = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab)
    _, cache = prefill(params, x[:, :4], cfg, max_seq=T)
    print(f"decoding {T} tokens on reduced recurrentgemma "
          f"(window={cfg.window}); cache={human_bytes(tree_bytes(cache))}")
    dec = jax.jit(lambda p, t, c, pos: decode_step(p, t, c, pos, cfg))
    pos = 4
    for t in range(4, T):
        logits, cache = dec(params, x[:, t:t + 1], cache, jnp.int32(pos))
        pos += 1
    assert np.isfinite(np.asarray(logits)).all()
    print(f"decoded to position {pos}; cache still "
          f"{human_bytes(tree_bytes(cache))} (constant)")


if __name__ == "__main__":
    main()
