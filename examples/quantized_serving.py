"""Paper Fig 6 as a serving decision: an int8-quantized zoo member costs
-75% storage and a small accuracy hit; CNNSelect treats it as just
another (A, mu, sigma) point on the frontier.

    PYTHONPATH=src python examples/quantized_serving.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.selection import ModelProfile, cnnselect
from repro.models import init_params, forward
from repro.quant import quantize_tree, dequantize_tree
from repro.utils import tree_bytes, human_bytes


def main():
    cfg = reduced_config("yi_9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qt = quantize_tree(params, min_size=256)
    raw, packed = tree_bytes(params), tree_bytes(qt)
    print(f"storage: fp32 {human_bytes(raw)} -> int8 {human_bytes(packed)} "
          f"({100*(1-packed/raw):.0f}% saved; paper: 75%)")

    x = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    base, _ = forward(params, x, cfg)
    deq = dequantize_tree(qt, like=params)
    pert, _ = forward(deq, x, cfg)
    agree = float((base.argmax(-1) == pert.argmax(-1)).mean())
    print(f"top-1 agreement after int8 roundtrip: {agree:.2%}")

    # A zoo where the quantized variant is faster but slightly less
    # accurate (profile numbers from paper-style measurements).
    profs = [
        ModelProfile("fp16_model", accuracy=0.779, mu=56.0, sigma=1.2),
        ModelProfile("int8_model", accuracy=0.779 * agree, mu=34.0,
                     sigma=1.0),
        ModelProfile("tiny_model", accuracy=0.497, mu=26.0, sigma=1.2),
    ]
    rng = np.random.default_rng(0)
    print(f"\n{'SLA(ms)':>8} | picks over 50 requests")
    for sla in (120, 155, 260, 500):
        counts = {}
        for _ in range(50):
            r = cnnselect(profs, sla, t_input=40.0, t_threshold=30.0, rng=rng)
            n = profs[r.index].name
            counts[n] = counts.get(n, 0) + 1
        print(f"{sla:8d} | {counts}")
    print("\nthe int8 variant wins the mid-SLA band: cheaper than fp16, "
          "far more accurate than tiny (paper Fig 6 trade-off).")


if __name__ == "__main__":
    main()
