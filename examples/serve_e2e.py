"""End-to-end serving demo (paper §5.2.1 prototype, Fig 12).

Trains TWO real LMs of different capacity on the copy task (the LM
analogue of the paper's accuracy axis: the bigger model genuinely copies
better), measures their REAL latency profiles on this host, then runs a
CNNSelect SLA sweep with live engines and prints attainment/accuracy per
SLA — reproducing the Fig 12 transition between models.

    PYTHONPATH=src python examples/serve_e2e.py [--steps 150] [--requests 30]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.utils.config import configure

configure(platform="cpu")  # pin before anything builds jax arrays

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.data import CopyTask
from repro.models import init_params
from repro.serving.batching import Request
from repro.serving.engine import InferenceEngine
from repro.serving.network import make_network
from repro.serving.server import CNNSelectServer, ServedModel
from repro.training.optim import adamw, constant_schedule
from repro.training.step import make_train_step, init_train_state


def train_model(cfg, task, steps, lr=3e-3, seed=0):
    opt = adamw(constant_schedule(lr))
    step = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    for i in range(steps):
        b = task.batch(i, 16)
        state, m = step(state, {"inputs": jnp.asarray(b["inputs"]),
                                "labels": jnp.asarray(b["labels"])})
    return state["params"], float(m["loss"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=30)
    args = ap.parse_args()

    task = CopyTask(vocab=32, prompt_len=6)
    base = reduced_config("stablelm_1_6b")
    tiny = dataclasses.replace(base, vocab=32, n_layers=1, d_model=32,
                               n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64)
    small = dataclasses.replace(base, vocab=32, n_layers=4, d_model=96,
                                n_heads=4, n_kv_heads=4, head_dim=24,
                                d_ff=192)

    models = []
    for name, cfg, steps in [("tiny", tiny, args.steps),
                             ("small", small, args.steps * 2)]:
        print(f"training {name} ({cfg.param_count():,} params, "
              f"{steps} steps)...", flush=True)
        params, loss = train_model(cfg, task, steps)
        eng = InferenceEngine(cfg, params, batch_size=1,
                              max_seq=task.prompt_len * 2 + 2)
        eng.warmup(task.prompt_len + 1)
        acc = task.exact_match(eng, n_batches=8)
        print(f"  final loss {loss:.3f}, copy accuracy {acc:.2%}")
        models.append(ServedModel(name=name, engine=eng, accuracy=float(acc)))

    assert models[1].accuracy > models[0].accuracy, \
        "bigger model should copy better; increase --steps"

    srv = CNNSelectServer(models, t_threshold=25.0,
                          n_tokens=task.prompt_len)
    srv.profile_models(prompt_len=task.prompt_len + 1, reps=5)
    for p in srv.current_profiles():
        print(f"profile {p.name}: mu={p.mu:.1f}ms sigma={p.sigma:.1f}ms "
              f"accuracy={p.accuracy:.2%}")

    net = make_network("campus_wifi")
    rng = np.random.default_rng(0)
    mus = {p.name: p.mu for p in srv.current_profiles()}
    slas = [mus["tiny"] * 1.5 + 130, (mus["tiny"] + mus["small"]) / 2 + 160,
            mus["small"] * 1.6 + 160, mus["small"] * 4 + 200]
    print(f"\n{'SLA(ms)':>8} | {'attain':>6} | {'acc':>6} | selections")
    for sla in slas:
        srv.metrics = type(srv.metrics)()
        for i in range(args.requests):
            d = task.batch(10_000 + i, 1)
            req = Request(arrival=0.0, rid=i, prompt=d["prompt"][0],
                          t_input_ms=float(net.sample_t_input(rng, 1)[0]))
            srv.handle(req, t_sla=float(sla))
        s = srv.metrics.summary()
        print(f"{sla:8.0f} | {s['attainment']:6.2f} | {s['accuracy']:6.2%} "
              f"| {s['selections']}")
    print("\nAs the SLA relaxes CNNSelect shifts traffic from the fast/"
          "inaccurate model to the slow/accurate one (paper Fig 12).")


if __name__ == "__main__":
    main()
