"""Quickstart: CNNSelect over the paper's CNN zoo (Table 5 profiles).

Shows the three-stage selection as the SLA relaxes: fallback-fastest ->
probabilistic exploration -> convergence on the most accurate model.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.utils.config import configure

configure(platform="cpu")  # pin before anything builds jax arrays

import numpy as np

from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import cnnselect, greedy_select
from repro.serving.network import make_network


def main():
    profs = paper_profiles()
    rng = np.random.default_rng(0)
    net = make_network("campus_wifi")
    print(f"{'SLA(ms)':>8} | {'base model':>20} | {'picked (100 reqs)':48s} | greedy")
    for sla in (80, 115, 150, 200, 300, 500, 1000, 3000):
        counts = {}
        base = None
        for _ in range(100):
            t_in = float(net.sample_t_input(rng, 1)[0])
            r = cnnselect(profs, sla, t_in, t_threshold=40.0, rng=rng)
            base = profs[r.base_index].name
            n = profs[r.index].name
            counts[n] = counts.get(n, 0) + 1
        top = sorted(counts.items(), key=lambda kv: -kv[1])[:3]
        picks = " ".join(f"{n}:{c}" for n, c in top)
        g = profs[greedy_select(profs, sla)].name
        print(f"{sla:8d} | {base:>20} | {picks:48s} | {g}")
    print("\nCNNSelect explores fast models at tight SLAs and converges to "
          "the most accurate model as the budget grows;\ngreedy ignores "
          "network time and picks by mean latency alone.")


if __name__ == "__main__":
    main()
