"""Architecture registry: the assigned 10 architectures + reduced smoke
variants. Each <arch>.py exposes `make_config()` with the exact assigned
hyper-parameters; `reduced_config(name)` scales a family down for CPU
smoke tests (same block pattern, tiny dims)."""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "musicgen_large",
    "stablelm_1_6b",
    "gemma2_9b",
    "yi_9b",
    "deepseek_coder_33b",
    "recurrentgemma_2b",
    "chameleon_34b",
    "mamba2_2_7b",
    "qwen3_moe_235b",
    "grok_1_314b",
]

# Canonical external ids (assignment spelling) -> module names.
ALIASES = {
    "musicgen-large": "musicgen_large",
    "stablelm-1.6b": "stablelm_1_6b",
    "gemma2-9b": "gemma2_9b",
    "yi-9b": "yi_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "grok-1-314b": "grok_1_314b",
}


def resolve(name: str) -> str:
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get_config(name: str, **runtime):
    mod = importlib.import_module(f"repro.configs.{resolve(name)}")
    cfg = mod.make_config()
    return cfg.with_runtime(**runtime) if runtime else cfg


def reduced_config(name: str, **runtime):
    """Tiny same-family config for CPU smoke tests."""
    from repro.models.config import MoEConfig, SSDConfig, RGLRUConfig
    cfg = get_config(name)
    pat = len(cfg.pattern)
    n_layers = pat * 2 + (1 if cfg.n_layers % pat else 0)  # 2 groups (+tail)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        window=8 if cfg.window else 0,
        tp_pad_heads=0,
        attn_chunk=16,
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                              capacity_factor=2.0)
    if cfg.ssd:
        kw["ssd"] = SSDConfig(d_state=16, head_dim=8, n_groups=1,
                              conv_width=4, expand=2, chunk=16)
    if cfg.rglru:
        kw["rglru"] = RGLRUConfig(lru_width=64, conv_width=4)
    cfg = dataclasses.replace(cfg, **kw)
    return cfg.with_runtime(**runtime) if runtime else cfg
