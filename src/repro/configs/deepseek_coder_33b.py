"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256 [arXiv:2401.14196]. Llama-architecture.

56 q-heads are not divisible by the fixed 16-way model axis, so
`tp_pad_heads=64` pads attention to 64 heads (zero-init extras). The
~14% attention-FLOP padding waste is surfaced by the roofline table's
MODEL_FLOPS/HLO_FLOPs ratio (DESIGN.md §6)."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab=32256,
        pattern=("attn",),
        rope_theta=100000.0,
        mlp_gated=True,
        mlp_act="silu",
        tie_embeddings=False,
        tp_pad_heads=64,
    )
