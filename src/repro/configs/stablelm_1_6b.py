"""stablelm-1.6b [dense]: 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b]. Partial rotary (25%)."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab=100352,
        pattern=("attn",),
        rotary_pct=0.25,
        mlp_gated=True,
        mlp_act="silu",
        tie_embeddings=False,
    )
