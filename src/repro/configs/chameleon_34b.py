"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 [arXiv:2405.09818]. Early-fusion over VQ image tokens; the
VQ tokenizer frontend is a stub (`input_specs()` provides precomputed
patch embeddings). QK-norm per the paper."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab=65536,
        pattern=("attn",),
        qk_norm=True,
        mlp_gated=True,
        mlp_act="silu",
        tie_embeddings=False,
        input_mode="embeddings",
    )
