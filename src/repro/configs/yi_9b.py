"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
[arXiv:2403.04652]. Llama-architecture GQA."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab=64000,
        pattern=("attn",),
        mlp_gated=True,
        mlp_act="silu",
        tie_embeddings=False,
    )
