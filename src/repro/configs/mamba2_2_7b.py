"""mamba2-2.7b [ssm]: 64L d_model=2560, attn-free, vocab=50280,
ssm_state=128 [arXiv:2405.21060]. SSD (state-space duality) blocks:
d_inner=5120 (expand 2), head_dim 64 -> 80 heads, 1 group, conv width 4.
Sub-quadratic: runs the long_500k shape.

50280 is not divisible by the 16-way model axis; the embedding table is
padded to 50432 rows (tp_pad_vocab) so vocab/logits shard — the same
tensor-core padding the public mamba2 checkpoints apply (50288). Without
it the per-rank fp32 logits blow past HBM at train_4k (measured in the
v0 roofline; see EXPERIMENTS.md §Perf)."""

from repro.models.config import ModelConfig, SSDConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=1,       # unused: attn-free
        n_kv_heads=1,
        head_dim=1,
        d_ff=0,
        vocab=50280,
        pattern=("ssd",),
        mlp_gated=False,
        tie_embeddings=True,
        tp_pad_vocab=50432,
        ssd=SSDConfig(d_state=128, head_dim=64, n_groups=1, conv_width=4,
                      expand=2, chunk=256),
    )
