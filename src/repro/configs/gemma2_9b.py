"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 [arXiv:2408.00118]. Local+global alternating attention
(window 4096), attention logit softcap 50, final logit softcap 30,
sandwich (pre+post) norms, gated GELU, sqrt(d) embedding scaling,
head_dim 256."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        pattern=("local", "global"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        mlp_gated=True,
        mlp_act="gelu",
        tie_embeddings=True,
        embed_scale=True,
    )
