"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 [arXiv:2402.19427]. RG-LRU + local attention in a 1:2
pattern — (rglru, rglru, local) cycled: 8 full groups + 2 tail RG-LRU
layers. Window 2048. Sub-quadratic: runs the long_500k shape.

10 q-heads are not divisible by the 16-way model axis: tp_pad_heads=16
pads the (minority) local-attention mixers; the ~2% total param overhead
is surfaced by the roofline MODEL_FLOPS/HLO_FLOPs ratio (DESIGN.md §6)."""

from repro.models.config import ModelConfig, RGLRUConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        pattern=("rglru", "rglru", "local"),
        window=2048,
        mlp_gated=True,
        mlp_act="gelu",
        tie_embeddings=True,
        embed_scale=True,
        tp_pad_heads=16,
        rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    )
