"""The paper's own measured data, used to seed simulations & benchmarks.

- TABLE5: CNN model statistics (top-1/top-5 accuracy, hot/cold start
  inference time on an EC2 p2.xlarge GPU server), paper Table 5.
- NETWORKS: mobile network conditions (paper §3 Fig 7/10: campus WiFi
  mean input-transfer 63 ms per ~330KB request, 36.83 ms per 172 KB
  upload; cellular hotspot transfer ~2x WiFi; LTE between, heavier tail).
- DEVICES: on-device inference times (Fig 5/6, Table 4) for the
  on-device-vs-cloud comparisons and the T_D bound on T_threshold.
- MODEL_SIZES: approximate serialized sizes (MB) from the public model
  zoo files, for the cold/hot memory-budget experiments.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.selection import ModelProfile

# name: (top1, top5, hot_mu, hot_sigma, cold_mu, cold_sigma) [ms]
TABLE5 = {
    "squeezenet":          (49.0, 72.9, 28.61, 1.13, 173.38, 25.73),
    "mobilenetv1_025":     (49.7, 74.1, 25.73, 1.22, 272.81, 45.00),
    "mobilenetv1_05":      (63.2, 84.9, 26.34, 1.19, 302.77, 45.50),
    "densenet":            (64.2, 85.6, 49.55, 3.21, 1149.04, 108.00),
    "mobilenetv1_075":     (68.3, 88.1, 28.02, 1.14, 351.92, 47.38),
    "mobilenetv1_10":      (71.8, 90.6, 28.15, 1.22, 421.23, 47.14),
    "nasnet_mobile":       (73.9, 91.5, 55.31, 4.09, 2817.25, 123.73),
    "inception_resnet_v2": (77.5, 94.0, 76.30, 5.74, 2844.29, 106.49),
    "inceptionv3":         (77.9, 93.8, 55.75, 1.20, 1950.71, 101.21),
    "inceptionv4":         (80.1, 95.1, 82.78, 0.89, 3162.24, 133.99),
    "nasnet_large":        (82.6, 96.1, 112.61, 6.09, 7054.52, 238.36),
}

MODEL_SIZES_MB = {
    "squeezenet": 5.0, "mobilenetv1_025": 1.9, "mobilenetv1_05": 5.2,
    "densenet": 32.6, "mobilenetv1_075": 10.3, "mobilenetv1_10": 16.9,
    "nasnet_mobile": 21.4, "inception_resnet_v2": 121.0,
    "inceptionv3": 95.3, "inceptionv4": 170.7, "nasnet_large": 355.3,
}

# T_input distributions (ms for a ~330KB preprocessed image). Lognormal
# keeps the positive heavy tail the paper attributes to mobile networks.
NETWORKS = {
    "campus_wifi": dict(mean=63.0, std=18.0),
    "lte": dict(mean=95.0, std=35.0),
    "cellular_hotspot": dict(mean=126.0, std=60.0),
    "edge_wired": dict(mean=20.0, std=5.0),
}

# Extra regime states for the time-varying processes (beyond the paper's
# stationary measurements): degraded/congested variants of the measured
# networks and a near-outage state (MDInference's "variable mobile
# network" regime).
NETWORK_STATES = {
    "congested_wifi": dict(mean=190.0, std=85.0),
    "degraded_lte": dict(mean=260.0, std=110.0),
    "outage": dict(mean=900.0, std=250.0),
}

# Named regime-switching scenarios for `serving.network.MarkovProcess`:
# states are NETWORKS/NETWORK_STATES names, `transition` is the
# per-request row-stochastic matrix. Diagonals near 1 give realistic
# multi-hundred-request dwells at the simulator's request granularity.
NETWORK_SCENARIOS = {
    # Device walks out of WiFi coverage and hands off to LTE (and back).
    "wifi_lte_handoff": dict(
        states=("campus_wifi", "lte"),
        transition=((0.998, 0.002),
                    (0.002, 0.998)),
        start=0),
    # Mostly-good WiFi with short heavy congestion bursts.
    "wifi_congestion_bursts": dict(
        states=("campus_wifi", "congested_wifi"),
        transition=((0.99, 0.01),
                    (0.08, 0.92)),
        start=0),
    # LTE that occasionally collapses toward an outage and recovers
    # through a degraded state.
    "lte_outages": dict(
        states=("lte", "degraded_lte", "outage"),
        transition=((0.995, 0.004, 0.001),
                    (0.050, 0.930, 0.020),
                    (0.020, 0.180, 0.800)),
        start=0),
}


# The synthetic mean-T_input traces `synthetic_trace` can build — the
# `trace:<name>` half of the trace registry (`capture_names()` is the
# recorded half).
SYNTHETIC_TRACES = ("wifi_lte_step", "diurnal", "sawtooth_congestion")


def synthetic_trace(name: str, n: int = 2048):
    """Synthetic mean-T_input traces (ms per request position) for
    `serving.network.TraceReplayProcess`:

    - ``wifi_lte_step``: abrupt campus_wifi -> lte handoff mid-trace.
    - ``diurnal``: smooth sinusoidal load swing between WiFi-like and
      hotspot-like conditions (a day of varying congestion).
    - ``sawtooth_congestion``: repeated build-up/clear congestion ramps.
    """
    i = np.arange(n)
    wifi, lte = NETWORKS["campus_wifi"]["mean"], NETWORKS["lte"]["mean"]
    hotspot = NETWORKS["cellular_hotspot"]["mean"]
    if name == "wifi_lte_step":
        return np.where(i < n // 2, wifi, lte).astype(np.float64)
    if name == "diurnal":
        mid, amp = (hotspot + wifi) / 2.0, (hotspot - wifi) / 2.0
        return mid + amp * np.sin(2.0 * np.pi * i / n)
    if name == "sawtooth_congestion":
        period = max(n // 8, 1)
        ramp = (i % period) / period
        return wifi + (hotspot - wifi) * ramp
    raise ValueError(f"unknown synthetic trace {name!r}; known: "
                     f"{', '.join(SYNTHETIC_TRACES)}")


# --------------------------------------------------------------------------
# Recorded captures (serving.trace.Trace files committed under traces/)
# --------------------------------------------------------------------------

_TRACES_DIR = os.path.join(os.path.dirname(__file__), "traces")

# Registered capture scenarios for `make_network("capture:<name>")` /
# `serving.trace.load_capture`: each entry names a committed capture
# file and the default replay mode (`serving.trace.CapturedTraceProcess`).
# `reference_fleet` is the ground-truth workload the sim-to-real CI loop
# pins: a mixed_fleet greedy_nw simulator run captured by
# `benchmarks/trace_replay.py --write-reference` (numpy-only policy, so
# regeneration is bit-for-bit reproducible across jax versions).
CAPTURE_SCENARIOS = {
    "reference_fleet": dict(file="reference_fleet.jsonl", mode="loop"),
}


def capture_names():
    return sorted(CAPTURE_SCENARIOS)


def capture_path(name: str) -> str:
    """Path of a registered capture file (see `CAPTURE_SCENARIOS`)."""
    if name not in CAPTURE_SCENARIOS:
        raise ValueError(f"unknown capture {name!r}; known: "
                         f"{', '.join(capture_names())}")
    return os.path.join(_TRACES_DIR, CAPTURE_SCENARIOS[name]["file"])

# On-device end-to-end inference (ms), Fig 5/6 & Table 4 (hot model).
DEVICES = {
    "pixel2": {"mobilenetv1_025": 133.0, "mobilenetv1_10": 352.0,
               "inceptionv3": 1910.0},
    "motox": {"mobilenetv1_025": 210.0},
    "nexus5_caffe": {"alexnet_equiv": 8910.0},
}

# Device tiers for `serving.fleet`: each tier pairs a radio (any
# make_network spec) with the on-device profile the paper measured for
# that class of phone — (DEVICES key, model) resolved against DEVICES
# for the mean and TABLE5 for the accuracy. `on_device=None` models a
# device that cannot run the CNN locally (the paper's Nexus 5 at ~9 s
# is never SLA-viable, so "legacy" simply has no fallback).
DEVICE_TIERS = {
    "flagship": dict(network="campus_wifi",
                     on_device=("pixel2", "mobilenetv1_10")),
    "midrange": dict(network="lte",
                     on_device=("pixel2", "mobilenetv1_025")),
    "budget": dict(network="cellular_hotspot",
                   on_device=("motox", "mobilenetv1_025")),
    "legacy": dict(network="cellular_hotspot", on_device=None),
}

# Named fleets for `serving.fleet.make_fleet`: tuples of tier entries
# (tier, weight, optional per-entry `network` override / `device_id`).
# `lte_outage_fleet` puts the midrange tier on the `lte_outages`
# regime-switching scenario — the degraded-regime tier the outage-aware
# hedging/fallback benchmark reports on.
FLEET_SCENARIOS = {
    "mixed_fleet": (
        dict(tier="flagship", weight=0.3),
        dict(tier="midrange", weight=0.5),
        dict(tier="budget", weight=0.2),
    ),
    "lte_outage_fleet": (
        dict(tier="flagship", weight=0.4),
        dict(tier="midrange", weight=0.4, network="lte_outages"),
        dict(tier="legacy", weight=0.2),
    ),
}


# SLA classes for multi-tenant cluster serving (serving/cluster.py,
# DESIGN.md §16; per-tenant SLA-aware selection after ModiPick,
# arXiv:1909.02053). `t_sla` is the class's end-to-end deadline;
# `shed_priority` orders load-shedding when the cluster saturates
# (lower sheds first — bronze traffic falls back on-device before any
# gold request does).
TENANT_SLA_CLASSES = {
    "gold":   dict(t_sla=250.0, shed_priority=2),
    "silver": dict(t_sla=500.0, shed_priority=1),
    "bronze": dict(t_sla=1200.0, shed_priority=0),
}

# Named tenant mixes for `serving.cluster.make_tenants`: each entry is
# one tenant — a device population (FLEET_SCENARIOS name) under an SLA
# class, with its share of the cluster's request volume and a staggered
# burst window (`phase` offsets the tenant's traffic peak as a fraction
# of the horizon; `burst` is the peak/trough rate ratio). Staggered
# peaks are what make the shared cluster beat static per-tenant
# replicas: pinned capacity must cover every tenant's own peak, the
# cluster reuses idle capacity across peaks.
TENANT_MIXES = {
    "consumer_burst": (
        dict(tenant="gold-flagship", sla_class="gold",
             fleet="mixed_fleet", weight=0.3, phase=0.0, burst=4.0),
        dict(tenant="silver-mid", sla_class="silver",
             fleet="mixed_fleet", weight=0.4, phase=0.4, burst=4.0),
        dict(tenant="bronze-budget", sla_class="bronze",
             fleet="lte_outage_fleet", weight=0.3, phase=0.7,
             burst=4.0),
    ),
    "enterprise_degraded": (
        dict(tenant="gold-field", sla_class="gold",
             fleet="lte_outage_fleet", weight=0.5, phase=0.0,
             burst=3.0),
        dict(tenant="bronze-bulk", sla_class="bronze",
             fleet="mixed_fleet", weight=0.5, phase=0.5, burst=3.0),
    ),
}


def scale_tenant_mix(n_devices: int, *, seed: int = 0):
    """A three-class tenant mix whose fleets total `n_devices`
    devices, for the cluster-scale sweep (benchmarks/cluster_scale.py:
    1k -> 1M tenant-devices). ``"array:<n>:<seed>"`` fleet specs keep
    per-device state columnar at any size — the FLEET_SCENARIOS
    mixtures enumerate device-id strings, which a 10^6-device
    population would pay for in memory and workload-generation time.
    Shares/phases mirror ``consumer_burst`` (staggered burst peaks per
    SLA class). Deliberately NOT a `TENANT_MIXES` entry: the registry
    names fixed paper-figure scenarios, this one is parameterized by
    scale. Returns the tenant-spec list `make_tenants` (and every
    workload/cluster constructor) accepts."""
    if n_devices < 3:
        raise ValueError(f"scale mix needs >= 3 devices (one per SLA "
                         f"class), got {n_devices}")
    classes = (("gold", 0.3, 0.0), ("silver", 0.4, 0.4),
               ("bronze", 0.3, 0.7))
    base = n_devices // len(classes)
    counts = [base, base, n_devices - 2 * base]
    return [
        dict(tenant=f"{cls}-scale", sla_class=cls,
             fleet=f"array:{n}:{seed + k}", weight=w, phase=ph,
             burst=4.0)
        for k, ((cls, w, ph), n) in enumerate(zip(classes, counts))]


# Named adaptive-controller presets for `serving.control.make_controller`
# (`SimConfig.controller`, CNNSelectServer/ServingLoop `controller=`):
# an ordered mode table (core.selection.CONTROL_MODES names, least ->
# most conservative), the change-point detector watching each device's
# monitor-estimator residuals, and the anti-thrash cooldown. "reactive"
# is the benchmark default; "conservative" needs a stronger/longer
# shift before it escalates (fewer false switches on heavy-tailed
# stationary traffic).
CONTROLLER_SCENARIOS = {
    "reactive": dict(modes=("stationary", "degraded"),
                     detector="cusum:8", monitor="ewma:0.2",
                     cooldown=8),
    "conservative": dict(modes=("stationary", "degraded"),
                         detector="cusum:16", monitor="ewma:0.1",
                         cooldown=32),
    "ph_reactive": dict(modes=("stationary", "degraded"),
                        detector="ph:8", monitor="ewma:0.2",
                        cooldown=8),
}


# --------------------------------------------------------------------------
# Measured zoo (DESIGN.md §14): models the repo actually RUNS behind the
# Router, replacing Table 5 lookups with this host's latencies.
# --------------------------------------------------------------------------

# Reduced attention-only LM variants (stablelm family — maskable KV-cache
# pattern, so padded prompts and mid-group slot backfill work) sized to
# run on CPU CI. d_model/d_ff/n_layers stratify latency the way Table 5's
# CNN depth does; `accuracy` is the offline task score attached to each
# candidate. int8 variants are *distinct selection candidates*: they pay
# a small accuracy penalty but ~75% storage, so under a memory budget a
# quantized larger model can sit on the frontier where its fp32 parent
# cannot fit — the "Smart at what cost?" trade-off. `lm_base_int8`'s
# fp32 parent is deliberately absent for exactly that reason.
MEASURED_ZOO = {
    "lm_tiny":       dict(arch="stablelm_1_6b", d_model=48, d_ff=96,
                          n_layers=2, quant=None, accuracy=0.58),
    "lm_small":      dict(arch="stablelm_1_6b", d_model=96, d_ff=192,
                          n_layers=2, quant=None, accuracy=0.66),
    "lm_small_int8": dict(arch="stablelm_1_6b", d_model=96, d_ff=192,
                          n_layers=2, quant="int8", accuracy=0.652),
    "lm_base_int8":  dict(arch="stablelm_1_6b", d_model=160, d_ff=320,
                          n_layers=4, quant="int8", accuracy=0.72),
}


def measured_zoo_names(subset=None):
    names = list(subset) if subset else list(MEASURED_ZOO)
    for n in names:
        if n not in MEASURED_ZOO:
            raise ValueError(f"unknown measured-zoo model {n!r}; known: "
                             f"{', '.join(MEASURED_ZOO)}")
    return names


def paper_profiles(subset=None):
    """ModelProfile list from Table 5 (top-1 accuracy as A(m))."""
    names = subset or list(TABLE5)
    out = []
    for n in names:
        t1, t5, mu, sg, cmu, csg = TABLE5[n]
        out.append(ModelProfile(
            name=n, accuracy=t1 / 100.0, mu=mu, sigma=sg,
            cold_mu=cmu, cold_sigma=csg,
            size_bytes=int(MODEL_SIZES_MB[n] * 1e6)))
    return out


def lognormal_params(mean, std):
    """(mu, sigma) of the lognormal matched to the given mean/std.
    Accepts scalars or arrays (per-request regime parameters); the one
    implementation shared by `sample_network` and every
    `serving.network.NetworkProcess` — the bit-for-bit legacy-draw
    guarantee depends on there being exactly one copy of this math."""
    mean = np.asarray(mean, np.float64)
    var = np.asarray(std, np.float64) ** 2
    sigma2 = np.log(1.0 + var / mean ** 2)
    return np.log(mean) - sigma2 / 2.0, np.sqrt(sigma2)


def sample_network(name: str, rng: np.random.Generator, n: int = 1):
    """Sample T_input (ms): lognormal matched to (mean, std)."""
    d = NETWORKS[name]
    mu, sigma = lognormal_params(d["mean"], d["std"])
    return rng.lognormal(mu, sigma, size=n)
