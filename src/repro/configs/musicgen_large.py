"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec/text-conditioning frontend is a stub:
`input_specs()` provides precomputed frame embeddings (B, T, d_model);
the backbone is the transformer profiled here. Norm type unified to
RMSNorm framework-wide (noted in DESIGN.md)."""

from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=2048,
        pattern=("attn",),
        mlp_gated=False,
        mlp_act="gelu",
        tie_embeddings=False,
        input_mode="embeddings",
    )
