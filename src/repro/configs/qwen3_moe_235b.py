"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4)
d_ff_expert=1536 vocab=151936, MoE 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B family scaling]. QK-norm, head_dim 128,
rope theta 1e6. Expert-parallel dispatch (128 % 16 == 0)."""

from repro.models.config import ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=0,  # every FFN is MoE
        vocab=151936,
        pattern=("moe",),
        qk_norm=True,
        rope_theta=1e6,
        mlp_gated=True,
        mlp_act="silu",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                      capacity_factor=1.25),
    )
