"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]. Attention and
final logit softcap 30, sqrt(d) embedding scaling, tied embeddings.
E=8 < 16-way model axis -> ff-slice TP expert sharding (moe_mode=tp)."""

from repro.models.config import ModelConfig, MoEConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=0,  # every FFN is MoE
        vocab=131072,
        pattern=("moe",),
        attn_softcap=30.0,
        final_softcap=30.0,
        mlp_gated=True,
        mlp_act="gelu",
        tie_embeddings=True,
        embed_scale=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768,
                      capacity_factor=1.25),
    )
