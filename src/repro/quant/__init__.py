"""Quantization utilities: int8 per-channel weight quantization (the
paper's 8-bit post-training quantization study, Fig 6, adapted to
serving weights) and error-feedback gradient compression building blocks
(cross-pod sync at DiLoCo-style outer steps)."""

from repro.quant.int8 import (
    quantize_int8,
    dequantize_int8,
    quantize_tree,
    dequantize_tree,
    quantize_exec_tree,
    tree_bytes_quantized,
    ef_compress,
)

__all__ = ["quantize_int8", "dequantize_int8", "quantize_tree",
           "dequantize_tree", "quantize_exec_tree",
           "tree_bytes_quantized", "ef_compress"]
