"""int8 quantization with per-channel scales + error feedback.

- Weights: symmetric per-output-channel int8; storage -75% vs fp32
  (matching the paper's "8-bit quantized model leads to the most storage
  saving of 75%" finding), dequantized on the fly or consumed by the
  int8 Pallas matmul kernel.
- Gradient/delta compression: `ef_compress` quantizes a tensor plus the
  accumulated residual and returns the new residual — the error-feedback
  loop keeps long-run bias at zero (property-tested)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, axis: int = -1):
    """Symmetric per-channel int8. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_tree(tree, min_size: int = 1024):
    """Quantize float leaves with >= min_size elements; keep the rest.
    Returns a tree of dicts {"q","scale"} or raw leaves."""
    def f(x):
        if (hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
                and x.size >= min_size and x.ndim >= 2):
            q, s = quantize_int8(x)
            return {"q": q, "scale": s}
        return x
    return jax.tree.map(f, tree)


def dequantize_tree(tree, like=None):
    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    def f(x):
        return dequantize_int8(x["q"], x["scale"]) if is_q(x) else x
    out = jax.tree.map(f, tree, is_leaf=is_q)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out


# Projection leaves the int8 matmul kernel can consume, with the number
# of trailing *output* axes per key (everything before them — minus a
# leading scan-stack axis — contracts): qkv map d -> (H, hd); wo maps
# (H, hd) -> d; the MLP matmuls are plain 2D.
PROJ_OUT_AXES = {"wq": 2, "wk": 2, "wv": 2, "wo": 1,
                 "w_up": 1, "w_gate": 1, "w_down": 1}


def _quantize_matmul(w, out_axes: int, stacked: bool):
    """Matmul-layout int8: one fp32 scale per output channel (the
    trailing `out_axes` axes), amax over the contraction axes — the
    layout `kernels.int8_matmul` needs after flattening to (K, N).
    `quantize_int8`'s axis=-1 scales (one per contraction row) cannot be
    folded into C = X @ Wq post-hoc; this can."""
    red = tuple(range(1 if stacked else 0, w.ndim - out_axes))
    xf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def quantize_exec_tree(params):
    """Execution-layout quantization for the serving fast path: every
    projection matmul weight becomes a {"q" int8, "scale" f32} dict leaf
    that stays resident (no dequantized copy) and is dispatched to the
    int8 matmul kernel by `models.layers._proj`. Embeddings and norms
    stay fp32 (the embedding is a gather and doubles as the tied
    unembed, which needs the transposed layout). Works on the model's
    {"blocks": stacked, "tail": unstacked} param tree; leaves it
    otherwise structurally identical, so jit entry points and lax.scan
    slicing are unchanged."""
    def walk(d, stacked):
        out = {}
        for key, val in d.items():
            if key in PROJ_OUT_AXES and hasattr(val, "dtype"):
                out[key] = _quantize_matmul(val, PROJ_OUT_AXES[key], stacked)
            elif isinstance(val, dict):
                out[key] = walk(val, stacked)
            else:
                out[key] = val
        return out

    out = dict(params)
    out["blocks"] = tuple(walk(b, True) for b in params["blocks"])
    out["tail"] = tuple(walk(b, False) for b in params["tail"])
    return out


def ef_compress(x, residual, axis: int = -1):
    """Error-feedback quantization step.

    q = Q(x + residual); new_residual = (x + residual) - deq(q).
    Returns (q, scale, new_residual). Summed over steps, the quantization
    error does not accumulate (sum of deq(q) -> sum of x)."""
    target = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(target, axis)
    new_residual = target - dequantize_int8(q, scale)
    return q, scale, new_residual


def tree_bytes_quantized(tree) -> int:
    import numpy as np
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total
