"""Data pipeline: synthetic LM tasks + file-corpus byte LM, with
deterministic, resumable, host-sharded iterators (fault tolerance:
an iterator's state is just (seed, step) — checkpointable as two ints)."""

from repro.data.pipeline import (
    MarkovLMTask,
    CopyTask,
    ByteCorpus,
    DataIterator,
)

__all__ = ["MarkovLMTask", "CopyTask", "ByteCorpus", "DataIterator"]
