"""Synthetic + file-backed LM data sources.

All sources are *stateless generators*: batch(step) is a pure function of
(seed, step, host_id), so restart-after-failure resumes bit-identically
from the step counter alone — no iterator state to snapshot (checkpoint
resume tests rely on this).

- MarkovLMTask: tokens from a random sparse Markov chain — learnable
  structure with tunable difficulty (entropy), good for loss-goes-down
  tests.
- CopyTask: `prompt # prompt` — exact-match accuracy is measurable, so
  differently-sized models get genuinely different accuracies for the
  serving demos (the LM analogue of the paper's ImageNet accuracy axis).
- ByteCorpus: byte-level LM over a real file tree (this repo's own
  sources by default).
"""

from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


def _rng_for(seed: int, step: int, host: int) -> np.random.Generator:
    mix = hashlib.blake2b(f"{seed}:{step}:{host}".encode(),
                          digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(mix, "little"))


@dataclass
class MarkovLMTask:
    vocab: int = 256
    branching: int = 4      # out-degree of each state
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(0, self.vocab,
                                        (self.vocab, self.branching))
        probs = rng.dirichlet(np.ones(self.branching) * 0.5, self.vocab)
        self.probs = probs

    def batch(self, step: int, batch: int, seq: int, host: int = 0) -> dict:
        rng = _rng_for(self.seed, step, host)
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        for t in range(seq):
            cur = toks[:, t]
            choice = np.array([rng.choice(self.branching, p=self.probs[c])
                               for c in cur])
            toks[:, t + 1] = self.next_tokens[cur, choice]
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class CopyTask:
    vocab: int = 64          # data tokens; vocab-1 is the separator
    prompt_len: int = 12
    seed: int = 0

    @property
    def sep(self) -> int:
        return self.vocab - 1

    def batch(self, step: int, batch: int, host: int = 0) -> dict:
        rng = _rng_for(self.seed, step, host)
        p = rng.integers(0, self.vocab - 1,
                         (batch, self.prompt_len)).astype(np.int32)
        sep = np.full((batch, 1), self.sep, np.int32)
        seq = np.concatenate([p, sep, p], axis=1)
        return {"inputs": seq[:, :-1], "labels": seq[:, 1:],
                "prompt": np.concatenate([p, sep], axis=1)}

    def exact_match(self, engine, n_batches: int = 4, start_step: int = 10_000):
        """Fraction of positions correctly copied by greedy decoding."""
        correct = total = 0
        for b in range(n_batches):
            d = self.batch(start_step + b, engine.batch_size)
            out = engine.generate(d["prompt"], self.prompt_len)
            correct += (out == d["prompt"][:, :self.prompt_len]).sum()
            total += out.size
        return correct / total


class ByteCorpus:
    """Byte-level LM over a directory of text files."""

    def __init__(self, root: str, exts=(".py", ".md"), seed: int = 0,
                 max_bytes: int = 4_000_000):
        blobs = []
        root = os.path.abspath(root)  # ".." segments would trip the
        # hidden-directory filter below
        for dirpath, _, files in sorted(os.walk(root)):
            if any(part.startswith(".") for part in dirpath.split(os.sep)):
                continue
            for f in sorted(files):
                if f.endswith(tuple(exts)):
                    with open(os.path.join(dirpath, f), "rb") as fh:
                        blobs.append(fh.read())
            if sum(map(len, blobs)) > max_bytes:
                break
        self.data = np.frombuffer(b"\n".join(blobs), dtype=np.uint8)
        self.seed = seed
        self.vocab = 256

    def batch(self, step: int, batch: int, seq: int, host: int = 0) -> dict:
        rng = _rng_for(self.seed, step, host)
        starts = rng.integers(0, len(self.data) - seq - 1, batch)
        rows = np.stack([self.data[s:s + seq + 1] for s in starts])
        rows = rows.astype(np.int32)
        return {"inputs": rows[:, :-1], "labels": rows[:, 1:]}


class DataIterator:
    """Host-sharded step iterator: each host draws its own sub-batch via
    its host id; global batch = per_host_batch * n_hosts. Resume = set
    .step (stored in the train checkpoint)."""

    def __init__(self, source, batch: int, seq: Optional[int] = None,
                 host: int = 0, n_hosts: int = 1, step: int = 0):
        self.source = source
        self.batch = batch
        self.seq = seq
        self.host = host
        self.n_hosts = n_hosts
        self.step = step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self.seq is not None:
            d = self.source.batch(self.step, self.batch, self.seq, self.host)
        else:
            d = self.source.batch(self.step, self.batch, self.host)
        self.step += 1
        return d
