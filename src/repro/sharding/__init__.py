"""Logical-axis -> mesh-axis rule tables and sharding helpers.

Two profiles:

- **train**: FSDP(ZeRO-3) + TP. Weight matmul-input dims (`hidden_in`,
  `embed`, `expert_in`) shard over the data axis (all-gathered at use by
  GSPMD / explicitly inside the MoE shard_map); TP dims (`heads`, `ff`,
  `vocab`, `experts`|`expert_ff`, `rnn_width`, `ssd_inner`...) shard over
  the model axis. Activations: batch over (pod, data); optionally the
  sequence dim over model between blocks (Megatron-style sequence
  parallelism, `seq_shard`) so the scanned residual carry stays sharded.

- **serve**: latency-oriented 2D TP. `ff` shards over (data, model)
  (all assigned d_ff are divisible by 256); heads over model; no FSDP
  for dense weights; MoE expert weights keep the per-layer FSDP gather
  (they are too large otherwise). KV caches: batch over (pod, data),
  kv-heads over model (GSPMD pads when kv < 16 — baseline; see §Perf).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParallelConfig:
    mesh: Mesh
    data_axes: Tuple[str, ...]          # activation batch axes, e.g. ("pod","data")
    fsdp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    moe_mode: str = "auto"              # ep | tp | auto
    profile: str = "train"              # train | serve
    seq_shard: bool = False             # Megatron-style SP between blocks
    # "full": T-sharded residual constraint after every add;
    # "carry": only the scan carry is T-sharded — x is gathered to
    # model-replicated at group entry so qkv/attention run head-sharded
    # (per-arch lever, see §Perf).
    seq_mode: str = "full"
    attn_pin: bool = False              # pin q/k/v head-sharded (per-arch lever)

    @property
    def num_devices(self) -> int:
        return self.mesh.devices.size


# Sentinel for 0-d state leaves (e.g. the train step counter): maps to P().
SCALAR_AXES = ("@scalar",)


def make_rules(parallel: ParallelConfig, cfg=None) -> dict:
    """pjit in_shardings demand exact divisibility (GSPMD padding applies
    only to propagated intermediates), so rules are config-conditional:

    - kv_heads shard over model only when n_kv_heads % tp == 0; otherwise
      the KV *cache* shards its sequence dim over model instead (GSPMD
      then executes decode attention flash-decode style: per-shard
      partial max/sum + tiny psums — verified in the dry-run HLO).
    - vocab shards only when divisible (mamba2's 50280 is not).
    """
    fsdp = parallel.fsdp_axes
    tp = parallel.tp_axis
    tp_size = parallel.mesh.shape[tp]
    train = parallel.profile == "train"
    kv_div = cfg is None or cfg.n_kv_heads % tp_size == 0
    vocab_div = cfg is None or cfg.padded_vocab % tp_size == 0
    return {
        # embedding / unembedding
        "vocab": tp if vocab_div else None,
        "embed": fsdp,
        # dense weights
        "hidden_in": fsdp if train else None,
        "heads": tp,
        "kv_heads": tp if kv_div else None,
        "head_dim": None,
        # 1D TP for ff in BOTH profiles: 2D (data x model) serve-TP forced
        # GSPMD to all-gather batch-sharded activations over data at every
        # FFN (v0 prefill blow-up, EXPERIMENTS.md §Perf iteration 1).
        "ff": tp,
        # MoE (layout consumed by the shard_map in models/moe.py)
        "router": None,
        "experts": tp,       # remapped to None at spec time for moe_mode=tp
        "expert_in": fsdp,
        "expert_ff": None,   # remapped to tp for moe_mode=tp
        # RG-LRU / SSD
        "rnn_in": None,
        "rnn_width": tp,
        "ssd_inner": tp,
        "ssd_heads": tp,
        "ssd_gn": None,
        "ssd_state": None,
        "ssd_hd": None,
        # caches
        "cache_batch": parallel.data_axes,
        "cache_seq": None if kv_div else tp,
        # misc
        "norm": None,
        "conv_k": None,
        "layers": None,
    }


def moe_mode_for(cfg, parallel: ParallelConfig) -> str:
    """auto   -> ep/tp   (weight-gather layouts: train/prefill)
       auto2d -> ep2d/tp2d (weight-resident layouts: decode)."""
    mode = parallel.moe_mode
    tp_size = parallel.mesh.shape[parallel.tp_axis]
    ep_ok = cfg.moe is not None and cfg.moe.n_experts % tp_size == 0
    if mode == "auto":
        return "ep" if ep_ok else "tp"
    if mode == "auto2d":
        return "ep2d" if ep_ok else "tp2d"
    return mode


def spec_for(axes: Tuple[str, ...], rules: dict) -> P:
    if tuple(axes) == SCALAR_AXES:
        return P()
    entries = []
    used = set()
    for ax in axes:
        m = rules.get(ax)
        if m is None:
            entries.append(None)
            continue
        if isinstance(m, str):
            entries.append(None if m in used else m)
            used.add(m)
            continue
        # Tuple rules stay tuples even when deduped down to one axis:
        # jax keeps P(('data',)) distinct from P('data'), and the rule
        # tables use tuple form for the (possibly multi-axis) fsdp /
        # data axes.
        ms = tuple(a for a in m if a not in used)
        used.update(ms)
        entries.append(ms if ms else None)
    return P(*entries)


def _is_axes_leaf(x) -> bool:
    # Non-empty tuples of axis names; empty tuples are STRUCTURAL (e.g. an
    # arch with no tail layers) and must stay part of the tree shape.
    return (isinstance(x, tuple) and len(x) > 0 and all(
        isinstance(a, (str, type(None))) for a in x))


def tree_specs(logical_tree, parallel: ParallelConfig, cfg=None):
    """Map a tree of logical-axis tuples to PartitionSpecs."""
    rules = dict(make_rules(parallel, cfg))
    if cfg is not None and cfg.moe is not None:
        # Keep stored expert-weight layouts in lockstep with the
        # shard_map in_specs (models/moe.py moe_weight_specs).
        mode = moe_mode_for(cfg, parallel)
        tp, fsdp = parallel.tp_axis, parallel.fsdp_axes
        remap = {
            "ep": {"experts": tp, "expert_in": fsdp, "expert_ff": None},
            "tp": {"experts": None, "expert_in": fsdp, "expert_ff": tp},
            "ep2d": {"experts": tp, "expert_in": None, "expert_ff": fsdp},
            "tp2d": {"experts": None, "expert_in": None,
                     "expert_ff": tuple(fsdp) + (tp,)},
        }[mode]
        rules.update(remap)
    return jax.tree.map(lambda axes: spec_for(axes, rules), logical_tree,
                        is_leaf=_is_axes_leaf)


def tree_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(parallel: ParallelConfig, ndim: int) -> P:
    """Batch-leading activation spec: (B, ...) -> batch over data axes."""
    return P(parallel.data_axes, *([None] * (ndim - 1)))


def make_parallel(mesh: Mesh, profile: str, *, seq_shard: Optional[bool] = None,
                  moe_mode: str = "auto", attn_pin: bool = False,
                  seq_mode: str = "full") -> ParallelConfig:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    if seq_shard is None:
        seq_shard = profile == "train"
    return ParallelConfig(
        mesh=mesh,
        data_axes=data_axes,
        fsdp_axes=("data",),
        tp_axis="model",
        moe_mode=moe_mode,
        profile=profile,
        seq_shard=seq_shard,
        seq_mode=seq_mode,
        attn_pin=attn_pin,
    )
