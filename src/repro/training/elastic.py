"""Elastic scaling & failure handling.

At 1000+ node scale, node loss is routine. The recovery path this module
implements (and tests exercise with host-device meshes):

  1. a heartbeat monitor marks hosts dead (`HostMonitor`),
  2. the launcher rebuilds a smaller rectangular mesh from survivors
     (`shrink_mesh`), preferring to shrink the data axis — TP degree is
     baked into weight layouts, DP is not,
  3. train state is restored from the last committed checkpoint onto the
     new mesh (checkpoint.restore_checkpoint with the new shardings) and
     the step function is re-lowered,
  4. the data iterator resumes from the checkpointed step — batches are
     pure functions of (seed, step, host), so the re-run is
     deterministic with the new host count.

Straggler mitigation at the serving layer is hedged requests
(simulator.py); at the training layer, synchronous SPMD steps make
per-step stragglers a scheduling concern, so the monitor also exposes
`slow_hosts` for the launcher to drain."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclass
class HostMonitor:
    n_hosts: int
    timeout_s: float = 60.0
    slow_factor: float = 3.0
    last_beat: Dict[int, float] = field(default_factory=dict)
    step_times: Dict[int, list] = field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None,
             step_time: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.last_beat[host] = now
        if step_time is not None:
            self.step_times.setdefault(host, []).append(step_time)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h in range(self.n_hosts)
                if now - self.last_beat.get(h, -1e18) > self.timeout_s]

    def slow_hosts(self) -> List[int]:
        med = np.median([np.median(v) for v in self.step_times.values()
                         if v] or [0.0])
        if med == 0.0:
            return []
        return [h for h, v in self.step_times.items()
                if v and np.median(v) > self.slow_factor * med]


def largest_rect(n: int, model: int) -> Tuple[int, int]:
    """Largest (data, model) grid with fixed model degree using <= n
    devices: data = n // model."""
    return max(n // model, 1), model


def shrink_mesh(alive_devices, *, model_degree: int, axis_names=("data", "model")):
    """Rebuild a rectangular mesh from surviving devices, keeping the TP
    (model) degree fixed and shrinking DP. Returns (mesh, n_dropped)."""
    alive = list(alive_devices)
    data, model = largest_rect(len(alive), model_degree)
    use = data * model
    devs = np.asarray(alive[:use]).reshape(data, model)
    mesh = jax.sharding.Mesh(devs, axis_names)
    return mesh, len(alive) - use


def recover(ckpt_manager, abstract_state, new_mesh, spec_tree):
    """Restore the latest committed checkpoint onto a (possibly smaller)
    mesh. Returns (state, step)."""
    from jax.sharding import NamedSharding
    shardings = jax.tree.map(
        lambda s: NamedSharding(new_mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    state, manifest = ckpt_manager.restore_latest(
        abstract_state, shardings=shardings)
    return state, manifest["step"]
