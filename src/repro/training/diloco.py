"""DiLoCo-style cross-pod training with error-feedback int8 outer sync.

At 1000+ node scale the cross-pod (DCN) links are the scarce resource.
Instead of all-reducing gradients across pods every step, each pod
trains independently for `inner_steps`, then pods exchange *parameter
deltas* quantized to int8 with error feedback (repro.quant.ef_compress)
and apply an outer (Nesterov-momentum) update to the shared anchor:

    delta_p   = anchor - params_p                  (per pod)
    q_p       = EF-int8(delta_p)                   (residual carried)
    delta_avg = mean_p dequant(q_p)                (the only DCN traffic)
    anchor'   <- outer_opt(anchor, delta_avg)
    params_p  <- anchor'

DCN bytes per sync drop 4x vs fp32 deltas (int8 + per-channel scales),
and by 1/inner_steps vs per-step gradient sync. The single-process
implementation below is pod-count-parameterized and exercised by tests;
on real multi-pod deployments each pod is one jax process group and the
averaging runs over DCN."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.quant.int8 import ef_compress, dequantize_int8


@dataclass
class OuterState:
    anchor: dict                      # shared fp32 anchor params
    momentum: dict                    # Nesterov momentum on deltas
    residuals: List[dict]             # per-pod EF residuals
    syncs: int = 0
    bytes_sent: int = 0               # cumulative compressed DCN bytes
    bytes_fp32: int = 0               # what fp32 deltas would have cost


def init_outer(params, n_pods: int) -> OuterState:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    return OuterState(
        anchor=f32,
        momentum=jax.tree.map(jnp.zeros_like, f32),
        residuals=[jax.tree.map(jnp.zeros_like, f32) for _ in range(n_pods)],
    )


def outer_sync(state: OuterState, pod_params: List[dict], *,
               outer_lr: float = 0.7, outer_momentum: float = 0.9,
               quantize: bool = True) -> OuterState:
    """One outer step. Returns the new OuterState; callers reset each
    pod's params to `state.anchor` afterwards."""
    n = len(pod_params)
    deltas = []
    comp_bytes = 0
    raw_bytes = 0
    for i, params in enumerate(pod_params):
        delta = jax.tree.map(
            lambda a, p: a - p.astype(jnp.float32), state.anchor, params)
        if quantize:
            new_res = {}
            deq = {}
            flat_delta, treedef = jax.tree.flatten(delta)
            flat_res = jax.tree.leaves(state.residuals[i])
            out_d, out_r = [], []
            for d, r in zip(flat_delta, flat_res):
                if d.ndim >= 2:
                    q, s, nr = ef_compress(d, r)
                    out_d.append(dequantize_int8(q, s))
                    out_r.append(nr)
                    comp_bytes += q.size + 4 * s.size
                else:  # tiny 1-D leaves stay fp32
                    out_d.append(d)
                    out_r.append(jnp.zeros_like(r))
                    comp_bytes += d.size * 4
                raw_bytes += d.size * 4
            delta = jax.tree.unflatten(treedef, out_d)
            state.residuals[i] = jax.tree.unflatten(treedef, out_r)
        deltas.append(delta)
    avg = jax.tree.map(lambda *ds: sum(ds) / n, *deltas)
    mom = jax.tree.map(
        lambda m, d: outer_momentum * m + d, state.momentum, avg)
    anchor = jax.tree.map(
        lambda a, m, d: a - outer_lr * (outer_momentum * m + d),
        state.anchor, mom, avg)  # Nesterov
    return OuterState(anchor=anchor, momentum=mom,
                      residuals=state.residuals,
                      syncs=state.syncs + 1,
                      bytes_sent=state.bytes_sent + comp_bytes,
                      bytes_fp32=state.bytes_fp32 + raw_bytes)


def broadcast_anchor(state: OuterState, like_params) -> dict:
    """anchor -> pod param dtype (bf16/fp32)."""
    return jax.tree.map(lambda a, p: a.astype(p.dtype), state.anchor,
                        like_params)
