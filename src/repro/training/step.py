"""Train step: mixed-precision loss, grads, optimizer update.

Parameters are kept in fp32 (master copy); the forward pass runs in
`cfg.compute_dtype` (bf16 on the TPU target), so gradients — and hence
the data-parallel reduction collectives — move bf16 bytes (the
"gradient compression" lever measured in §Perf; int8+error-feedback
building blocks live in repro/quant)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from repro.utils import dtype_of


def cast_floating(tree, dtype):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)


def cross_entropy(logits, labels, z_weight: float = 0.0):
    """logits: (B,T,V) fp32; labels: (B,T) int32. Mean token NLL.

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: with vocab sharded over the model axis, GSPMD shards
    the one-hot and psums a scalar, whereas gathering on the sharded dim
    all-gathered the full logits (v0 roofline, §Perf iteration 1)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    loss = nll.mean()
    if z_weight:
        loss = loss + z_weight * jnp.square(lse).mean()
    return loss


def make_loss_fn(cfg: ModelConfig, parallel=None, aux_weight: float = 0.01,
                 z_weight: float = 0.0):
    compute = dtype_of(cfg.compute_dtype)

    def loss_fn(params, batch):
        cparams = cast_floating(params, compute)
        logits, extras = forward(cparams, batch["inputs"], cfg,
                                 parallel=parallel)
        loss = cross_entropy(logits, batch["labels"], z_weight)
        total = loss + aux_weight * extras["aux_loss"]
        return total, {"loss": loss, "aux_loss": extras["aux_loss"]}

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, parallel=None,
                    aux_weight: float = 0.01):
    loss_fn = make_loss_fn(cfg, parallel, aux_weight)

    def train_step(state, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        new_params, new_opt, om = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        metrics = dict(metrics, total_loss=total, **om)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, optimizer, key):
    from repro.models import init_params
    params = init_params(cfg, key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_logical_axes(cfg: ModelConfig, optimizer):
    from repro.models import param_logical_axes
    from repro.sharding import SCALAR_AXES
    axes = param_logical_axes(cfg)
    return {"params": axes, "opt": optimizer.state_logical_axes(axes),
            "step": SCALAR_AXES}


def abstract_train_state(cfg: ModelConfig, optimizer):
    """ShapeDtypeStruct train state (params fp32 master + opt state)."""
    from repro.models.params import abstract_params

    params = abstract_params(cfg)

    def opt_abstract(p):
        return jax.eval_shape(optimizer.init, p)

    opt = opt_abstract(params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
