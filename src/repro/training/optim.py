"""Optimizers from scratch (no optax in this environment).

- `adamw`: classic AdamW with fp32 m/v, decoupled weight decay, global
  gradient-norm clipping, arbitrary LR schedule.
- `adafactor`: factored second moments (rows/cols) for >=2D leaves, no
  first moment by default — the memory-frugal choice for the 100B+
  assigned architectures (grok-1, qwen3-moe), where full Adam state
  would not fit a single v5e pod (DESIGN.md §5).

Both expose `state_logical_axes(param_axes)` so optimizer state shards
exactly like (or factored from) its parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable          # params -> opt_state
    update: Callable        # (grads, opt_state, params, step) -> (new_params, new_opt_state, metrics)
    state_logical_axes: Callable  # param_axes_tree -> state_axes_tree


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


class _Packed:
    """Opaque (unregistered-pytree) container so per-leaf optimizer results
    can be split apart with tree.map — plain tuples would collide with the
    structural tuples inside parameter trees."""
    __slots__ = ("vals",)

    def __init__(self, *vals):
        self.vals = vals


def _unpack(flat, i):
    return jax.tree.map(lambda t: t.vals[i], flat)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), gn


def mixed_precision(inner: Optimizer) -> Optimizer:
    """bf16 params + fp32 master copy in the optimizer state.

    With fp32 params the autodiff cast boundary made XLA all-reduce
    gradients in fp32; keeping the *live* params bf16 means gradients are
    born bf16, so the data-parallel reductions move half the bytes (the
    gradient-compression lever of DESIGN.md §5 — measured in §Perf).
    The int8+error-feedback path (repro.quant.ef_compress) extends this
    for cross-pod outer steps."""

    def init(params):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return {"master": master, "inner": inner.init(params)}

    def update(grads, state, params, step):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_master, new_inner, metrics = inner.update(
            g32, state["inner"], state["master"], step)
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_master, params)
        return new_params, {"master": new_master, "inner": new_inner}, metrics

    def state_logical_axes(param_axes):
        return {"master": param_axes,
                "inner": inner.state_logical_axes(param_axes)}

    return Optimizer(init, update, state_logical_axes)


def adamw(lr_schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, clip_norm)
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_schedule(step)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            pf = p.astype(jnp.float32)
            new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
            return _Packed(new_p.astype(p.dtype), m, v)

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = _unpack(flat, 0)
        new_m = _unpack(flat, 1)
        new_v = _unpack(flat, 2)
        return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gn, "lr": lr}

    def state_logical_axes(param_axes):
        return {"m": param_axes, "v": param_axes}

    return Optimizer(init, update, state_logical_axes)


def adafactor(lr_schedule, eps2: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0,
              min_dim_factored: int = 2) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), beta1=0 variant."""

    def _factored(p):
        return p.ndim >= min_dim_factored

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        beta2 = 1.0 - stepf ** (-decay_pow)
        lr = lr_schedule(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps2
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps2)
                u = (g * jax.lax.rsqrt(vr / denom)[..., None]
                     * jax.lax.rsqrt(vc)[..., None, :])
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vv)
                new_v = {"v": vv}
            # RMS clip.
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(pf)) + 1e-30), 1e-3)
            new_p = pf - lr * scale * u - lr * weight_decay * pf
            return _Packed(new_p.astype(p.dtype), new_v)

        # grads' structure drives the map; the state subtree ({"vr","vc"} or
        # {"v"}) at each grad leaf is passed whole to upd.
        flat = jax.tree.map(upd, grads, state["v"], params)
        new_params = _unpack(flat, 0)
        new_v = _unpack(flat, 1)
        return new_params, {"v": new_v}, {"lr": lr}

    def state_logical_axes(param_axes):
        def st(axes):
            # Mirror the factoring: vr drops the last logical axis, vc the
            # second-to-last.
            if len(axes) >= min_dim_factored:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}
        # Empty tuples are structural (archs without tail layers), not axes.
        return {"v": jax.tree.map(
            st, param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
                isinstance(a, (str, type(None))) for a in x))}

    return Optimizer(init, update, state_logical_axes)
