"""Fault-tolerant checkpointing (no orbax in this environment).

Layout per step:  <dir>/step_<N>/
    manifest.json      tree structure, shapes/dtypes, config hash, step
    shard_<host>.npz   this host's param/opt arrays (flattened leaves)
    _COMMITTED         sentinel written LAST (atomic rename) — restore
                       ignores checkpoints without it, so a crash mid-
                       write can never be restored from.

CheckpointManager: retention (keep_n), save_interval, latest-committed
lookup, resume; restore reshards onto the current mesh via device_put
with the target shardings — which is also the elastic-rescale path
(restore the same arrays onto a smaller/larger surviving mesh)."""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def tree_fingerprint(tree) -> str:
    spec = [(list(x.shape), str(x.dtype)) for x in jax.tree.leaves(tree)]
    return hashlib.sha256(json.dumps(spec).encode()).hexdigest()[:16]


def save_checkpoint(path: str, state, *, step: int, host: int = 0,
                    extra: Optional[dict] = None):
    """Atomic: write into a temp dir, fsync, then rename + commit marker."""
    os.makedirs(path, exist_ok=True)
    step_dir = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=path)
    try:
        leaves, treedef = _flatten(state)
        arrs = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, f"shard_{host}.npz"), **arrs)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "fingerprint": tree_fingerprint(state),
            "treedef": str(treedef),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.rename(tmp, step_dir)
        # Commit marker written last: restore treats its absence as a
        # torn write and skips the checkpoint.
        with open(os.path.join(step_dir, "_COMMITTED"), "w") as f:
            f.write("ok")
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return step_dir


def committed_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for d in sorted(os.listdir(path)):
        if d.startswith("step_") and os.path.exists(
                os.path.join(path, d, "_COMMITTED")):
            out.append(int(d.split("_")[1]))
    return out


def restore_checkpoint(path: str, target_state, *, step: Optional[int] = None,
                       host: int = 0, shardings=None):
    """Restore into the structure of `target_state` (abstract or concrete).
    shardings: optional matching tree of NamedShardings — arrays are
    device_put onto them (the elastic re-mesh path)."""
    steps = committed_steps(path)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {path}")
    step = steps[-1] if step is None else step
    step_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["fingerprint"] != tree_fingerprint(target_state):
        raise ValueError(
            "checkpoint/model structure mismatch: "
            f"{manifest['fingerprint']} vs {tree_fingerprint(target_state)}")
    data = np.load(os.path.join(step_dir, f"shard_{host}.npz"))
    leaves, treedef = _flatten(target_state)
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        arr = arr.astype(ref.dtype) if str(arr.dtype) != str(ref.dtype) else arr
        new_leaves.append(arr)
    restored = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    return restored, manifest


class CheckpointManager:
    def __init__(self, path: str, *, keep_n: int = 3, save_interval: int = 50):
        self.path = path
        self.keep_n = keep_n
        self.save_interval = save_interval
        os.makedirs(path, exist_ok=True)

    def maybe_save(self, state, step: int, **kw) -> Optional[str]:
        if step % self.save_interval != 0:
            return None
        return self.save(state, step, **kw)

    def save(self, state, step: int, **kw) -> str:
        out = save_checkpoint(self.path, state, step=step, **kw)
        self._gc()
        return out

    def _gc(self):
        steps = committed_steps(self.path)
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        steps = committed_steps(self.path)
        return steps[-1] if steps else None

    def restore_latest(self, target_state, **kw):
        return restore_checkpoint(self.path, target_state, **kw)
