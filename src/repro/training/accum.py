"""Gradient accumulation (microbatching): the standard lever when the
global batch exceeds what activations allow per step. The batch is split
into `n_micro` microbatches scanned sequentially; gradients average in
fp32. Loss/grads are IDENTICAL to the monolithic step (property-tested),
so it composes with every optimizer and sharding profile."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.training.step import make_loss_fn


def make_accum_train_step(cfg, optimizer, n_micro: int, parallel=None,
                          aux_weight: float = 0.01):
    loss_fn = make_loss_fn(cfg, parallel, aux_weight)

    def train_step(state, batch):
        def split(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            gsum, lsum = carry
            (total, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + metrics["loss"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state["params"])
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_params, new_opt, om = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, dict(loss=lsum / n_micro, **om)

    return train_step
