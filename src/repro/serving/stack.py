"""The `ServingStack` protocol: one API over every serving stack.

The paper evaluates CNNSelect in three settings — a live prototype
server (`CNNSelectServer`, batch-of-one), a continuous-batching loop
(`ServingLoop`), and event-driven simulation (`simulate`). Each grew
its own entry points, so nothing could compose them. The protocol is
the enabling redesign for the multi-tenant cluster (serving/cluster.py,
DESIGN.md §16): a stack is anything that can

- ``submit(req, *, now=0.0) -> StackOutcome``  — admit one request
  (executing it inline, or queueing it with ``pending=True``),
- ``drain()``                                   — run queued work,
- ``observe_outcome(name, latency_ms, ...)``    — feed a measured
  latency back into its online profiles,
- expose ``metrics``                            — the unified
  `ServingMetrics` ledger (serving/metrics.py).

`Cluster` composes replicas through exactly this surface without
caring which kind they are. `SimReplicaStack` is the third
implementation: the simulator's sampled-execution semantics (profile
lognormals, cold starts, a single-server virtual clock) behind the
same API, cheap enough to run 10-100x today's request rates in the
multi-tenant benchmark.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.selection import ModelProfile
from repro.serving.batching import Request
from repro.serving.control import ControlPlane
from repro.serving.metrics import ServingMetrics
from repro.serving.router import Router

__all__ = ["ServingStack", "StackOutcome", "SimReplicaStack",
           "BlockNormals"]


class BlockNormals:
    """Blocked gaussian sampling with the scalar draw order preserved.

    `SimReplicaStack` consumes one gaussian per exec sample (plus one
    per cold start), through ``Generator.normal(loc, scale)``. numpy
    computes that as ``loc + scale * standard_normal()``, and a block
    ``standard_normal(n)`` consumes the ziggurat stream exactly like n
    scalar calls — so refilling from ``standard_normal(block)`` and
    affine-transforming per draw is bit-for-bit the scalar sequence
    while paying the generator call overhead once per `block` draws
    (pinned by tests/test_cluster_engine.py).

    `take(n)` hands the next n standard normals out as an array —
    the scan cluster engine (serving/cluster_engine.py) pre-draws each
    replica's whole stream from a deepcopy of this object, then calls
    `take` on the live one to advance it by exactly the count the scan
    consumed, so python and scan paths leave identical RNG state.
    """

    def __init__(self, seed, *, block: int = 256):
        self.gen = (seed if isinstance(seed, np.random.Generator)
                    else np.random.default_rng(seed))
        self.block = int(block)
        self._z = np.empty(0, np.float64)
        self._i = 0

    def normal(self, loc: float = 0.0, scale: float = 1.0) -> float:
        if self._i >= len(self._z):
            self._z = self.gen.standard_normal(self.block)
            self._i = 0
        z = self._z[self._i]
        self._i += 1
        return float(loc + scale * z)

    def take(self, n: int) -> np.ndarray:
        """Consume the next `n` standard normals, leaving the state
        exactly where n scalar `normal` calls would."""
        out = np.empty(int(n), np.float64)
        filled = 0
        while filled < len(out):
            if self._i >= len(self._z):
                self._z = self.gen.standard_normal(self.block)
                self._i = 0
            k = min(len(self._z) - self._i, len(out) - filled)
            out[filled:filled + k] = self._z[self._i:self._i + k]
            self._i += k
            filled += k
        return out


@dataclass
class StackOutcome:
    """What a stack can say about a request at submission time.

    Inline stacks (server, sim replica) know the outcome immediately;
    queueing stacks (the loop) return ``pending=True`` and the outcome
    lands in `metrics.records` at `drain`."""
    model: str
    mode: str = "static"
    e2e_ms: Optional[float] = None
    ok: Optional[bool] = None
    pending: bool = False
    tenant: Optional[str] = None
    hedged: bool = False
    fallback: bool = False


@runtime_checkable
class ServingStack(Protocol):
    """Structural type for a serving stack (module docstring). Checked
    with ``isinstance`` (``issubclass`` rejects protocols with data
    members); the conformance suite in tests/test_stack.py runs the
    same behavioural contract against all implementations."""

    metrics: ServingMetrics

    def submit(self, req: Request, *, now: float = 0.0) -> StackOutcome:
        ...

    def drain(self) -> None:
        ...

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        ...


class SimReplicaStack:
    """A simulated single-server replica behind the `ServingStack` API.

    Execution is sampled from the registered profiles (the simulator's
    semantics: gaussian exec via `ModelZoo.sample_exec`, cold-start
    penalty via `ensure_hot`, FIFO queueing at one virtual server) —
    no engines, so a `Cluster` of these runs 10-100x today's request
    rates. `speed` scales execution (a replica on faster silicon);
    `tokens_per_s` carries the *measured* capacity score when the
    profiles came from `measured_profiles` (PR 7's executed tokens/s,
    not table lookups) and backs `capacity_score`.
    """

    def __init__(self, profiles: Sequence[ModelProfile], *,
                 policy: str = "cnnselect", t_threshold: float = 50.0,
                 seed: int = 0, controller=None, t_estimator=None,
                 speed: float = 1.0,
                 memory_budget_bytes: Optional[int] = None,
                 tokens_per_s: Optional[float] = None,
                 name: str = "replica"):
        self.name = name
        self.router = Router(profiles, policy=policy,
                             t_threshold=t_threshold, seed=seed,
                             t_estimator=t_estimator,
                             memory_budget_bytes=memory_budget_bytes)
        self.control = ControlPlane(self.router, controller=controller,
                                    seed=seed, t_threshold=t_threshold)
        self.speed = float(speed)
        self.tokens_per_s = tokens_per_s
        self.metrics = ServingMetrics()
        self.rng = BlockNormals(
            np.random.default_rng(np.random.SeedSequence(seed)))
        self._server_free = 0.0
        # Cluster-wide placement hook (serving/cluster.py): when set,
        # hot transitions route through the placer's global budget
        # instead of this replica's own zoo LRU.
        self._placer = None

    # -- capacity -----------------------------------------------------
    def capacity_score(self) -> float:
        """Requests/s this replica can execute, used for scale-up
        ordering and hedge targets: the measured executed tokens/s when
        available, else 1000/mu of the fastest profile (a pure-profile
        proxy with the same ordering semantics)."""
        if self.tokens_per_s is not None:
            return float(self.tokens_per_s)
        mus = [p.mu for p in self.router.current_profiles() if p.mu > 0]
        return 1000.0 / min(mus) if mus else 0.0

    @property
    def free_time(self) -> float:
        """When the virtual server frees up — the raw queue state.
        `Cluster` caches this per replica and derives `queue_delay`
        itself (same ``max(0, free - arrive)`` expression, so the
        cached path is bit-for-bit the uncached one)."""
        return self._server_free

    def queue_delay(self, now: float) -> float:
        """How long a request arriving `now` waits before executing."""
        return max(0.0, self._server_free - now)

    # -- placement ----------------------------------------------------
    def attach_placer(self, placer) -> None:
        self._placer = placer

    def _ensure_hot(self, name: str, now: float) -> float:
        if self._placer is not None:
            return self._placer.ensure_hot(self, name, now)
        return self.router.zoo.ensure_hot(name, now, self.rng)

    # -- ServingStack -------------------------------------------------
    def submit(self, req: Request, *, now: float = 0.0) -> StackOutcome:
        t_sla = req.sla_ms or 1e9
        d = self.control.step(t_sla, req.t_input_ms,
                              device_id=req.device_id)
        startup = self._ensure_hot(d.name, now)
        exec_ms = (self.router.zoo.sample_exec(d.name, self.rng)
                   / self.speed + startup)
        arrive = now + req.t_input_ms
        start = max(arrive, self._server_free)
        queue = start - arrive
        self._server_free = start + exec_ms
        e2e = 2 * req.t_input_ms + queue + exec_ms
        ok = (e2e <= t_sla) if req.sla_ms else True
        acc = self.router.zoo.entries[d.name].profile.accuracy
        self.metrics.add(req, d.name, queue_ms=queue, exec_ms=exec_ms,
                         mode=d.mode, e2e_ms=e2e, ok=ok, accuracy=acc)
        return StackOutcome(model=d.name, mode=d.mode, e2e_ms=e2e,
                            ok=ok, tenant=req.tenant)

    def drain(self) -> None:
        """Inline execution — nothing queued across submits."""

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        self.control.observe_outcome(name, latency_ms, cold=cold,
                                     now=now)
