"""One metrics schema for every serving stack (DESIGN.md §16).

Pre-unification the three entry points grew three incompatible report
surfaces: `CNNSelectServer` counted into `ServerMetrics` fields,
`ServingLoop` appended `LoopMetrics` record dicts, and `simulate()`
returned `SimResult` arrays — same questions (served / attainment /
latency, split by device / mode), three shapes. `ServingMetrics` is the
one record-of-dicts ledger behind the first two (and the `Cluster`),
and `group_stats` is the one group-by-attainment aggregation shared
with `SimResult.per_regime / per_device / per_mode`.

Unified `summary()` schema (every stack, simulator included):

    served, attainment, accuracy, mean_ms, p95_ms,
    mean_queue_ms, p95_queue_ms, selections
    + by_device   (when any request carried a device_id)
    + by_mode, fallbacks (when any mode beyond "static" governed)
    + by_tenant   (when any request carried a tenant tag)
    + hedges      (when any request was duplicated cross-replica)

Unified per-bucket schema (`per_device` / `per_mode` / `per_tenant`,
and `SimResult.per_regime`): share, served, attainment, mean_latency
(+ accuracy when recorded, + extra mean columns).

The pre-unification attribute names (`latencies_ms`, `accuracies`,
`selections`, `by_device`, `by_mode` as raw containers) survive as
deprecated read-only aliases that emit `DeprecationWarning` (pinned by
tests/test_stack.py); the loop's `mean_e2e_ms`/`p95_e2e_ms` summary
keys became `mean_ms`/`p95_ms` (migration note in CHANGES.md).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.batching import Request

__all__ = ["ServingMetrics", "group_stats"]


def group_stats(index: np.ndarray, names: Sequence[str], *,
                violations: np.ndarray, latencies: np.ndarray,
                accuracies: Optional[np.ndarray] = None,
                extras: Sequence = ()) -> Dict[str, Dict[str, float]]:
    """The one group-by-attainment aggregation behind every
    `per_regime` / `per_device` / `per_mode` / `per_tenant`: bucket
    requests by an (N,) integer index, report share / served /
    attainment / mean latency (+ accuracy when recorded) per named
    bucket. `extras` adds ``(label, (N,) array)`` mean columns; a None
    array is skipped. NaN accuracies (requests with no recorded score)
    are excluded from the bucket mean; an all-NaN bucket omits the key.
    """
    index = np.asarray(index)
    violations = np.asarray(violations)
    latencies = np.asarray(latencies)
    out: Dict[str, Dict[str, float]] = {}
    for k, name in enumerate(names):
        mask = index == k
        if not mask.any():
            continue
        d = {
            "share": float(mask.mean()),
            "served": int(mask.sum()),
            "attainment": float(1.0 - violations[mask].mean()),
            "mean_latency": float(latencies[mask].mean()),
        }
        if accuracies is not None:
            a = np.asarray(accuracies, float)[mask]
            a = a[~np.isnan(a)]
            if a.size:
                d["accuracy"] = float(a.mean())
        for label, arr in extras:
            if arr is not None:
                d[label] = float(np.asarray(arr)[mask].mean())
        out[name] = d
    return out


def _warn(name: str, repl: str):
    warnings.warn(
        f"ServingMetrics.{name} is deprecated; use {repl}",
        DeprecationWarning, stacklevel=3)


@dataclass
class ServingMetrics:
    """Per-request outcome ledger shared by every `ServingStack`.

    One dict per served request: rid, model, queue_ms, exec_ms, e2e_ms,
    device, mode, ok, tenant, accuracy, fallback, hedged, replica.
    """

    records: List[dict] = field(default_factory=list)

    # -- recording ----------------------------------------------------
    def add(self, req: Request, model: str, queue_ms: float = 0.0,
            exec_ms: float = 0.0, mode: Optional[str] = None, *,
            e2e_ms: Optional[float] = None, ok: Optional[bool] = None,
            t_sla: Optional[float] = None,
            accuracy: Optional[float] = None,
            tenant: Optional[str] = None, fallback: bool = False,
            hedged: bool = False, replica: Optional[int] = None):
        """Record one served request. E2E defaults to the paper's
        ``2·T_input + queue + exec`` decomposition; the SLA verdict to
        ``e2e <= t_sla`` against the request's own SLA (``sla_ms == 0``
        means "no SLA": reported met). Explicit `e2e_ms`/`ok` override
        both (on-device advisories skip the upload legs entirely)."""
        if e2e_ms is None:
            e2e_ms = 2 * req.t_input_ms + queue_ms + exec_ms
        if t_sla is None:
            t_sla = req.sla_ms
        if ok is None:
            ok = (e2e_ms <= t_sla) if t_sla else True
        self.records.append({
            "rid": req.rid, "model": model, "queue_ms": queue_ms,
            "exec_ms": exec_ms, "e2e_ms": e2e_ms,
            "device": req.device_id, "mode": mode or "static",
            "ok": bool(ok),
            "tenant": tenant if tenant is not None
            else getattr(req, "tenant", None),
            "accuracy": accuracy, "fallback": bool(fallback),
            "hedged": bool(hedged), "replica": replica,
        })

    # -- scalar views -------------------------------------------------
    @property
    def served(self) -> int:
        return len(self.records)

    @property
    def violations(self) -> int:
        return sum(not r["ok"] for r in self.records)

    @property
    def attainment(self) -> float:
        return 1.0 - self.violations / max(self.served, 1)

    @property
    def fallbacks(self) -> int:
        return sum(r["fallback"] for r in self.records)

    @property
    def hedges(self) -> int:
        return sum(r["hedged"] for r in self.records)

    # -- aggregation --------------------------------------------------
    def _selection_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r["model"]] = out.get(r["model"], 0) + 1
        return out

    def _grouped(self, key: str) -> Dict[str, Dict[str, float]]:
        if not self.records:
            return {}
        names = sorted({r[key] or "<none>" for r in self.records})
        pos = {n: i for i, n in enumerate(names)}
        index = np.array([pos[r[key] or "<none>"] for r in self.records])
        accs = np.array([np.nan if r["accuracy"] is None
                         else r["accuracy"] for r in self.records])
        return group_stats(
            index, names,
            violations=np.array([not r["ok"] for r in self.records],
                                float),
            latencies=np.array([r["e2e_ms"] for r in self.records]),
            accuracies=None if np.isnan(accs).all() else accs,
            extras=(
                ("mean_queue_ms",
                 np.array([r["queue_ms"] for r in self.records])),
                ("fallback_share",
                 np.array([r["fallback"] for r in self.records],
                          float))))

    def per_device(self) -> Dict[str, Dict[str, float]]:
        """Attainment / latency split by issuing device (fleet
        traffic; "<none>" buckets untagged requests)."""
        return self._grouped("device")

    def per_mode(self) -> Dict[str, Dict[str, float]]:
        """Attainment split by governing control mode (adaptive runs;
        one 'static' bucket otherwise)."""
        return self._grouped("mode")

    def per_tenant(self) -> Dict[str, Dict[str, float]]:
        """Attainment split by tenant (multi-tenant cluster runs)."""
        return self._grouped("tenant")

    def summary(self) -> dict:
        """The unified summary schema (module docstring)."""
        n = len(self.records)
        lat = (np.array([r["e2e_ms"] for r in self.records])
               if n else np.zeros(1))
        q = (np.array([r["queue_ms"] for r in self.records])
             if n else np.zeros(1))
        acc = [r["accuracy"] for r in self.records
               if r["accuracy"] is not None]
        out = {
            "served": n,
            "attainment": self.attainment,
            "accuracy": float(np.mean(acc)) if acc else 0.0,
            "mean_ms": float(lat.mean()),
            "p95_ms": float(np.percentile(lat, 95)),
            "mean_queue_ms": float(q.mean()),
            "p95_queue_ms": float(np.percentile(q, 95)),
            "selections": dict(sorted(self._selection_counts().items())),
        }
        if any(r["device"] is not None for r in self.records):
            out["by_device"] = self.per_device()
        if {r["mode"] for r in self.records} - {"static"}:
            out["by_mode"] = self.per_mode()
            out["fallbacks"] = self.fallbacks
        if any(r["tenant"] is not None for r in self.records):
            out["by_tenant"] = self.per_tenant()
        if self.hedges:
            out["hedges"] = self.hedges
        return out

    # -- deprecated pre-unification aliases ---------------------------
    @property
    def latencies_ms(self) -> List[float]:
        _warn("latencies_ms", "records[*]['e2e_ms']")
        return [r["e2e_ms"] for r in self.records]

    @property
    def accuracies(self) -> List[float]:
        _warn("accuracies", "records[*]['accuracy']")
        return [r["accuracy"] for r in self.records
                if r["accuracy"] is not None]

    @property
    def selections(self) -> Dict[str, int]:
        _warn("selections", "summary()['selections']")
        return self._selection_counts()

    @property
    def by_device(self) -> Dict[str, List[int]]:
        _warn("by_device", "per_device()")
        out: Dict[str, List[int]] = {}
        for r in self.records:
            e = out.setdefault(r["device"] or "<none>", [0, 0])
            e[0] += 1
            e[1] += int(not r["ok"])
        return out

    @property
    def by_mode(self) -> Dict[str, int]:
        _warn("by_mode", "per_mode()")
        out: Dict[str, int] = {}
        for r in self.records:
            out[r["mode"]] = out.get(r["mode"], 0) + 1
        return out
