"""Continuous-batching serving loop: ContinuousBatcher x InferenceEngine
with per-request SLA accounting and CNNSelect at admission.

The paper's observation that throughput-batching "may increase waiting
time of some requests" becomes measurable here: `ServingLoop.run`
processes an arrival trace and reports queue wait vs execution time per
request. Admission goes through the shared `Router`: the whole trace is
routed in one vectorized `route_batch` call (the jit'd cnnselect_batch
path) and lands in the per-model `ContinuousBatcher`s the router owns
as its queues — batching and selection compose (beyond-paper: the
paper serves batch-of-one). Mid-group, freed slots are backfilled with
queued arrivals via `InferenceEngine.prefill_row` (true continuous
batching), and each measured per-request exec_ms feeds
`ControlPlane.observe_outcome` so the online profiles track this
host's executed latencies."""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.selection import ModelProfile
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.control import ControlPlane
from repro.serving.engine import InferenceEngine
from repro.serving.metrics import ServingMetrics
from repro.serving.router import Router
from repro.serving.stack import StackOutcome


class LoopMetrics(ServingMetrics):
    """The loop's ledger — now the unified `ServingMetrics` schema
    (serving/metrics.py); kept as a named subclass for imports. The
    pre-unification ``mean_e2e_ms``/``p95_e2e_ms`` summary keys are now
    ``mean_ms``/``p95_ms`` (migration note in CHANGES.md)."""


class ServingLoop:
    """Drives engines through a request trace in virtual time.

    engines: {name: InferenceEngine}. The loop seeds an aligned group
    per model, then runs decode rounds with slot-level joins: a member
    retiring early frees its slot and the next queued arrival prefills
    into it mid-group (`InferenceEngine.prefill_row`) — the scheduler
    half of continuous batching (see DESIGN.md §14).

    profiles: a ModelProfile list, or the string ``"measured"`` to
    profile each engine on this host at construction (requires
    `accuracies={name: score}` for the selection objective)."""

    def __init__(self, engines: Dict[str, InferenceEngine],
                 profiles: Union[List[ModelProfile], str, None] = None,
                 t_threshold: float = 30.0, seed: int = 0,
                 policy="cnnselect", t_estimator=None, controller=None,
                 accuracies: Optional[Dict[str, float]] = None):
        self.engines = engines
        some = next(iter(engines.values()))
        self.batchers = {
            name: ContinuousBatcher(eng.batch_size,
                                    prompt_len=some.max_seq // 4)
            for name, eng in engines.items()}
        if isinstance(profiles, str):
            if profiles != "measured":
                raise ValueError(f"unknown profiles source {profiles!r}; "
                                 f"pass a list or 'measured'")
            if accuracies is None:
                raise ValueError("profiles='measured' needs accuracies="
                                 "{name: score}")
            prompt_len = some.max_seq // 4
            profiles = []
            for name, eng in engines.items():
                cold_s = eng.warmup(prompt_len)
                p = eng.measured_profile(prompt_len, n_tokens=4)
                profiles.append(ModelProfile(
                    name=name, accuracy=accuracies[name], mu=p["mu"],
                    sigma=max(p["sigma"], 1e-3), cold_mu=cold_s * 1000.0,
                    cold_sigma=100.0 * cold_s))
        if profiles is None or len(engines) == 1:
            # Single-engine loop: no selection, everything to one queue.
            self.router = None
            self.control = None
        else:
            # t_estimator: budget-side T_input source (DESIGN.md §9) —
            # None trusts each request's observed upload time; an
            # EstimatorBank keys estimation on each request's
            # `device_id` (fleet traces, DESIGN.md §10).
            self.router = Router(profiles, policy=policy,
                                 t_threshold=t_threshold, seed=seed,
                                 t_estimator=t_estimator)
            for name in self.router.order:
                self.router.attach_queue(name, self.batchers[name])
            # The shared per-request control step (DESIGN.md §12):
            # with a `controller` (CONTROLLER_SCENARIOS name or
            # AdaptiveController) admission adapts per request; without
            # one, admission stays the vectorized submit_many path.
            self.control = ControlPlane(self.router,
                                        controller=controller,
                                        seed=seed,
                                        t_threshold=t_threshold)
        self.metrics = LoopMetrics()
        self._req_modes: Dict[int, str] = {}
        # Optional trace capture (serving/trace.py, DESIGN.md §11):
        # `run` records each drained request with its SLA outcome.
        # Attach here, not to self.router — the router hook would
        # record the same request again at admission.
        self.recorder = None

    def run(self, requests: List[Request]) -> LoopMetrics:
        ordered = sorted(requests, key=lambda r: r.arrival)
        if self.router is not None and self.control.controller is None:
            # Vectorized admission: one chunked jit call for the trace.
            self.router.submit_many(ordered)
        else:
            # Per-request admission (single-engine, or adaptive — the
            # controller's decisions are inherently sequential).
            for req in ordered:
                self.submit(req)
        self.drain()
        return self.metrics

    # -- ServingStack (serving/stack.py, DESIGN.md §16) ---------------

    def submit(self, req: Request, *, now: float = 0.0) -> StackOutcome:
        """Protocol admission: route (through the shared control step
        when a controller is attached) and queue on the chosen model's
        batcher; execution and the metrics row land at `drain`."""
        if self.router is None:
            only = next(iter(self.engines))
            self.batchers[only].submit(req)
            return StackOutcome(model=only, pending=True,
                                tenant=req.tenant)
        if self.control.controller is None:
            d = self.router.submit(req, now=now)
            return StackOutcome(model=d.name, pending=True,
                                tenant=req.tenant)
        # Adaptive: detect -> maybe switch mode -> estimate -> select.
        d = self.control.step(req.sla_ms or 1e9, req.t_input_ms,
                              device_id=req.device_id)
        self._req_modes[req.rid] = d.mode
        self.router.submit(req, name=d.name)
        return StackOutcome(model=d.name, mode=d.mode, pending=True,
                            tenant=req.tenant)

    def drain(self) -> None:
        """Drain each model's queue in arrival order (virtual clock per
        model; engines measure real exec time on this host)."""
        for name, batcher in self.batchers.items():
            self._drain(name, batcher)

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        if self.control is not None:
            self.control.observe_outcome(name, latency_ms, cold=cold,
                                         now=now)

    def _finish(self, r: Request, name: str, exec_ms: float):
        """Per-request completion: metrics row, online profile feedback,
        trace capture — with the request's OWN measured exec_ms, not a
        group-shared wall time."""
        queue_ms = max(0.0, r.start_exec - r.arrival)
        self.metrics.add(r, name, queue_ms, exec_ms,
                         mode=self._req_modes.get(r.rid))
        if self.control is not None:
            self.control.observe_outcome(name, exec_ms)
        if self.recorder is not None:
            # sla_ms=0 means "no SLA": the outcome is unknown, not met
            # (metrics report ok=True for convenience, but a capture
            # must not fabricate attainment).
            self.recorder.record_request(
                r, model=name, exec_ms=exec_ms,
                sla_ok=(self.metrics.records[-1]["ok"]
                        if r.sla_ms else None))

    def _drain(self, name: str, batcher: ContinuousBatcher):
        eng = self.engines[name]
        now = 0.0
        # rid -> exec ms accumulated while the request occupied a slot.
        # Every engine call's wall time is charged to the requests that
        # were resident during it (aligned decode: they all stall
        # together), so per-request exec_ms is honest under backfill.
        acc: Dict[int, float] = {}
        n_done = len(batcher.done)     # done entries from previous runs
        logits = None
        while batcher.has_work:
            if batcher.n_active == 0:
                # Engine idle: advance the clock to the next arrival and
                # seed a fresh group.
                if not batcher.queue:
                    break
                now = max(now, batcher.queue[0].arrival)
                group = batcher.form_group(now)
                if group is None:
                    break
                t0 = time.perf_counter()
                logits = eng.run_prefill(batcher.pad_prompts(),
                                         lengths=batcher.prompt_lengths())
                dt = (time.perf_counter() - t0) * 1000.0
                now += dt
                for r in group:
                    acc[r.rid] = dt
            # One aligned decode round: sample, record/retire, backfill
            # freed slots, then step the whole group.
            nxt = logits.argmax(-1).astype(np.int32)
            batcher.record_tokens(nxt, now)
            while n_done < len(batcher.done):
                r = batcher.done[n_done]
                self._finish(r, name, acc.pop(r.rid, 0.0))
                n_done += 1
            if batcher.n_active == 0:
                continue            # drained; next iteration reseeds
            if eng._backfillable:
                for slot, r in batcher.backfill(now, eng.free_context):
                    prompt = np.zeros(batcher.prompt_len, np.int32)
                    p = r.prompt[-batcher.prompt_len:]
                    prompt[len(prompt) - len(p):] = p
                    t0 = time.perf_counter()
                    tok = int(eng.prefill_row(prompt, slot, length=len(p))
                              .argmax(-1))
                    dt = (time.perf_counter() - t0) * 1000.0
                    now += dt
                    # The whole group stalls for the row prefill.
                    for rr in batcher.slots:
                        if rr is not None:
                            acc[rr.rid] = acc.get(rr.rid, 0.0) + dt
                    nxt[slot] = tok
                    batcher.record_token(slot, tok, now)
                    while n_done < len(batcher.done):
                        done_r = batcher.done[n_done]
                        self._finish(done_r, name,
                                     acc.pop(done_r.rid, 0.0))
                        n_done += 1
            if batcher.n_active == 0:
                continue
            t0 = time.perf_counter()
            logits = eng.run_decode(nxt[:, None])
            dt = (time.perf_counter() - t0) * 1000.0
            now += dt
            for rr in batcher.slots:
                if rr is not None:
                    acc[rr.rid] = acc.get(rr.rid, 0.0) + dt
