"""Continuous-batching serving loop: ContinuousBatcher x InferenceEngine
with per-request SLA accounting and CNNSelect at admission.

The paper's observation that throughput-batching "may increase waiting
time of some requests" becomes measurable here: `ServingLoop.run`
processes an arrival trace and reports queue wait vs execution time per
request. Admission goes through the shared `Router`: the whole trace is
routed in one vectorized `route_batch` call (the jit'd cnnselect_batch
path) and lands in the per-model `ContinuousBatcher`s the router owns
as its queues — batching and selection compose (beyond-paper: the
paper serves batch-of-one)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.selection import ModelProfile
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.control import ControlPlane
from repro.serving.engine import InferenceEngine
from repro.serving.router import Router


@dataclass
class LoopMetrics:
    records: List[dict] = field(default_factory=list)

    def add(self, req: Request, model: str, queue_ms: float,
            exec_ms: float, mode: Optional[str] = None):
        e2e = 2 * req.t_input_ms + queue_ms + exec_ms
        self.records.append({
            "rid": req.rid, "model": model, "queue_ms": queue_ms,
            "exec_ms": exec_ms, "e2e_ms": e2e,
            "device": req.device_id, "mode": mode or "static",
            "ok": (e2e <= req.sla_ms) if req.sla_ms else True,
        })

    def summary(self) -> dict:
        if not self.records:
            return {}
        q = np.array([r["queue_ms"] for r in self.records])
        e = np.array([r["e2e_ms"] for r in self.records])
        return {
            "served": len(self.records),
            "attainment": float(np.mean([r["ok"] for r in self.records])),
            "mean_queue_ms": float(q.mean()),
            "p95_queue_ms": float(np.percentile(q, 95)),
            "mean_e2e_ms": float(e.mean()),
            "p95_e2e_ms": float(np.percentile(e, 95)),
        }

    def _group_by(self, field_name: str) -> Dict[str, dict]:
        """Shared group-by-attainment aggregation over the records."""
        out: Dict[str, dict] = {}
        for key in sorted({r[field_name] or "<none>"
                           for r in self.records}):
            rs = [r for r in self.records
                  if (r[field_name] or "<none>") == key]
            out[key] = {
                "served": len(rs),
                "attainment": float(np.mean([r["ok"] for r in rs])),
                "mean_e2e_ms": float(np.mean([r["e2e_ms"] for r in rs])),
            }
        return out

    def per_device(self) -> Dict[str, dict]:
        """Attainment / queue split by issuing device (fleet traces)."""
        return self._group_by("device")

    def per_mode(self) -> Dict[str, dict]:
        """Attainment split by governing control mode (controller runs;
        one 'static' bucket otherwise)."""
        return self._group_by("mode")


class ServingLoop:
    """Drives engines through a request trace in virtual time.

    engines: {name: (InferenceEngine, accuracy)}. The loop forms aligned
    groups per model, prefills once per group, decodes until the group
    drains, then admits the next group — the scheduler half of
    continuous batching (slot-level join is bounded by the aligned-
    decode engine; see DESIGN.md)."""

    def __init__(self, engines: Dict[str, InferenceEngine],
                 profiles: Optional[List[ModelProfile]] = None,
                 t_threshold: float = 30.0, seed: int = 0,
                 policy="cnnselect", t_estimator=None, controller=None):
        self.engines = engines
        some = next(iter(engines.values()))
        self.batchers = {
            name: ContinuousBatcher(eng.batch_size,
                                    prompt_len=some.max_seq // 4)
            for name, eng in engines.items()}
        if profiles is None or len(engines) == 1:
            # Single-engine loop: no selection, everything to one queue.
            self.router = None
            self.control = None
        else:
            # t_estimator: budget-side T_input source (DESIGN.md §9) —
            # None trusts each request's observed upload time; an
            # EstimatorBank keys estimation on each request's
            # `device_id` (fleet traces, DESIGN.md §10).
            self.router = Router(profiles, policy=policy,
                                 t_threshold=t_threshold, seed=seed,
                                 t_estimator=t_estimator)
            for name in self.router.order:
                self.router.attach_queue(name, self.batchers[name])
            # The shared per-request control step (DESIGN.md §12):
            # with a `controller` (CONTROLLER_SCENARIOS name or
            # AdaptiveController) admission adapts per request; without
            # one, admission stays the vectorized submit_many path.
            self.control = ControlPlane(self.router,
                                        controller=controller,
                                        seed=seed,
                                        t_threshold=t_threshold)
        self.metrics = LoopMetrics()
        self._req_modes: Dict[int, str] = {}
        # Optional trace capture (serving/trace.py, DESIGN.md §11):
        # `run` records each drained request with its SLA outcome.
        # Attach here, not to self.router — the router hook would
        # record the same request again at admission.
        self.recorder = None

    def run(self, requests: List[Request]) -> LoopMetrics:
        ordered = sorted(requests, key=lambda r: r.arrival)
        if self.router is None:
            only = next(iter(self.engines))
            for req in ordered:
                self.batchers[only].submit(req)
        elif self.control.controller is None:
            # Vectorized admission: one chunked jit call for the trace.
            self.router.submit_many(ordered)
        else:
            # Adaptive admission: the shared per-request control step
            # (detect -> maybe switch mode -> estimate -> select), one
            # request at a time in arrival order — the controller's
            # decisions are inherently sequential.
            for req in ordered:
                d = self.control.step(req.sla_ms or 1e9,
                                      req.t_input_ms,
                                      device_id=req.device_id)
                self._req_modes[req.rid] = d.mode
                self.router.enqueue(req, d.name)
        now = 0.0
        # Drain each model's queue in arrival order (virtual clock per
        # model; engines measure real exec time on this host).
        import time
        for name, batcher in self.batchers.items():
            eng = self.engines[name]
            now = 0.0
            while batcher.has_work:
                # Advance the clock to the next arrival if idle.
                if batcher.n_active == 0 and batcher.queue:
                    now = max(now, batcher.queue[0].arrival)
                group = batcher.form_group(now)
                if group is None:
                    break
                t0 = time.perf_counter()
                prompts = batcher.pad_prompts()
                logits = eng.run_prefill(prompts)
                while batcher.n_active > 0:
                    nxt = logits.argmax(-1).astype(np.int32)
                    batcher.record_tokens(nxt, now)
                    if batcher.n_active == 0:
                        break
                    logits = eng.run_decode(nxt[:, None])
                exec_ms = (time.perf_counter() - t0) * 1000.0
                now += exec_ms
                for r in group:
                    queue_ms = max(0.0, r.start_exec - r.arrival)
                    self.metrics.add(r, name, queue_ms, exec_ms,
                                     mode=self._req_modes.get(r.rid))
                    if self.recorder is not None:
                        # sla_ms=0 means "no SLA": the outcome is
                        # unknown, not met (metrics report ok=True for
                        # convenience, but a capture must not fabricate
                        # attainment).
                        self.recorder.record_request(
                            r, model=name, exec_ms=exec_ms,
                            sla_ok=(self.metrics.records[-1]["ok"]
                                    if r.sla_ms else None))
        return self.metrics
