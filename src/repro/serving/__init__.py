"""Serving substrate: network models, the event-driven request simulator
(paper §5.2 simulations), the real CPU inference engine with KV-cache
management and continuous batching, and the CNNSelect-fronted server."""
