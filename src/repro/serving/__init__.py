"""Serving substrate: network models, the event-driven request simulator
(paper §5.2 simulations), the real CPU inference engine with KV-cache
management and continuous batching, and the CNNSelect-fronted server.

All three serving stacks (batch-of-one server, continuous-batching
loop, simulator) admit requests through one `Router` (router.py), which
owns the profile store, cold/warm zoo state, and per-model queues, and
resolves its selection policy by name from the `core.selection`
registry. See DESIGN.md §2–3."""

from repro.serving.control import (AdaptiveController, ControlDecision,
                                   ControlPlane, CusumDetector,
                                   PageHinkleyDetector, make_controller,
                                   make_detector)
from repro.serving.fleet import (DeviceProfile, EstimatorBank,
                                 FleetMixture, make_fleet)
from repro.serving.network import (MarkovProcess, NetworkProcess,
                                   StationaryProcess, TInputEstimator,
                                   TraceReplayProcess, make_estimator,
                                   make_network)
from repro.serving.router import RouteDecision, Router
from repro.serving.trace import (CapturedTraceProcess, Trace,
                                 TraceRecorder, load_capture,
                                 requests_from_trace)

__all__ = ["Router", "RouteDecision", "NetworkProcess",
           "StationaryProcess", "MarkovProcess", "TraceReplayProcess",
           "TInputEstimator", "make_network", "make_estimator",
           "DeviceProfile", "FleetMixture", "EstimatorBank", "make_fleet",
           "Trace", "TraceRecorder", "CapturedTraceProcess",
           "load_capture", "requests_from_trace", "ControlPlane",
           "ControlDecision", "AdaptiveController", "CusumDetector",
           "PageHinkleyDetector", "make_controller", "make_detector"]
