"""Serving substrate: network models, the event-driven request simulator
(paper §5.2 simulations), the real CPU inference engine with KV-cache
management and continuous batching, and the CNNSelect-fronted server.

All serving stacks (batch-of-one server, continuous-batching loop,
simulated replica, multi-tenant cluster) admit requests through one
`Router` (router.py), report through one `ServingMetrics` schema
(metrics.py), and expose one `ServingStack` protocol (stack.py) the
`Cluster` composes. See DESIGN.md §2–3 and §16."""

from repro.serving.cluster import (Cluster, ClusterPlacer, TenantSpec,
                                   make_tenant_workload, make_tenants)
from repro.serving.control import (AdaptiveController, ControlDecision,
                                   ControlPlane, CusumDetector,
                                   PageHinkleyDetector, make_controller,
                                   make_detector)
from repro.serving.fleet import (DeviceProfile, EstimatorBank,
                                 FleetMixture, make_fleet)
from repro.serving.metrics import ServingMetrics, group_stats
from repro.serving.network import (MarkovProcess, NetworkProcess,
                                   StationaryProcess, TInputEstimator,
                                   TraceReplayProcess, make_estimator,
                                   make_network)
from repro.serving.router import RouteDecision, Router
from repro.serving.stack import (ServingStack, SimReplicaStack,
                                 StackOutcome)
from repro.serving.trace import (CapturedTraceProcess, Trace,
                                 TraceRecorder, load_capture,
                                 requests_from_trace)

__all__ = ["Router", "RouteDecision", "NetworkProcess",
           "StationaryProcess", "MarkovProcess", "TraceReplayProcess",
           "TInputEstimator", "make_network", "make_estimator",
           "DeviceProfile", "FleetMixture", "EstimatorBank", "make_fleet",
           "Trace", "TraceRecorder", "CapturedTraceProcess",
           "load_capture", "requests_from_trace", "ControlPlane",
           "ControlDecision", "AdaptiveController", "CusumDetector",
           "PageHinkleyDetector", "make_controller", "make_detector",
           "ServingMetrics", "group_stats", "ServingStack",
           "StackOutcome", "SimReplicaStack", "Cluster", "ClusterPlacer",
           "TenantSpec", "make_tenants", "make_tenant_workload"]
