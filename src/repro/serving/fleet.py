"""Device fleet layer: per-device network processes, device-keyed
T_input estimation, and on-device fallback profiles.

The paper's measurement study (§4, Table 4) shows that *which device*
issues a request dominates end-to-end time: different radios (WiFi vs
LTE vs hotspot tails) and very different on-device capabilities (a
Pixel 2 runs MobileNetV1 in ~350 ms; a Nexus 5 takes ~9 s). The
pre-fleet simulator drew every request from one shared
`NetworkProcess`; here a `FleetMixture` tags each request with a
`device_id` and draws its T_input from *that device's* process, so the
serving stack can key estimation and budgeting per device:

- `DeviceProfile` — a device tier: its radio (a `NetworkProcess` spec,
  stationary or regime-switching) plus an optional on-device execution
  profile (mean/σ/accuracy of the model the device can run locally,
  paper Table 4) used for MDInference-style fallback.
- `FleetMixture` — weighted mixture over `DeviceProfile`s. Traces are
  drawn per device from independent child RNG streams (seeded up front
  from the caller's generator), so one device's draw sequence does not
  depend on another device's process — the per-device determinism the
  fleet tests pin.
- `EstimatorBank` — the `TInputEstimator` keyed per device: each
  device gets its own estimator instance (one device's outage cannot
  move another device's estimate), with an optional observation `lag`
  that feeds each device only its own stale observations. `lag=1` is
  ModiPick's (arXiv:1909.02053) client-side view: the budget is
  estimated on the device *before* upload, so the server-side estimate
  is one RTT behind — the freshest upload measurement has not arrived
  back yet.

Named fleets live in `configs/paper_zoo.DEVICE_TIERS` /
`FLEET_SCENARIOS` and resolve through `make_fleet`. See DESIGN.md §10.
"""

from __future__ import annotations

import copy
import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.paper_zoo import (DEVICE_TIERS, DEVICES, FLEET_SCENARIOS,
                                     TABLE5, lognormal_params)
from repro.serving.network import (MIN_T_INPUT_MS, NetworkProcess,
                                   TInputEstimator, estimator_factory,
                                   make_network)

# Table 4 reports on-device means without spread; mobile execution jitter
# is modeled as a fixed coefficient of variation around them.
ON_DEVICE_SIGMA_FRACTION = 0.08


@dataclass(frozen=True)
class DeviceProfile:
    """One device tier of the fleet.

    `network` is any `make_network` spec — a NETWORKS name, a
    NETWORK_SCENARIOS name, ``trace:<name>``, or a prebuilt process —
    so a tier can sit on a stationary radio or walk through outages.
    `on_device_ms == 0` means the device cannot run the model locally
    (no fallback; e.g. the paper's Nexus 5 at ~9 s is never viable).
    """

    device_id: str
    network: Union[str, NetworkProcess]
    weight: float = 1.0
    on_device_ms: float = 0.0          # 0 = no on-device capability
    on_device_sigma: float = 0.0
    on_device_accuracy: float = 0.0
    tier: str = ""                     # optional tier label for reporting


@dataclass
class FleetTrace:
    """One sampled fleet workload: per-request upload time, global
    regime id (device-prefixed names), and device index."""

    t_input: np.ndarray                # (N,) ms
    regime: np.ndarray                 # (N,) int64, global regime ids
    device_index: np.ndarray           # (N,) int64, index into the fleet
    regime_names: List[str]
    # Per-device id strings, or None when devices are identified by
    # their integer index alone (ArrayFleet populations — materializing
    # a million id strings would dwarf the trace itself).
    device_ids: Optional[List[str]] = None

    def device_keys(self) -> np.ndarray:
        """(N,) estimator-bank keys: device_id strings when the fleet
        names its devices, the integer device indices otherwise."""
        if self.device_ids is None:
            return self.device_index
        return np.asarray(self.device_ids, object)[self.device_index]


class FleetMixture:
    """Weighted mixture of devices, each with its own network process.

    `sample_trace` first draws one child seed per device (plus one for
    the assignment stream) from the caller's generator, then assigns
    each request a device i.i.d. by weight and fills that device's
    positions from its own process under its own child generator.
    Consequence: with a fixed seed, changing device B's *process* never
    changes device A's draw sequence (only the weights shift the
    request assignment) — pinned by tests/test_fleet.py.
    """

    def __init__(self, devices: Sequence[DeviceProfile], *,
                 name: str = "fleet"):
        devices = list(devices)
        if not devices:
            raise ValueError("fleet needs at least one device")
        ids = [d.device_id for d in devices]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate device ids in fleet: {ids}")
        if any(d.weight <= 0 for d in devices):
            raise ValueError("device weights must be positive")
        self.name = name
        self.devices = devices
        self.device_ids = ids
        self.processes = [make_network(d.network) for d in devices]
        w = np.array([d.weight for d in devices], np.float64)
        self.weights = w / w.sum()
        # Global regime ids: each device's local regimes occupy a
        # contiguous block, names prefixed with the device id.
        self._regime_offsets = np.cumsum(
            [0] + [len(p.regime_names()) for p in self.processes[:-1]])

    @property
    def mean(self) -> float:
        """Fleet-wide long-run mean T_input (weight-averaged)."""
        return float(sum(w * p.mean
                         for w, p in zip(self.weights, self.processes)))

    def priors(self) -> Dict[str, float]:
        """Per-device long-run mean T_input — the estimator-bank
        cold-start priors (what offline measurement would give)."""
        return {d.device_id: p.mean
                for d, p in zip(self.devices, self.processes)}

    def prior_array(self) -> np.ndarray:
        """`priors()` in device-index order — the (D,) array form the
        scan engine (and the simulator's per-request gather) consume."""
        return np.array([p.mean for p in self.processes], np.float64)

    def on_device_arrays(self):
        """``(od_ms, od_sigma, od_accuracy)`` each (D,) in device-index
        order — the fallback profiles as arrays."""
        return (np.array([d.on_device_ms for d in self.devices],
                         np.float64),
                np.array([d.on_device_sigma for d in self.devices],
                         np.float64),
                np.array([d.on_device_accuracy for d in self.devices],
                         np.float64))

    def regime_names(self) -> List[str]:
        return [f"{d.device_id}:{rn}"
                for d, p in zip(self.devices, self.processes)
                for rn in p.regime_names()]

    @classmethod
    def from_capture(cls, trace, *, mode: str = "loop",
                     name: Optional[str] = None,
                     profiles: Optional[Dict[str, DeviceProfile]] = None
                     ) -> "FleetMixture":
        """Reconstruct a fleet from a multi-device capture
        (`serving.trace.Trace`): each recorded device becomes a
        `DeviceProfile` whose radio replays that device's own captured
        T_input subsequence (a `CapturedTraceProcess`, regime ids
        preserved) and whose weight is its empirical request share — so
        recorded fleets replay through the device-keyed `EstimatorBank`
        path. Non-radio fields come from `profiles[device_id]` when
        given, else from `DEVICE_TIERS` when the device id names a
        tier (the `FLEET_SCENARIOS` default), else radio-only."""
        from repro.serving.trace import CapturedTraceProcess
        profiles = dict(profiles or {})
        devices = []
        for dev, idx in trace.per_device().items():
            sub_reg = trace.regime_id[idx]
            # Compact this device's regimes to a local numbering; the
            # mixture re-prefixes names, so strip an existing "dev:"
            # prefix (fleet-sourced captures) to avoid "mid:mid:lte".
            gids, local = np.unique(sub_reg, return_inverse=True)
            lnames = [trace.regime_names[g].removeprefix(f"{dev}:")
                      for g in gids]
            dev_id = dev or "<untagged>"
            proc = CapturedTraceProcess(
                trace.t_input_ms[idx], mode=mode, regimes=local,
                regime_names=lnames, name=f"capture:{dev_id}")
            weight = len(idx) / len(trace)
            # Overrides may be keyed by the raw captured id or the
            # visible one ("" is exposed as "<untagged>").
            base = profiles.get(dev) or profiles.get(dev_id)
            if base is not None:
                devices.append(dataclasses.replace(
                    base, device_id=dev_id, network=proc, weight=weight))
            elif dev in DEVICE_TIERS:
                devices.append(device_tier_profile(
                    dev, network=proc, weight=weight))
            else:
                devices.append(DeviceProfile(dev_id, proc, weight=weight))
        return cls(devices, name=name or f"capture:{trace.name}")

    def sample_trace(self, rng: np.random.Generator,
                     n: int = 1) -> FleetTrace:
        n = int(n)
        # Child seeds first: device d's stream is fixed by (caller rng
        # state, d) alone, independent of the other devices' processes.
        seeds = rng.integers(0, 2 ** 63 - 1, size=len(self.devices) + 1)
        assign = np.random.default_rng(seeds[-1]).choice(
            len(self.devices), size=n, p=self.weights)
        t = np.empty(n, np.float64)
        reg = np.empty(n, np.int64)
        for d, proc in enumerate(self.processes):
            mask = assign == d
            m = int(mask.sum())
            if m == 0:
                continue
            td, rd = proc.sample_trace(np.random.default_rng(seeds[d]), m)
            t[mask] = td
            reg[mask] = rd + self._regime_offsets[d]
        return FleetTrace(t, reg, assign.astype(np.int64),
                          self.regime_names(), list(self.device_ids))


class ArrayFleet:
    """Vectorized fleet for million-device populations (DESIGN.md §13).

    `FleetMixture` models a handful of *tiers* faithfully (independent
    child RNG streams, regime-switching radios) but draws each device's
    subsequence in a python loop — O(D) overhead that dominates at
    10^5+ devices. `ArrayFleet` trades radio fidelity for scale: every
    device sits on a *stationary* lognormal radio whose mean is its
    tier's long-run mean perturbed by a per-device lognormal jitter
    (devices within a tier are heterogeneous, so per-device estimation
    stays meaningful), and a whole trace is one vectorized lognormal
    draw. Devices are identified by their integer index; the fleet
    protocol (`prior_array` / `on_device_arrays` / `sample_trace` /
    `priors` / `mean`) matches `FleetMixture`, so both engines accept
    either class. Tier membership is deterministic (contiguous blocks
    proportional to `tier_weights`); the per-device jitter is fixed by
    `seed` at construction, so two fleets built with the same arguments
    are identical."""

    def __init__(self, n_devices: int, *,
                 tiers: Sequence[str] = ("flagship", "midrange",
                                         "budget"),
                 tier_weights: Optional[Sequence[float]] = None,
                 cv: float = 0.4, mean_jitter: float = 0.15,
                 seed: int = 0, name: str = "array_fleet"):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if cv <= 0 or mean_jitter < 0:
            raise ValueError("cv must be > 0 and mean_jitter >= 0")
        self.name = name
        self.n_devices = D = int(n_devices)
        self.tier_names = [str(t) for t in tiers]
        profs = [device_tier_profile(t) for t in self.tier_names]
        tier_mean = np.array([make_network(p.network).mean for p in profs])
        w = np.ones(len(profs)) if tier_weights is None else np.asarray(
            tier_weights, np.float64)
        if len(w) != len(profs) or (w <= 0).any():
            raise ValueError("tier_weights must be positive, one per tier")
        # Deterministic contiguous tier blocks, sized proportionally
        # (every tier gets at least one device when D allows).
        bounds = np.round(np.cumsum(w) / w.sum() * D).astype(np.int64)
        counts = np.diff(np.concatenate([[0], bounds]))
        self.tier_of = np.repeat(np.arange(len(profs)), counts)
        # Per-device radio: the tier mean times a unit-median lognormal
        # jitter, with the tier-level coefficient of variation.
        jit = np.random.default_rng(seed).lognormal(
            0.0, mean_jitter, D) if mean_jitter > 0 else np.ones(D)
        self.device_mean = tier_mean[self.tier_of] * jit
        self._mu, self._sigma = lognormal_params(
            self.device_mean, cv * self.device_mean)
        self._od = (
            np.array([p.on_device_ms for p in profs])[self.tier_of],
            np.array([p.on_device_sigma for p in profs])[self.tier_of],
            np.array([p.on_device_accuracy for p in profs])[self.tier_of])

    @property
    def mean(self) -> float:
        """Fleet-wide long-run mean T_input (devices equally likely)."""
        return float(self.device_mean.mean())

    def prior_array(self) -> np.ndarray:
        return self.device_mean.copy()

    def on_device_arrays(self):
        return self._od

    def priors(self) -> Dict[int, float]:
        """Dict form of `prior_array` (python-engine bank priors).
        O(D) — the scan engine uses `prior_array` directly."""
        return dict(enumerate(self.device_mean))

    def regime_names(self) -> List[str]:
        return list(self.tier_names)

    def sample_trace(self, rng: np.random.Generator,
                     n: int = 1) -> FleetTrace:
        n = int(n)
        dev = rng.integers(0, self.n_devices, size=n)
        t = np.maximum(rng.lognormal(self._mu[dev], self._sigma[dev]),
                       MIN_T_INPUT_MS)
        return FleetTrace(t, self.tier_of[dev], dev.astype(np.int64),
                          self.regime_names(), device_ids=None)


# --------------------------------------------------------------------------
# Per-device keyed estimation (the TInputEstimator bank)
# --------------------------------------------------------------------------

class EstimatorBank:
    """A keyed bank of `TInputEstimator`s: one independent estimator
    per device, created on first use from a shared spec (string spec or
    a prototype instance that is deep-copied per device).

    `lag` delays observation delivery: each device's estimator sees its
    own upload measurements only `lag` requests late. ``lag=0`` is the
    server-side view (the previous upload has been measured by the time
    the next request is admitted); ``lag=1`` is ModiPick's client-side
    (pre-upload) view — the device estimated its budget before
    uploading, so the freshest measurement is one RTT stale.

    The streaming protocol mirrors `TInputEstimator`:
    ``estimate(key, observed=...)`` then ``observe(key, t)`` per
    request, or the vectorized ``estimate_series(t_input, keys)`` over
    a whole trace — the two are agreement-tested. Under ``lag > 0`` the
    current observation is never consulted (it has not arrived), so
    cold estimators answer their prior; a prior is therefore required
    when ``lag > 0``.
    """

    def __init__(self, spec: Union[str, TInputEstimator] = "ewma:0.2", *,
                 priors: Optional[Dict] = None,
                 default_prior: Optional[float] = None, lag: int = 0):
        if isinstance(spec, EstimatorBank):
            raise ValueError("cannot nest EstimatorBanks")
        if isinstance(spec, str):
            # Parse ONCE: the bank instantiates estimators lazily (one
            # per device, on first use), and routing each cold start
            # back through the spec-string parser costs real time at
            # fleet scale. The factory closes over the parsed spec and
            # also front-loads the registry-style ValueError a bad spec
            # would otherwise raise mid-run.
            self._factory = estimator_factory(spec)
        elif not isinstance(spec, TInputEstimator):
            raise ValueError(f"EstimatorBank spec must be a "
                             f"TInputEstimator or a str, got "
                             f"{type(spec).__name__}")
        else:
            self._factory = None
        if lag < 0:
            raise ValueError(f"lag must be >= 0, got {lag}")
        if lag > 0 and (spec == "observed"
                        or getattr(spec, "name", None) == "observed"):
            # "observed" budgets from the *current* upload, which by
            # definition has not arrived under a stale view. The
            # last-known-observation equivalent is ewma:1.0.
            raise ValueError("'observed' estimator is undefined under "
                             "lag > 0; use 'ewma:1.0' (last known "
                             "observation) instead")
        self.spec = spec
        self.priors = dict(priors or {})
        self.default_prior = default_prior
        self.lag = int(lag)
        self._estimators: Dict[object, TInputEstimator] = {}
        self._pending: Dict[object, deque] = {}

    def keys(self):
        return list(self._estimators)

    def prior_for(self, key) -> Optional[float]:
        """The cold-start prior `key`'s estimator is (or would be)
        primed with — the device's long-run mean, the control plane's
        degradation reference."""
        return self.priors.get(key, self.default_prior)

    def estimator_for(self, key) -> TInputEstimator:
        est = self._estimators.get(key)
        if est is None:
            prior = self.priors.get(key, self.default_prior)
            if self._factory is not None:
                est = self._factory(prior=prior)
            else:
                est = copy.deepcopy(self.spec)
                if est.prior is None:
                    est.prior = prior
            if self.lag > 0 and est.prior is None:
                raise ValueError(
                    f"EstimatorBank(lag={self.lag}) needs a prior for "
                    f"device {key!r}: under a stale view a cold "
                    f"estimator has nothing else to answer")
            self._estimators[key] = est
            self._pending[key] = deque()
        return est

    def estimate(self, key, observed: Optional[float] = None) -> float:
        """Budget-side T_input for `key`'s current request. Under
        ``lag > 0`` the current observation is not consulted."""
        est = self.estimator_for(key)
        if self.lag > 0:
            return est.estimate()
        return est.estimate(observed=observed)

    def observe(self, key, t_input: float) -> None:
        """Record `key`'s measured upload; it reaches the estimator
        after `lag` further observations."""
        est = self.estimator_for(key)
        pend = self._pending[key]
        pend.append(float(t_input))
        while len(pend) > self.lag:
            est.observe(pend.popleft())

    def estimate_series(self, t_input, keys=None) -> np.ndarray:
        """Vectorized causal estimation over a whole trace: positions
        are grouped per key (order-preserving) and each device's
        subsequence runs through its own estimator's `estimate_series`,
        shifted by `lag`. Continues any streaming state (pending
        observations carry across calls)."""
        t_input = np.asarray(t_input, np.float64)
        n = len(t_input)
        if keys is None:
            keys = [None] * n
        if len(keys) != n:
            raise ValueError(f"{n} observations but {len(keys)} keys")
        groups: Dict[object, list] = {}
        for i, k in enumerate(keys):
            groups.setdefault(k, []).append(i)
        out = np.empty(n, np.float64)
        for k, pos_list in groups.items():
            pos = np.asarray(pos_list, np.intp)
            out[pos] = self._series_for(k, t_input[pos])
        return out

    def _series_for(self, key, xs: np.ndarray) -> np.ndarray:
        est = self.estimator_for(key)
        if self.lag == 0:
            return est.estimate_series(xs)
        pend = self._pending[key]
        p0, m = len(pend), len(xs)
        combined = np.concatenate([np.asarray(pend, np.float64), xs])
        # At request i the device has pushed p0+i observations, of
        # which max(0, p0+i-lag) have arrived at the estimator.
        feed_n = max(0, p0 + m - self.lag)
        if feed_n == 0:
            out = np.full(m, est.estimate())
        else:
            # vals[k] = estimate from the state after k arrivals within
            # this call (the k=0 cold start answers the required
            # prior); request i has seen max(0, p0+i-lag) of them,
            # which is always < feed_n.
            vals = est.estimate_series(combined[:feed_n])
            out = vals[np.maximum(0, p0 + np.arange(m) - self.lag)]
        self._pending[key] = deque(combined[feed_n:])
        return out


# --------------------------------------------------------------------------
# Named fleets (paper Table 4 tiers; configs/paper_zoo data)
# --------------------------------------------------------------------------

def device_tier_profile(tier: str, *, device_id: Optional[str] = None,
                        weight: float = 1.0,
                        network: Union[str, NetworkProcess, None] = None
                        ) -> DeviceProfile:
    """Build a `DeviceProfile` from a `configs/paper_zoo.DEVICE_TIERS`
    entry: the tier's radio (overridable, e.g. to put the midrange tier
    on the `lte_outages` scenario) and its on-device profile resolved
    from the paper's Table 4 measurements + Table 5 accuracy."""
    if tier not in DEVICE_TIERS:
        raise ValueError(f"unknown device tier {tier!r}; known: "
                         f"{sorted(DEVICE_TIERS)}")
    d = DEVICE_TIERS[tier]
    od_ms = od_sigma = od_acc = 0.0
    if d.get("on_device") is not None:
        dev_name, model = d["on_device"]
        od_ms = float(DEVICES[dev_name][model])
        od_sigma = ON_DEVICE_SIGMA_FRACTION * od_ms
        od_acc = TABLE5[model][0] / 100.0
    return DeviceProfile(
        device_id=device_id or tier,
        network=network if network is not None else d["network"],
        weight=weight, on_device_ms=od_ms, on_device_sigma=od_sigma,
        on_device_accuracy=od_acc, tier=tier)


# `array:<n>[:<seed>]` fleets are cached per spec string: tenant
# helpers (workload gen, priors, on-device tables) each re-resolve the
# spec, and rebuilding a million-device ArrayFleet per call would
# dominate. Safe to share — ArrayFleet is immutable after construction
# (sampling uses the caller's generator).
_ARRAY_FLEET_CACHE: Dict[str, "ArrayFleet"] = {}


def make_fleet(spec: Union[str, FleetMixture, "ArrayFleet", None]
               ) -> Union[FleetMixture, "ArrayFleet", None]:
    """Resolve a fleet spec: a `FleetMixture` or `ArrayFleet` passes
    through, ``"array:<n>[:<seed>]"`` builds (and caches) an
    `ArrayFleet` of n devices, any other string names a
    `configs/paper_zoo.FLEET_SCENARIOS` entry, None -> None (single
    shared process — the pre-fleet default path)."""
    if spec is None or isinstance(spec, (FleetMixture, ArrayFleet)):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"fleet spec must be a FleetMixture or a str, "
                         f"got {type(spec).__name__}")
    if spec.startswith("array:"):
        fleet = _ARRAY_FLEET_CACHE.get(spec)
        if fleet is None:
            parts = spec.split(":")
            if len(parts) not in (2, 3):
                raise ValueError(f"bad array fleet spec {spec!r}; "
                                 f"expected 'array:<n>[:<seed>]'")
            seed = int(parts[2]) if len(parts) == 3 else 0
            fleet = ArrayFleet(int(parts[1]), seed=seed, name=spec)
            _ARRAY_FLEET_CACHE[spec] = fleet
        return fleet
    if spec not in FLEET_SCENARIOS:
        raise ValueError(f"unknown fleet {spec!r}; known: "
                         f"{sorted(FLEET_SCENARIOS)}")
    devices = [device_tier_profile(e["tier"],
                                   device_id=e.get("device_id"),
                                   weight=e.get("weight", 1.0),
                                   network=e.get("network"))
               for e in FLEET_SCENARIOS[spec]]
    return FleetMixture(devices, name=spec)
