"""Vectorized simulation engine: the whole control plane as one
jit-compiled `lax.scan` over array-resident per-device state
(DESIGN.md §13).

The python engine replays the control plane per request — estimator
banks as dicts of objects, detectors as scalar accumulators, a python
loop over the trace. That is faithful but O(N) python-interpreter work;
at a million devices x ten million requests it is hours. This module
re-expresses the *same* math as a fixed-size array program:

**Column layout.** Requests are packed into an ``(L, D)`` matrix — one
column per device, row ``k`` holding each device's k-th request
(``L = max requests per device``; absent cells masked by ``valid``).
One `lax.scan` walks the L rows carrying ``(D,)`` state vectors
(estimator state, change-point statistics, controller mode / cooldown /
reference level) updated **elementwise** under the row's valid mask.
No per-device gather/scatter ever happens — XLA:CPU does not alias
scan-carry buffers for scatters, so the obvious one-step-per-request
formulation degrades to O(N*D); the column program is O(L*D) = O(N)
with pure vector ops. Per-device state evolution is independent across
devices, so row-major processing is equivalent to arrival order; event
records carry the original request index and are re-sorted afterwards.

**Exactness.** Every update mirrors the python classes op-for-op in
float64 (EWMA recurrence, numpy-interpolation percentile over a ring
buffer, CUSUM / Page-Hinkley with the shared self-normalizing scale,
the controller's cooldown/re-anchor walk), so selections, modes, and
switch events reproduce the python engine exactly; budget estimates
agree to the ULP-level tolerance the estimator-series tests already
grant the blocked closed forms. Selection, hedging masks, fallback
draws, and the RNG consumption order are *shared* with the python
engine (`ControlPlane.finish_static` / `finish_adaptive`), not
re-implemented.

**Sharding.** All ops are elementwise across the device axis, so the
fleet shards trivially: `shards=S` pads D to a multiple of S and wraps
the program in `repro.utils.shard_map` over an S-device mesh — bitwise
identical to the unsharded run. CPU CI gets its mesh from
`repro.utils.config.configure(host_devices=N)`.
"""

from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional

import numpy as np

from repro.serving.control import (CusumDetector, PageHinkleyDetector)
from repro.serving.fleet import EstimatorBank
from repro.serving.network import (EWMAEstimator, MeanEstimator,
                                   ObservedEstimator, PercentileEstimator)

_DEFAULT_PARAM = {"ewma": 0.2, "pctl": 90.0}


class BankDesc(NamedTuple):
    """Static description of one estimator bank — everything the array
    program needs, hashable for the compile cache."""

    kind: str                # observed | mean | ewma | pctl
    param: float             # ewma alpha / pctl q (0.0 otherwise)
    window: int              # pctl ring size (0 otherwise)
    lag: int
    prior_override: Optional[float] = None   # instance-level prior


class CtrlDesc(NamedTuple):
    """Static description of an `AdaptiveController` for the array
    program: monitor bank, detector parameters, mode-walk constants."""

    monitor: BankDesc
    det_kind: str            # cusum | ph
    threshold: float
    drift: float             # cusum drift / ph delta
    fixed_scale: Optional[float]
    scale_beta: float
    min_scale: float
    n_modes: int
    start: int
    cooldown: int
    scale_frac: float
    table: tuple             # per-mode-spec BankDescs (None = identity)


# --------------------------------------------------------------------------
# Descriptor extraction (python objects -> static descs)
# --------------------------------------------------------------------------

def _desc_from_spec(spec: str, lag: int) -> BankDesc:
    head, _, arg = spec.partition(":")
    param = float(arg) if arg else _DEFAULT_PARAM.get(head, 0.0)
    window = 64 if head == "pctl" else 0
    return BankDesc(head, param, window, int(lag))


def _desc_from_instance(est, lag: int) -> BankDesc:
    """Translate a prebuilt estimator instance. Only cold instances
    translate — a warm one carries python-side state the array program
    does not ingest."""
    if type(est) is ObservedEstimator:
        kind, param, window, cold = "observed", 0.0, 0, True
    elif type(est) is MeanEstimator:
        kind, param, window, cold = "mean", 0.0, 0, True
    elif type(est) is EWMAEstimator:
        kind, param, window = "ewma", est.alpha, 0
        cold = est._est is None
    elif type(est) is PercentileEstimator:
        kind, param, window = "pctl", est.q, est.window
        cold = not est._buf
    else:
        raise ValueError(
            f"engine='scan' cannot translate a custom estimator "
            f"({type(est).__name__}); use a registry spec string or "
            f"engine='python'")
    if not cold:
        raise ValueError(
            f"engine='scan' needs a cold estimator instance; this "
            f"{kind} estimator already holds observations")
    prior = None if est.prior is None else float(est.prior)
    return BankDesc(kind, param, window, int(lag), prior_override=prior)


def _static_desc(plane) -> Optional[BankDesc]:
    """The static path's budget estimator as a BankDesc (None =
    identity: budget from the observed upload time)."""
    est = plane.router.t_estimator
    if est is None:
        return None
    if isinstance(est, EstimatorBank):
        if isinstance(est.spec, str):
            return _desc_from_spec(est.spec, est.lag)
        return _desc_from_instance(est.spec, est.lag)
    return _desc_from_instance(est, 0)


def ctrl_desc_from_controller(ctrl, *, lag: int = 0,
                              table_specs=None) -> CtrlDesc:
    """Translate an `AdaptiveController` into the column program's
    `CtrlDesc`. Shared with the cluster engine
    (serving/cluster_engine.py), which runs the same controller kernel
    without a `ControlPlane` around it: the cluster only consumes the
    mode / switch-event outputs, so it passes ``table_specs=(None,)``
    to keep the per-mode estimator lanes trivial."""
    det = ctrl._detector_template
    if type(det) is CusumDetector:
        kind, drift = "cusum", det.drift
    elif type(det) is PageHinkleyDetector:
        kind, drift = "ph", det.delta
    else:
        raise ValueError(
            f"engine='scan' cannot translate a custom detector "
            f"({type(det).__name__}); use 'cusum'/'ph' or "
            f"engine='python'")
    if det.statistic != 0.0:
        raise ValueError("engine='scan' needs a pristine detector "
                         "template (statistic != 0)")
    specs = (tuple(table_specs) if table_specs is not None else
             tuple(dict.fromkeys(m.t_estimator for m in ctrl.modes)))
    table = tuple(
        None if spec is None else _desc_from_spec(spec, lag)
        for spec in specs)
    return CtrlDesc(
        monitor=_desc_from_spec(ctrl.monitor, 0), det_kind=kind,
        threshold=det.threshold, drift=drift,
        fixed_scale=det.fixed_scale, scale_beta=det.scale_beta,
        min_scale=det.min_scale, n_modes=len(ctrl.modes),
        start=ctrl.start, cooldown=ctrl.cooldown,
        scale_frac=ctrl.scale_frac, table=table)


def _ctrl_desc(plane) -> CtrlDesc:
    return ctrl_desc_from_controller(plane.controller, lag=plane.lag)


# --------------------------------------------------------------------------
# Column packing: (N,) request stream -> (L, D) per-device columns
# --------------------------------------------------------------------------

class _Packed(NamedTuple):
    t_mat: np.ndarray        # (L, D) f64, 0 in absent cells
    valid: np.ndarray        # (L, D) bool
    order: np.ndarray        # (N,) request indices in (device, k) order
    k_s: np.ndarray          # (N,) row of request order[j]
    dev_s: np.ndarray        # (N,) column of request order[j]
    r_idx: np.ndarray        # (L, D) original request index (-1 absent)


def _pack_columns(t: np.ndarray, dev: np.ndarray, D: int) -> _Packed:
    n = len(t)
    counts = np.bincount(dev, minlength=D)
    L = int(counts.max()) if n else 0
    order = np.argsort(dev, kind="stable")    # device-major, arrival-
    dev_s = dev[order]                        # ordered within device
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    k_s = np.arange(n) - starts[dev_s]
    t_mat = np.zeros((L, D))
    valid = np.zeros((L, D), bool)
    r_idx = np.full((L, D), -1, np.int64)
    t_mat[k_s, dev_s] = t[order]
    valid[k_s, dev_s] = True
    r_idx[k_s, dev_s] = order
    return _Packed(t_mat, valid, order, k_s, dev_s, r_idx)


def _unpack(p: _Packed, mat, dtype=np.float64) -> np.ndarray:
    out = np.empty(len(p.order), dtype)
    out[p.order] = np.asarray(mat)[p.k_s, p.dev_s]
    return out


# --------------------------------------------------------------------------
# The array program (built lazily so jax imports stay off the cold path)
# --------------------------------------------------------------------------

def _topm_size(q: float, n_rows: int, cap: int = 8):
    """How deep below the maximum a q-th percentile read can reach when
    at most `n_rows` values are ever seen: ranks lo/hi stay within the
    top `(n_rows-1) - floor(q/100*(n_rows-1)) + 1` order statistics.
    Returns that depth when it is small enough to keep as explicit
    (D,)-vector state, else None."""
    if q < 50.0:
        return None
    m = (n_rows - 1) - math.floor((q / 100.0) * (n_rows - 1)) + 1
    return m if m <= cap else None


def _unfused(prod, jnp):
    """Round a product separately before it feeds an add, so the XLA
    CPU backend cannot contract ``a*b + c`` into one fused
    multiply-add — python/numpy round the product and the sum
    separately, and engine parity here is bitwise.

    The guard is ``where(prod == prod, prod, 0.0)``: NaN semantics
    keep the compiler from proving the predicate true, and a select
    (unlike optimization_barrier or a bitcast round-trip, both erased
    before LLVM's contraction pass) survives to codegen. Two guarded
    products may feed one add — the select-merge rule only fuses
    selects sharing a predicate, and each guard's predicate is its own
    product. Only ever wrap products that are finite on lanes whose
    value is used (a used-NaN lane would turn into 0.0)."""
    return jnp.where(prod == prod, prod, 0.0)


def _core_init(desc: BankDesc, D: int, jnp, n_rows=None):
    if desc.kind == "ewma":
        return {"est": jnp.zeros(D), "seen": jnp.zeros(D, bool)}
    if desc.kind == "pctl":
        # Three layouts, specialized at trace time (n_rows = scan
        # length, static):
        #  - `top`: at most `n_rows` <= window values ever arrive AND
        #    the percentile only reads the top few order statistics —
        #    keep just those, maintained by an O(m) min/max chain of
        #    (D,) ops.
        #  - `sbuf` alone: ring never rolls (n_rows <= window) — the
        #    sorted multiset, pure insertion, no eviction bookkeeping.
        #  - `sbuf` + `buf`: general rolling window; `buf` keeps
        #    insertion order so the evicted value can be found.
        # A comparator sort per scan row is the dominant cost at fleet
        # scale, incremental maintenance is not.  +inf padding sorts
        # last, so the first `cnt` entries are real.
        if n_rows is not None and n_rows <= desc.window:
            m = _topm_size(desc.param, n_rows)
            if m is not None:
                return {"top": jnp.full((D, m), -jnp.inf),
                        "cnt": jnp.zeros(D, jnp.int32)}
            return {"sbuf": jnp.full((D, desc.window), jnp.inf),
                    "cnt": jnp.zeros(D, jnp.int32)}
        return {"buf": jnp.full((D, desc.window), jnp.inf),
                "sbuf": jnp.full((D, desc.window), jnp.inf),
                "cnt": jnp.zeros(D, jnp.int32)}
    return {}                                 # observed / mean: stateless


def _core_estimate(desc: BankDesc, st, priors, x, jnp):
    """The warm-state estimate with the cold-start chain
    state -> prior -> observation (`x=None` drops the last link — the
    lag>0 view, where the current upload has not arrived)."""
    fallback = priors if x is None else jnp.where(
        jnp.isnan(priors), x, priors)
    if desc.kind == "observed":
        return fallback if x is None else x
    if desc.kind == "mean":
        return priors
    if desc.kind == "ewma":
        return jnp.where(st["seen"], st["est"], fallback)
    # pctl: numpy-interpolation percentile read off the incrementally
    # maintained sorted state (no per-row sort).
    c = jnp.minimum(st["cnt"], desc.window).astype(jnp.float64)
    v = _unfused((desc.param / 100.0) * (c - 1.0), jnp)
    lo = jnp.clip(jnp.floor(v), 0).astype(jnp.int32)
    hi = jnp.clip(jnp.ceil(v), 0).astype(jnp.int32)
    g = v - jnp.floor(v)
    if "top" in st:
        # `top` is sorted descending: ascending rank k reads top[c-1-k].
        ci = jnp.minimum(st["cnt"], desc.window) - 1
        a = jnp.take_along_axis(st["top"], jnp.maximum(
            ci - lo, 0)[:, None], 1)[:, 0]
        b = jnp.take_along_axis(st["top"], jnp.maximum(
            ci - hi, 0)[:, None], 1)[:, 0]
    else:
        s = st["sbuf"]
        a = jnp.take_along_axis(s, lo[:, None], 1)[:, 0]
        b = jnp.take_along_axis(s, hi[:, None], 1)[:, 0]
    warm = jnp.where(
        g >= 0.5, b - _unfused((b - a) * (1.0 - g), jnp),
        a + _unfused((b - a) * g, jnp))
    return jnp.where(st["cnt"] > 0, warm, fallback)


def _core_observe(desc: BankDesc, st, x, mask, jnp):
    if desc.kind == "ewma":
        upd = jnp.where(
            st["seen"],
            _unfused((1.0 - desc.param) * st["est"], jnp)
            + _unfused(desc.param * x, jnp),
            x)
        return {"est": jnp.where(mask, upd, st["est"]),
                "seen": st["seen"] | mask}
    if desc.kind == "pctl":
        if "top" in st:
            # Bubble x down the descending top-m chain: 2m (D,) ops.
            cur = x
            cols = []
            for t in range(st["top"].shape[1]):
                col = st["top"][:, t]
                cols.append(jnp.maximum(col, cur))
                cur = jnp.minimum(col, cur)
            new_top = jnp.stack(cols, axis=1)
            return {"top": jnp.where(mask[:, None], new_top, st["top"]),
                    "cnt": st["cnt"] + mask}
        W = desc.window
        j = jnp.arange(W, dtype=jnp.int32)[None, :]
        s = st["sbuf"]
        if "buf" not in st:
            # Insert-only layout (ring never rolls): shift [i, W) right
            # by one and drop x in at its rank — the slot falling off
            # the end is still the +inf pad.
            i = jnp.sum(s < x[:, None], axis=1, dtype=jnp.int32)[:, None]
            left = jnp.concatenate([s[:, :1], s[:, :-1]], axis=1)
            new_s = jnp.where(j == i, x[:, None],
                              jnp.where(j > i, left, s))
            return {"sbuf": jnp.where(mask[:, None], new_s, s),
                    "cnt": st["cnt"] + mask}
        pos = st["cnt"] % W
        old = jnp.take_along_axis(st["buf"], pos[:, None], 1)[:, 0]
        hit = (j == pos[:, None]) & mask[:, None]
        # Sorted-buffer maintenance: drop the first occurrence of the
        # evicted value (index r — unfilled lanes evict the +inf pad),
        # insert x at its rank (i2, post-removal).  Every slot moves by
        # at most one position, so the update is selects over the two
        # shifted views — elementwise rank arithmetic, no comparator
        # sort and no gather.
        r = jnp.argmax(s == old[:, None], axis=1).astype(jnp.int32)[:, None]
        i = jnp.sum(s < x[:, None], axis=1, dtype=jnp.int32)[:, None]
        i2 = i - (r < i)
        left = jnp.concatenate([s[:, :1], s[:, :-1]], axis=1)
        right = jnp.concatenate([s[:, 1:], s[:, -1:]], axis=1)
        new_s = jnp.where(
            j == i2, x[:, None],
            jnp.where((r <= j) & (j < i2), right,
                      jnp.where((i2 < j) & (j <= r), left, s)))
        return {"buf": jnp.where(hit, x[:, None], st["buf"]),
                "sbuf": jnp.where(mask[:, None], new_s, s),
                "cnt": st["cnt"] + mask}
    return st


def _bank_init(desc: BankDesc, D: int, jnp, n_rows=None):
    st = {"core": _core_init(desc, D, jnp, n_rows)}
    if desc.lag > 0:
        st["pend"] = jnp.zeros((D, desc.lag))
        st["pcnt"] = jnp.zeros(D, jnp.int32)
    return st


def _bank_step(desc: BankDesc, st, x, valid, priors, jnp):
    """One request row through one bank: estimate (before this row's
    observation lands), then observe — through the lag ring when the
    bank serves a stale view."""
    if desc.lag == 0:
        est = _core_estimate(desc, st["core"], priors, x, jnp)
        return est, {"core": _core_observe(desc, st["core"], x, valid,
                                           jnp)}
    est = _core_estimate(desc, st["core"], priors, None, jnp)
    slot = st["pcnt"] % desc.lag
    old = jnp.take_along_axis(st["pend"], slot[:, None], 1)[:, 0]
    feed = valid & (st["pcnt"] >= desc.lag)
    core = _core_observe(desc, st["core"], old, feed, jnp)
    hit = (jnp.arange(desc.lag)[None, :] == slot[:, None]) \
        & valid[:, None]
    return est, {"core": core,
                 "pend": jnp.where(hit, x[:, None], st["pend"]),
                 "pcnt": st["pcnt"] + valid}


def _det_init(c: CtrlDesc, D: int, priors, jnp):
    st = {}
    if c.det_kind == "cusum":
        st["pos"] = jnp.zeros(D)
        st["neg"] = jnp.zeros(D)
    else:
        st["up"] = jnp.zeros(D)
        st["up_min"] = jnp.zeros(D)
        st["dn"] = jnp.zeros(D)
        st["dn_max"] = jnp.zeros(D)
    if c.fixed_scale is None:
        pre = c.scale_frac * jnp.abs(priors)
        st["sset"] = pre > 0
        st["scale"] = jnp.where(pre > 0,
                                jnp.maximum(pre, c.min_scale), 0.0)
    return st


def _det_step(c: CtrlDesc, st, r, s_obs, valid, jnp):
    """Standardize the residual, advance the two-sided statistic,
    return the (D,) alarm in {-1, 0, +1}. The statistic resets where it
    fires regardless of the controller's cooldown — exactly the python
    detectors, whose `update` self-resets."""
    st = dict(st)
    if c.fixed_scale is not None:
        z = r / c.fixed_scale
    else:
        cur = jnp.where(st["sset"], st["scale"],
                        jnp.maximum(s_obs, c.min_scale))
        z = r / cur
        new = jnp.maximum(
            _unfused((1.0 - c.scale_beta) * cur, jnp)
            + _unfused(c.scale_beta * s_obs, jnp),
            c.min_scale)
        st["scale"] = jnp.where(valid, new, st["scale"])
        st["sset"] = st["sset"] | valid
    if c.det_kind == "cusum":
        pos = jnp.maximum(0.0, st["pos"] + z - c.drift)
        neg = jnp.maximum(0.0, st["neg"] - z - c.drift)
        alarm = jnp.where(pos > c.threshold, 1,
                          jnp.where(neg > c.threshold, -1, 0))
        fired = valid & (alarm != 0)
        st["pos"] = jnp.where(valid,
                              jnp.where(fired, 0.0, pos), st["pos"])
        st["neg"] = jnp.where(valid,
                              jnp.where(fired, 0.0, neg), st["neg"])
    else:
        up = st["up"] + z - c.drift
        up_min = jnp.minimum(st["up_min"], up)
        dn = st["dn"] + z + c.drift
        dn_max = jnp.maximum(st["dn_max"], dn)
        alarm = jnp.where(up - up_min > c.threshold, 1,
                          jnp.where(dn_max - dn > c.threshold, -1, 0))
        fired = valid & (alarm != 0)
        for k, v in (("up", up), ("up_min", up_min), ("dn", dn),
                     ("dn_max", dn_max)):
            st[k] = jnp.where(valid, jnp.where(fired, 0.0, v), st[k])
    return jnp.where(valid, alarm, 0), st


_COMPILED: Dict[tuple, object] = {}


def _compile(static_desc, ctrl_desc, shards: int):
    """Build (and cache) the jitted ``run(t_mat, valid, priors)`` array
    program for one (estimator, controller, shards) configuration.
    Shapes recompile inside jax's own cache."""
    key = (static_desc, ctrl_desc, shards)
    fn = _COMPILED.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp
    from jax import lax

    def run(t_mat, valid, priors):
        L, D = t_mat.shape
        if ctrl_desc is None:
            bank0 = _bank_init(static_desc, D, jnp, L)

            def step(st, row):
                x, v = row
                est, st = _bank_step(static_desc, st, x, v, priors,
                                     jnp)
                return st, {"est": est}

            _, out = lax.scan(step, bank0, (t_mat, valid))
            return out

        c = ctrl_desc
        carry0 = {
            "mon": _bank_init(c.monitor, D, jnp, L),
            "det": _det_init(c, D, priors, jnp),
            "mode": jnp.full(D, c.start, jnp.int32),
            "cool": jnp.zeros(D, jnp.int32),
            "ref": priors + jnp.zeros(D),
            "banks": [None if d is None else _bank_init(d, D, jnp, L)
                      for d in c.table],
        }

        def step(st, row):
            x, v = row
            # Tracker: pre-observation prediction, observe, post level.
            pred, mon = _bank_step(c.monitor, st["mon"], x, v, priors,
                                   jnp)
            post = _core_estimate(c.monitor, mon["core"], priors, x,
                                  jnp)
            # Detect on (obs - reference); learn scale from the tracker
            # residual (process noise, not the offset being detected).
            alarm, det = _det_step(c, st["det"], x - st["ref"],
                                   jnp.abs(x - pred), v, jnp)
            in_cool = st["cool"] > 0
            cool = jnp.where(v & in_cool, st["cool"] - 1, st["cool"])
            eff = jnp.where(v & ~in_cool, alarm, 0)
            new_mode = jnp.clip(st["mode"] + jnp.sign(eff), 0,
                                c.n_modes - 1).astype(jnp.int32)
            switched = (eff != 0) & (new_mode != st["mode"])
            down_bottom = (eff < 0) & ~switched
            # int8 event outputs: mode indices and the alarm sign fit,
            # and the stacked (L, D) outputs are copy-bound at scale.
            out = {
                "switched": switched,
                "ev_from": st["mode"].astype(jnp.int8),
                "ev_to": new_mode.astype(jnp.int8),
                "ev_alarm": eff.astype(jnp.int8),
                "ev_ref": st["ref"], "ev_level": post,
            }
            mode = jnp.where(switched, new_mode, st["mode"])
            out["mode"] = mode.astype(jnp.int8)
            banks = []
            for i, d in enumerate(c.table):
                if d is None:
                    out[f"est{i}"] = x
                    banks.append(None)
                else:
                    est, b = _bank_step(d, st["banks"][i], x, v,
                                        priors, jnp)
                    out[f"est{i}"] = est
                    banks.append(b)
            return {"mon": mon, "det": det, "mode": mode,
                    "cool": jnp.where(switched, c.cooldown, cool),
                    "ref": jnp.where(switched | down_bottom, post,
                                     st["ref"]),
                    "banks": banks}, out

        _, out = lax.scan(step, carry0, (t_mat, valid))
        return out

    if shards > 1:
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.utils import shard_map
        devs = jax.devices()
        if len(devs) < shards:
            raise ValueError(
                f"shards={shards} but only {len(devs)} jax devices; "
                f"call repro.utils.config.configure(host_devices="
                f"{shards}) before jax initializes (CI sets "
                f"REPRO_HOST_DEVICES)")
        mesh = Mesh(np.array(devs[:shards]), ("fleet",))
        run = shard_map(run, mesh=mesh,
                        in_specs=(P(None, "fleet"), P(None, "fleet"),
                                  P("fleet")),
                        out_specs=P(None, "fleet"))
    fn = jax.jit(run)
    _COMPILED[key] = fn
    return fn


def _run_program(static_desc, ctrl_desc, packed: _Packed,
                 priors_vec: np.ndarray, shards: int):
    """Pad to the shard grid, run the jitted program under x64, strip
    the padding, and hand back numpy arrays."""
    from jax.experimental import enable_x64
    t_mat, valid = packed.t_mat, packed.valid
    D = t_mat.shape[1]
    pad = (-D) % shards
    if pad:
        t_mat = np.pad(t_mat, ((0, 0), (0, pad)))
        valid = np.pad(valid, ((0, 0), (0, pad)))
        priors_vec = np.pad(priors_vec, (0, pad), constant_values=1.0)
    for desc in ([static_desc] if ctrl_desc is None else
                 [ctrl_desc.monitor, *ctrl_desc.table]):
        if desc is not None and desc.kind == "mean" and np.isnan(
                priors_vec).any():
            raise ValueError("mean estimator needs a prior")
    fn = _compile(static_desc, ctrl_desc, shards)
    with enable_x64():
        out = fn(t_mat, valid, np.asarray(priors_vec, np.float64))
        out = {k: np.asarray(v)[:, :D] if pad else np.asarray(v)
               for k, v in out.items()}
    return out


# --------------------------------------------------------------------------
# Engine entry points (called from simulate())
# --------------------------------------------------------------------------

def _assemble_events(out, packed: _Packed, mode_names: List[str],
                     device_names, dev) -> List[dict]:
    """The (L, D) switch masks back into the python engine's
    chronological event-dict list."""
    ks, ds = np.nonzero(out["switched"] & packed.valid)
    if not len(ks):
        return []
    req = packed.r_idx[ks, ds]
    o = np.argsort(req, kind="stable")
    ks, ds, req = ks[o], ds[o], req[o]
    events = []
    for k, d, r in zip(ks, ds, req):
        if dev is None:
            name = ""
        elif device_names is not None:
            name = str(device_names[d])
        else:
            name = str(d)
        events.append({
            "request": int(r), "device": name,
            "from": mode_names[int(out["ev_from"][k, d])],
            "to": mode_names[int(out["ev_to"][k, d])],
            "alarm": int(out["ev_alarm"][k, d]),
            "ref": float(out["ev_ref"][k, d]),
            "level": float(out["ev_level"][k, d])})
    return events


def scan_plan_batch(plane, rng: np.random.Generator, t_sla: float,
                    t_inputs: np.ndarray, *,
                    device_index: Optional[np.ndarray] = None,
                    prior_vec: Optional[np.ndarray] = None,
                    device_names=None, estimator_scope: str = "device",
                    realized: Optional[np.ndarray] = None,
                    prior_mean: Optional[np.ndarray] = None,
                    on_device=None, shards: int = 1):
    """`ControlPlane.plan_batch`, scan-engine edition: budget
    estimation and the adaptive controller run as the (L, D) array
    program; selection, hedging gates, fallback masks, and the RNG
    draws then go through the *shared* `finish_static` /
    `finish_adaptive` — op-for-op and draw-for-draw the python path.

    `device_index` / `prior_vec` are the fleet's integer device axis
    and per-device long-run means; None collapses to one shared column
    (no fleet, or ``estimator_scope="global"``)."""
    t_inputs = np.asarray(t_inputs, np.float64)
    n = len(t_inputs)
    dev = device_index if estimator_scope == "device" else None
    if dev is None:
        D = 1
        dev_cols = np.zeros(n, np.int64)
        priors_vec = np.array([np.nan if plane.default_prior is None
                               else float(plane.default_prior)])
    else:
        dev_cols = np.asarray(dev, np.int64)
        priors_vec = np.asarray(prior_vec, np.float64)
        D = len(priors_vec)

    if plane.controller is None:
        desc = _static_desc(plane)
        if desc is None:                      # identity: budget = obs
            t_est = t_inputs.copy()
        else:
            if desc.prior_override is not None:
                priors_vec = np.full(D, desc.prior_override)
            packed = _pack_columns(t_inputs, dev_cols, D)
            out = _run_program(desc, None, packed, priors_vec, shards)
            t_est = _unpack(packed, out["est"])
        return plane.finish_static(rng, t_sla, t_est, realized,
                                   prior_mean, on_device, n)

    cdesc = _ctrl_desc(plane)
    if dev is not None and np.isnan(priors_vec).any():
        raise ValueError("engine='scan' adaptive control needs a prior "
                         "for every device")
    packed = _pack_columns(t_inputs, dev_cols, D)
    out = _run_program(None, cdesc, packed, priors_vec, shards)
    modes_idx = _unpack(packed, out["mode"], np.int64)
    spec_order = list(dict.fromkeys(
        m.t_estimator for m in plane.controller.modes))
    series = {spec: _unpack(packed, out[f"est{i}"])
              for i, spec in enumerate(spec_order)}
    t_est = plane.compose_adaptive_estimates(series, modes_idx, n)
    events = _assemble_events(out, packed,
                              plane.controller.mode_names(),
                              device_names, dev)
    return plane.finish_adaptive(rng, t_sla, t_est, modes_idx, events,
                                 realized, prior_mean, on_device, n)


def scan_event_phase(cfg, plan, t_inputs, arrivals, exec_samples,
                     profiles, zoo, rng):
    """The request event loop, vectorized: cold starts charged at each
    model's first (non-fallback) use in request order — the same
    `zoo.ensure_hot` calls, in the same order, drawing from the same
    rng as the python loop — then closed-loop latencies as one numpy
    expression or open-loop queueing as a small `lax.scan` over the
    arrival sequence. Returns ``(lat, sel, hedges, fallbacks)``."""
    n = len(t_inputs)
    sel = plan.sel
    fb = (plan.fb_mask if plan.fb_mask is not None
          else np.zeros(n, bool))
    fallbacks = int(fb.sum())
    startup = np.zeros(n)
    live = np.flatnonzero(~fb)
    if live.size:
        # First use per model, in request order (= python's rng order).
        _, first = np.unique(sel[live], return_index=True)
        firsts = np.sort(live[first])
        for i in firsts:
            startup[i] = zoo.ensure_hot(profiles[sel[i]].name,
                                        arrivals[i], rng)
    exec_t = exec_samples[np.arange(n), np.maximum(sel, 0)] + startup
    if cfg.arrival_rate_hz <= 0:
        lat = (t_inputs + exec_t) + t_inputs   # python's add order
        queue = None
    else:
        import jax.numpy as jnp
        from jax import lax
        from jax.experimental import enable_x64
        hedgeable = cfg.n_servers > 1

        def step(carry, row):
            sf, h = carry
            a, e, p95g, outg, active = row
            s = jnp.argmin(sf)
            start = jnp.maximum(a, sf[s])
            do_h = active & hedgeable & (
                (p95g & (start - a > 0.05 * cfg.t_sla)) | outg)
            sf = jnp.where(active, sf.at[s].set(start + e), sf)
            return (sf, h + do_h), jnp.where(active, start - a, 0.0)

        with enable_x64():
            (_, hedges), queue = lax.scan(
                step, (jnp.zeros(cfg.n_servers), jnp.int64(0)),
                (jnp.asarray(arrivals + t_inputs), jnp.asarray(exec_t),
                 jnp.asarray(plan.p95_gate),
                 jnp.asarray(plan.outage_gate), jnp.asarray(~fb)))
            queue = np.asarray(queue)
        lat = ((t_inputs + queue) + exec_t) + t_inputs
    hedges = 0 if queue is None else int(hedges)
    if fallbacks:
        lat = np.where(fb, plan.od_latency, lat)
        sel = np.where(fb, -1, sel)
    return lat, sel, hedges, fallbacks
