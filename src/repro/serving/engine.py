"""Real inference engine: jit'd prefill + decode with KV-cache slots.

This is the execution backend behind the CNNSelect server for models
that actually run in this process (CPU here; the same step functions are
what the dry-run lowers for the TPU meshes). Decode steps are *aligned*
within a batch group; the continuous-batching scheduler (batching.py)
regroups requests between steps and backfills freed slots via
`prefill_row` mid-group."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache
from repro.models.config import ATTN_KINDS, ModelConfig
from repro.models.model import prefill


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_calls: int = 0
    backfill_calls: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    backfill_time_s: float = 0.0
    compile_time_s: float = 0.0


class InferenceEngine:
    """One model's runnable engine with a fixed batch capacity."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_seq: int, parallel=None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.parallel = parallel
        self.stats = EngineStats()
        self.cache = None
        self.cache_pos = 0
        self.valid_from = None
        kinds = set(cfg.pattern) | set(cfg.tail_kinds)
        # Per-row masking (left-padded prompts / slot backfill) only works
        # on attention caches; recurrent state integrates pads irrevocably.
        self._maskable = kinds <= set(ATTN_KINDS)
        # Slot backfill additionally needs every layer's cache to span
        # max_seq (a windowed ring smaller than max_seq wraps slots).
        self._backfillable = self._maskable and not (
            "local" in kinds and cfg.window and cfg.window < max_seq)

        def _prefill(params, tokens, valid_from=None):
            return prefill(params, tokens, cfg, max_seq=max_seq,
                           parallel=parallel, logits_last_only=True,
                           valid_from=valid_from)

        def _decode(params, token, cache, pos, valid_from=None):
            return decode_step(params, token, cache, pos, cfg,
                               parallel=parallel, valid_from=valid_from)

        def _prefill_row(params, tokens, offset, valid_from):
            # Single-row prefill at absolute positions offset..offset+T-1
            # into a fresh (B=1) cache; merged into the live batch cache by
            # `_merge`. RoPE is applied at the true absolute positions so
            # the merged keys are indistinguishable from ones written by a
            # from-scratch group prefill.
            T = tokens.shape[1]
            positions = offset + jnp.arange(T, dtype=jnp.int32)
            cache = init_cache(cfg, 1, max_seq)
            logits, extras = forward(params, tokens, cfg, parallel=parallel,
                                     cache=cache, positions=positions,
                                     logits_last_only=True,
                                     valid_from=valid_from)
            return logits, extras["cache"]

        def _merge(bcache, rcache, row, offset, T):
            # Copy the row cache's first T seq slots into batch slot `row`
            # at seq offset `offset`. The shared (S,) pos array needs no
            # update: group prefill + aligned decode already maintain
            # pos[s] == s for every slot below cache_pos.
            def one(bd, rd):
                out = dict(bd)
                for key in ("k", "v"):
                    b, r = bd[key], rd[key]
                    if b.ndim == 5:     # stacked blocks: (G, B, S, KV, hd)
                        upd = r[:, :, :T].astype(b.dtype)
                        out[key] = jax.lax.dynamic_update_slice(
                            b, upd, (0, row, offset, 0, 0))
                    else:               # tail: (B, S, KV, hd)
                        upd = r[:, :T].astype(b.dtype)
                        out[key] = jax.lax.dynamic_update_slice(
                            b, upd, (row, offset, 0, 0))
                return out
            return {
                "blocks": tuple(one(bd, rd) for bd, rd in
                                zip(bcache["blocks"], rcache["blocks"])),
                "tail": tuple(one(bd, rd) for bd, rd in
                              zip(bcache["tail"], rcache["tail"])),
            }

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._prefill_row = jax.jit(_prefill_row)
        self._merge = jax.jit(_merge, donate_argnums=(0,),
                              static_argnums=(4,))

    def warmup(self, prompt_len: int = 8):
        """Cold-start work: first-call compilation (the serving analogue
        of the paper's model-load phase). Returns compile seconds."""
        t0 = time.perf_counter()
        toks = jnp.zeros((self.batch_size, prompt_len), jnp.int32)
        vf = jnp.zeros((self.batch_size,), jnp.int32) if self._maskable \
            else None
        logits, cache = self._prefill(self.params, toks, vf)
        logits.block_until_ready()
        out = self._decode(self.params, toks[:, :1], cache,
                           jnp.int32(prompt_len), vf)
        out[0].block_until_ready()
        if self._backfillable:
            # Compile the backfill pair too: a first mid-group join must
            # not charge jit time to a measured request.
            rl, rc = self._prefill_row(self.params, toks[:1],
                                       jnp.int32(0),
                                       jnp.zeros((1,), jnp.int32))
            _ = self._merge(out[1], rc, jnp.int32(0), jnp.int32(0),
                            prompt_len)
            rl.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.compile_time_s += dt
        return dt

    def _valid_from_for(self, tokens, lengths):
        """(B,) first attendable absolute position per row, or None."""
        B, T = tokens.shape
        if lengths is None:
            if not self._maskable:
                return None
            return jnp.zeros((B,), jnp.int32)
        if not self._maskable:
            raise NotImplementedError(
                f"padded prompts need per-row masking, which recurrent "
                f"blocks in pattern {self.cfg.pattern} do not support")
        lengths = np.asarray(lengths, np.int64)
        if lengths.shape != (B,) or np.any(lengths < 1) or np.any(lengths > T):
            raise ValueError(f"lengths must be (B,) in [1, {T}]")
        return jnp.asarray(T - lengths, jnp.int32)

    def run_prefill(self, tokens: np.ndarray, lengths=None):
        """tokens: (B, T) int32, left-padded; lengths: optional (B,) count
        of real (right-aligned) tokens per row — padding positions are
        masked out of attention so they cannot contaminate logits or
        later cache reads. Returns next-token logits; stores cache."""
        assert tokens.shape[0] == self.batch_size
        vf = self._valid_from_for(tokens, lengths)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(tokens), vf)
        logits.block_until_ready()
        self.stats.prefill_calls += 1
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.cache = cache
        self.cache_pos = tokens.shape[1]
        self.valid_from = vf
        return np.asarray(logits[:, 0])

    def run_decode(self, tokens: np.ndarray):
        """tokens: (B, 1) int32 next tokens. Returns logits (B, V)."""
        if self.cache is None:
            raise RuntimeError(
                "no KV cache — call run_prefill first (run_decode on a "
                "fresh engine would donate cache=None into jit)")
        if self.cache_pos >= self.max_seq:
            raise RuntimeError(
                f"KV cache full (cache_pos={self.cache_pos}, "
                f"max_seq={self.max_seq})")
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.int32(self.cache_pos), self.valid_from)
        logits.block_until_ready()
        self.cache_pos += 1
        self.stats.decode_calls += 1
        self.stats.decode_time_s += time.perf_counter() - t0
        return np.asarray(logits[:, 0])

    def prefill_row(self, prompt: np.ndarray, slot: int, length=None):
        """Backfill: prefill one request into batch slot `slot` mid-group.

        prompt: (T,) int32, left-padded to the group prompt length;
        length: real token count (right-aligned; default: all T). The row
        is prefilled at absolute positions cache_pos-T .. cache_pos-1 in
        a private cache, then merged into the live batch cache; its
        valid_from masks both the padding and whatever the slot's retired
        previous occupant left behind. Returns next-token logits (V,)."""
        if self.cache is None:
            raise RuntimeError("no KV cache — call run_prefill first")
        if not self._backfillable:
            raise NotImplementedError(
                "slot backfill needs full-seq attention caches "
                f"(pattern {self.cfg.pattern}, window {self.cfg.window})")
        if not 0 <= slot < self.batch_size:
            raise ValueError(f"slot {slot} out of range")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        T = prompt.shape[0]
        offset = self.cache_pos - T
        if offset < 0:
            raise ValueError(
                f"prompt ({T} tokens) longer than current context "
                f"({self.cache_pos})")
        length = T if length is None else int(length)
        if not 1 <= length <= T:
            raise ValueError(f"length must be in [1, {T}]")
        vf_row = self.cache_pos - length
        t0 = time.perf_counter()
        logits, rcache = self._prefill_row(
            self.params, jnp.asarray(prompt)[None], jnp.int32(offset),
            jnp.asarray([vf_row], jnp.int32))
        self.cache = self._merge(self.cache, rcache, jnp.int32(slot),
                                 jnp.int32(offset), T)
        logits.block_until_ready()
        self.stats.backfill_calls += 1
        self.stats.backfill_time_s += time.perf_counter() - t0
        vf = np.asarray(self.valid_from).copy()
        vf[slot] = vf_row
        self.valid_from = jnp.asarray(vf)
        return np.asarray(logits[0, 0])

    @property
    def free_context(self) -> int:
        """Decode steps left before the cache fills."""
        return max(0, self.max_seq - self.cache_pos)

    @property
    def resident_bytes(self) -> int:
        """Bytes of the LIVE parameter tree — int8 execution leaves
        count at one byte per weight (plus their fp32 scales), so the
        memory budget the ModelZoo enforces reflects what this engine
        actually holds, not a notional quantized copy."""
        from repro.quant.int8 import tree_bytes_quantized
        return tree_bytes_quantized(self.params)

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True, rng: Optional[np.random.Generator] = None,
                 lengths=None):
        """Prefill + n_tokens decode steps. Returns (B, n_tokens) ints."""
        out = np.zeros((self.batch_size, n_tokens), np.int32)
        logits = self.run_prefill(prompts, lengths=lengths)
        for t in range(n_tokens):
            if greedy:
                nxt = logits.argmax(-1).astype(np.int32)
            else:
                e = rng.gumbel(size=logits.shape)
                nxt = (logits + e).argmax(-1).astype(np.int32)
            out[:, t] = nxt
            logits = self.run_decode(nxt[:, None])
        return out

    def measured_profile(self, prompt_len: int, n_tokens: int,
                         reps: int = 3) -> dict:
        """Measure hot latency (mu, sigma) of a full request on this
        engine — the on-line analogue of paper Table 5. The first rep is
        discarded (dispatch warmup) and the center is a trimmed mean, so
        a loaded host doesn't corrupt the profile. Prefill and decode are
        timed separately: per_token_ms is decode-only (the prefill is one
        batched pass, not n_tokens+1 of anything)."""
        tot, pre, dec = [], [], []
        for r in range(reps + 1):
            toks = np.random.default_rng(r).integers(
                0, self.cfg.vocab, (self.batch_size, prompt_len),
                dtype=np.int32)
            t0 = time.perf_counter()
            logits = self.run_prefill(toks)
            t1 = time.perf_counter()
            for _ in range(n_tokens):
                nxt = logits.argmax(-1).astype(np.int32)
                logits = self.run_decode(nxt[:, None])
            t2 = time.perf_counter()
            tot.append((t2 - t0) * 1000.0)
            pre.append((t1 - t0) * 1000.0)
            dec.append((t2 - t1) * 1000.0)
        # Drop the warmup rep; trim the slowest remaining rep (by total
        # latency) from every series so the three stats stay aligned.
        order = np.argsort(tot[1:])[:max(1, reps - 1)] + 1
        tot_c = np.array(tot)[order]
        pre_c = np.array(pre)[order]
        dec_c = np.array(dec)[order]
        return {"mu": float(np.mean(tot_c)),
                "sigma": float(np.std(tot_c)),
                "prefill_ms": float(np.mean(pre_c)),
                "per_token_ms": float(np.mean(dec_c) / max(1, n_tokens)),
                "resident_bytes": self.resident_bytes}
