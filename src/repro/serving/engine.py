"""Real inference engine: jit'd prefill + decode with KV-cache slots.

This is the execution backend behind the CNNSelect server for models
that actually run in this process (CPU here; the same step functions are
what the dry-run lowers for the TPU meshes). Decode steps are *aligned*
within a batch group; the continuous-batching scheduler (batching.py)
regroups requests between steps."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig
from repro.models.model import prefill


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_calls: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    compile_time_s: float = 0.0


class InferenceEngine:
    """One model's runnable engine with a fixed batch capacity."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_seq: int, parallel=None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.parallel = parallel
        self.stats = EngineStats()
        self.cache = None
        self.cache_pos = 0

        def _prefill(params, tokens):
            return prefill(params, tokens, cfg, max_seq=max_seq,
                           parallel=parallel, logits_last_only=True)

        def _decode(params, token, cache, pos):
            return decode_step(params, token, cache, pos, cfg,
                               parallel=parallel)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def warmup(self, prompt_len: int = 8):
        """Cold-start work: first-call compilation (the serving analogue
        of the paper's model-load phase). Returns compile seconds."""
        t0 = time.perf_counter()
        toks = jnp.zeros((self.batch_size, prompt_len), jnp.int32)
        logits, cache = self._prefill(self.params, toks)
        logits.block_until_ready()
        _ = self._decode(self.params, toks[:, :1], cache,
                         jnp.int32(prompt_len))
        _[0].block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.compile_time_s += dt
        return dt

    def run_prefill(self, tokens: np.ndarray):
        """tokens: (B, T) int32. Returns next-token logits; stores cache."""
        assert tokens.shape[0] == self.batch_size
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(tokens))
        logits.block_until_ready()
        self.stats.prefill_calls += 1
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.cache = cache
        self.cache_pos = tokens.shape[1]
        return np.asarray(logits[:, 0])

    def run_decode(self, tokens: np.ndarray):
        """tokens: (B, 1) int32 next tokens. Returns logits (B, V)."""
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.int32(self.cache_pos))
        logits.block_until_ready()
        self.cache_pos += 1
        self.stats.decode_calls += 1
        self.stats.decode_time_s += time.perf_counter() - t0
        return np.asarray(logits[:, 0])

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True, rng: Optional[np.random.Generator] = None):
        """Prefill + n_tokens decode steps. Returns (B, n_tokens) ints."""
        out = np.zeros((self.batch_size, n_tokens), np.int32)
        logits = self.run_prefill(prompts)
        for t in range(n_tokens):
            if greedy:
                nxt = logits.argmax(-1).astype(np.int32)
            else:
                e = rng.gumbel(size=logits.shape)
                nxt = (logits + e).argmax(-1).astype(np.int32)
            out[:, t] = nxt
            logits = self.run_decode(nxt[:, None])
        return out

    def measured_profile(self, prompt_len: int, n_tokens: int,
                         reps: int = 3) -> dict:
        """Measure hot latency (mu, sigma) of a full request on this
        engine — the on-line analogue of paper Table 5. The first rep is
        discarded (dispatch warmup) and the center is a trimmed mean, so
        a loaded host doesn't corrupt the profile."""
        lat = []
        for r in range(reps + 1):
            toks = np.random.default_rng(r).integers(
                0, self.cfg.vocab, (self.batch_size, prompt_len),
                dtype=np.int32)
            t0 = time.perf_counter()
            self.generate(toks, n_tokens)
            lat.append((time.perf_counter() - t0) * 1000.0)
        lat = np.sort(np.array(lat[1:]))          # drop warmup rep
        core = lat[:max(1, len(lat) - 1)]         # trim the slowest
        return {"mu": float(np.mean(core)),
                "sigma": float(np.std(core)),
                "per_token_ms": float(np.mean(core) / (n_tokens + 1))}
