"""Mobile network processes (paper §4.1 "Impact of mobile network
conditions", extended beyond the paper's stationary measurements).

T_input is the request upload time; the paper estimates T_nw
conservatively as 2 * T_input (responses are small text labels). The
paper samples each network i.i.d.; real mobile networks are *time-
varying* (handoffs, congestion bursts, outages — the regime MDInference
arXiv:2002.06603 and ModiPick arXiv:1909.02053 target), so the
simulator draws whole traces from a `NetworkProcess`:

- `StationaryProcess` — i.i.d. draws, backward compatible with the
  named networks of `configs/paper_zoo.NETWORKS`.
- `MarkovProcess` — regime-switching between stationary states under a
  row-stochastic transition matrix (e.g. campus_wifi -> lte handoff,
  congestion bursts, outages).
- `TraceReplayProcess` — replay a recorded/synthetic mean-T_input
  trace cyclically, with optional lognormal jitter around it.

All processes generate whole-trace arrays vectorized (the Markov chain
is sampled per *dwell segment*, not per request), so 10k-request
simulations keep their chunked-admission speed, and every process
clamps at `MIN_T_INPUT_MS` — no process can emit a non-positive upload
time (pre-refactor only the legacy fallback path clamped).

Server-side budgeting under time variation is the `TInputEstimator`
family (ModiPick's online estimation): the admission `Router` consults
an estimator to turn observed upload times into per-request budget
estimates instead of trusting a distribution mean. See DESIGN.md §9.
"""

from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.paper_zoo import (CAPTURE_SCENARIOS, NETWORK_SCENARIOS,
                                     NETWORK_STATES, NETWORKS,
                                     SYNTHETIC_TRACES, lognormal_params,
                                     synthetic_trace)
from repro.core.registry import parse_spec

# No network can deliver a request in non-positive time; every process
# clamps here (unified — previously only the legacy fallback did).
MIN_T_INPUT_MS = 1.0


def _resolve_state(spec) -> Tuple[str, float, float]:
    """A Markov/trace state: a named network, a named synthetic state
    (NETWORK_STATES), or an explicit (name, mean, std) triple / dict."""
    if isinstance(spec, str):
        d = NETWORKS.get(spec) or NETWORK_STATES.get(spec)
        if d is None:
            raise ValueError(f"unknown network state {spec!r}; known: "
                             f"{sorted(NETWORKS) + sorted(NETWORK_STATES)}")
        return spec, float(d["mean"]), float(d["std"])
    if isinstance(spec, dict):
        name, mean, std = spec["name"], spec["mean"], spec["std"]
    else:
        name, mean, std = spec
    name, mean, std = str(name), float(mean), float(std)
    # Lognormal matching takes log(mean): a non-positive mean would
    # yield NaN draws that sail through the clamp unnoticed.
    if mean <= 0 or std < 0:
        raise ValueError(f"state {name!r} needs mean > 0 and std >= 0, "
                         f"got ({mean}, {std})")
    return name, mean, std


class NetworkProcess:
    """Base of the T_input trace generators.

    Subclasses implement `_raw_trace`; the public `sample_trace` /
    `sample_t_input` apply the unified `MIN_T_INPUT_MS` clamp so no
    process can emit non-positive upload times.
    """

    name: str = "network"

    @property
    def mean(self) -> float:
        """Long-run mean T_input (the stationary budget a non-adaptive
        server would trust)."""
        raise NotImplementedError

    def regime_names(self) -> List[str]:
        """Labels for the regime indices emitted by `sample_trace`."""
        return [self.name]

    def _raw_trace(self, rng: np.random.Generator,
                   n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(t_input (n,), regime (n,) int64) before clamping."""
        raise NotImplementedError

    def sample_trace(self, rng: np.random.Generator,
                     n: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        t, regimes = self._raw_trace(rng, int(n))
        return np.maximum(t, MIN_T_INPUT_MS), regimes

    def sample_t_input(self, rng: np.random.Generator, n: int = 1):
        return self.sample_trace(rng, n)[0]


class StationaryProcess(NetworkProcess):
    """i.i.d. draws: lognormal matched to (mean, std) for the paper's
    named networks (positive heavy tail), or plain normal for ad-hoc
    (mean, std) models — both behind the base clamp."""

    def __init__(self, name: str, mean_ms: float, std_ms: float,
                 dist: str = "lognormal"):     # "lognormal" | "normal"
        if dist not in ("lognormal", "normal"):
            raise ValueError(f"unknown distribution {dist!r}")
        if dist == "lognormal" and mean_ms <= 0:
            # log(mean) of a non-positive mean -> NaN draws that the
            # clamp cannot catch (np.maximum(nan, x) is nan).
            raise ValueError(f"lognormal network {name!r} needs a "
                             f"positive mean, got {mean_ms}")
        if std_ms < 0:
            raise ValueError(f"network {name!r} needs std >= 0, "
                             f"got {std_ms}")
        self.name = name
        self.mean_ms = float(mean_ms)
        self.std_ms = float(std_ms)
        self.dist = dist

    @classmethod
    def named(cls, name: str) -> "StationaryProcess":
        d = NETWORKS[name]
        return cls(name, d["mean"], d["std"])

    @property
    def mean(self) -> float:
        return self.mean_ms

    def _raw_trace(self, rng, n):
        if self.dist == "lognormal":
            mu, sg = lognormal_params(self.mean_ms, self.std_ms)
            t = rng.lognormal(mu, sg, size=n)
        else:
            t = rng.normal(self.mean_ms, self.std_ms, n)
        return t, np.zeros(n, np.int64)


class MarkovProcess(NetworkProcess):
    """Regime-switching network: a Markov chain over stationary states
    (one lognormal T_input distribution each), advanced per request.

    The chain is sampled per dwell *segment* (geometric dwell in the
    current state, then one conditional transition), so generating a
    sticky 10k-request trace costs a handful of numpy draws, not 10k
    python steps; the per-request T_input draw is one vectorized
    `rng.lognormal` over per-request (mu, sigma) arrays.
    """

    def __init__(self, states: Sequence, transition, *, start: int = 0,
                 name: str = "markov"):
        self.name = name
        resolved = [_resolve_state(s) for s in states]
        self.state_names = [r[0] for r in resolved]
        self._means = np.array([r[1] for r in resolved], np.float64)
        self._stds = np.array([r[2] for r in resolved], np.float64)
        self.P = np.asarray(transition, np.float64)
        K = len(resolved)
        if self.P.shape != (K, K):
            raise ValueError(f"transition matrix shape {self.P.shape} "
                             f"does not match {K} states")
        if (self.P < 0).any() or not np.allclose(self.P.sum(axis=1), 1.0):
            raise ValueError("transition matrix rows must be "
                             "non-negative and sum to 1")
        if not 0 <= start < K:
            raise ValueError(f"start state {start} out of range")
        self.start = int(start)

    @classmethod
    def from_scenario(cls, name: str) -> "MarkovProcess":
        d = NETWORK_SCENARIOS[name]
        return cls(d["states"], d["transition"],
                   start=d.get("start", 0), name=name)

    def regime_names(self) -> List[str]:
        return list(self.state_names)

    def stationary_distribution(self) -> np.ndarray:
        """pi with pi @ P = pi, sum(pi) = 1 (least-squares solve)."""
        K = self.P.shape[0]
        a = np.vstack([self.P.T - np.eye(K), np.ones(K)])
        b = np.concatenate([np.zeros(K), [1.0]])
        pi, *_ = np.linalg.lstsq(a, b, rcond=None)
        return np.maximum(pi, 0.0) / np.maximum(pi, 0.0).sum()

    @property
    def mean(self) -> float:
        return float(self.stationary_distribution() @ self._means)

    def _sample_regimes(self, rng, n):
        out = np.empty(n, np.int64)
        s, i = self.start, 0
        while i < n:
            p_stay = self.P[s, s]
            if p_stay >= 1.0:
                out[i:] = s
                break
            dwell = int(rng.geometric(1.0 - p_stay))
            j = min(n, i + dwell)
            out[i:j] = s
            i = j
            if i >= n:
                break
            cond = self.P[s].copy()
            cond[s] = 0.0
            s = int(rng.choice(len(cond), p=cond / cond.sum()))
        return out

    def _raw_trace(self, rng, n):
        regimes = self._sample_regimes(rng, n)
        mu, sg = lognormal_params(self._means[regimes],
                                   self._stds[regimes])
        return rng.lognormal(mu, sg), regimes


class TraceReplayProcess(NetworkProcess):
    """Replay a recorded/synthetic mean-T_input trace (ms per request,
    cycled over the run), with lognormal jitter of coefficient of
    variation `jitter_cv` around each point. `regime_labels` optionally
    buckets trace positions for per-regime reporting (same length as
    the trace; defaults to one regime)."""

    def __init__(self, trace, *, jitter_cv: float = 0.15,
                 name: str = "trace",
                 regime_labels: Optional[Sequence[int]] = None,
                 regime_names: Optional[Sequence[str]] = None):
        self.name = name
        self.trace = np.asarray(trace, np.float64)
        if self.trace.ndim != 1 or len(self.trace) == 0:
            raise ValueError("trace must be a non-empty 1-D array")
        if (self.trace <= 0).any():
            raise ValueError("trace means must be positive")
        self.jitter_cv = float(jitter_cv)
        if regime_labels is not None and len(regime_labels) != len(
                self.trace):
            raise ValueError("regime_labels must align with the trace")
        self._labels = (np.zeros(len(self.trace), np.int64)
                        if regime_labels is None
                        else np.asarray(regime_labels, np.int64))
        if (self._labels < 0).any():
            raise ValueError("regime_labels must be non-negative")
        n_regimes = int(self._labels.max()) + 1
        if regime_names is not None:
            self._names = list(regime_names)
            if len(self._names) < n_regimes:
                raise ValueError("regime_names must cover every label")
        else:
            # Default names must cover every label or per-regime
            # reporting would silently drop regimes >= 1.
            self._names = ([name] if n_regimes == 1 else
                           [f"{name}:{k}" for k in range(n_regimes)])

    @property
    def mean(self) -> float:
        return float(self.trace.mean())

    def regime_names(self) -> List[str]:
        return list(self._names)

    def _raw_trace(self, rng, n):
        pos = np.arange(n) % len(self.trace)
        means = self.trace[pos]
        if self.jitter_cv <= 0:
            return means.copy(), self._labels[pos]
        mu, sg = lognormal_params(means, self.jitter_cv * means)
        return rng.lognormal(mu, sg), self._labels[pos]


class NetworkModel(StationaryProcess):
    """Legacy shim (pre-NetworkProcess API): named networks draw the
    matched lognormal, ad-hoc (mean, std) models draw a clamped normal.
    Prefer `make_network` / `StationaryProcess` in new code."""

    def __init__(self, name: str, mean: float, std: float):
        super().__init__(name, mean, std,
                         dist="lognormal" if name in NETWORKS else "normal")

    @classmethod
    def named(cls, name: str) -> "NetworkModel":
        d = NETWORKS[name]
        return cls(name, d["mean"], d["std"])

    def estimate_t_input(self, observed: float | None = None) -> float:
        """Deprecated pre-estimator shim: budget from the observed
        upload time, falling back to the distribution mean. The
        estimator API subsumes it — ``make_estimator("observed")`` for
        the observation path, ``make_estimator("mean", prior=...)`` for
        the mean fallback."""
        import warnings
        warnings.warn(
            "NetworkModel.estimate_t_input is deprecated; use "
            "make_estimator('observed') / make_estimator('mean', "
            "prior=net.mean) and the Router's t_estimator instead",
            DeprecationWarning, stacklevel=2)
        return observed if observed is not None else self.mean_ms


def _captured_process(name: str, spec: str) -> NetworkProcess:
    # Lazy import: serving.trace imports NetworkProcess from here.
    from repro.serving.trace import CapturedTraceProcess, load_capture
    d = CAPTURE_SCENARIOS[name]
    return CapturedTraceProcess(load_capture(name),
                                mode=d.get("mode", "loop"), name=spec)


def trace_names() -> List[str]:
    """Every name ``trace:<name>`` resolves: the synthetic traces plus
    the registered captures."""
    return sorted(SYNTHETIC_TRACES) + sorted(CAPTURE_SCENARIOS)


def make_network(spec: Union[str, NetworkProcess]) -> NetworkProcess:
    """Resolve a network spec to a process:

    - a `NetworkProcess` instance passes through;
    - a `NETWORKS` name -> `StationaryProcess` (paper behaviour);
    - a `NETWORK_SCENARIOS` name -> `MarkovProcess`;
    - ``trace:<name>`` -> `TraceReplayProcess` over the synthetic trace,
      or a recorded capture when `<name>` is a `CAPTURE_SCENARIOS` entry;
    - ``capture:<name>`` -> `CapturedTraceProcess` over the registered
      recorded capture only.
    """
    if isinstance(spec, NetworkProcess):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"network spec must be a NetworkProcess or a "
                         f"str, got {type(spec).__name__}")
    if spec in NETWORKS:
        return StationaryProcess.named(spec)
    if spec in NETWORK_SCENARIOS:
        return MarkovProcess.from_scenario(spec)
    # The shared registry grammar (core.registry.parse_spec): the same
    # unknown/takes-no-arg/needs-arg errors every other factory raises.
    head, arg = parse_spec(
        spec, kind="network",
        heads=list(NETWORKS) + list(NETWORK_SCENARIOS)
        + ["trace", "capture"],
        known=sorted(NETWORKS) + sorted(NETWORK_SCENARIOS)
        + ["trace:<name>", "capture:<name>"],
        arg_heads=("trace", "capture"),
        required_arg_heads=("trace", "capture"),
        arg_desc={"trace": ("trace name", "name"),
                  "capture": ("capture name", "name")})
    if head == "trace":
        # Sub-registry resolution: one ValueError naming every
        # resolvable trace (synthetic + recorded captures).
        if arg in SYNTHETIC_TRACES:
            return TraceReplayProcess(synthetic_trace(arg), name=spec)
        if arg in CAPTURE_SCENARIOS:
            return _captured_process(arg, spec)
        raise ValueError(f"unknown trace {arg!r}; "
                         f"known: {', '.join(trace_names())}")
    if arg not in CAPTURE_SCENARIOS:
        raise ValueError(f"unknown capture {arg!r}; known: "
                         f"{', '.join(sorted(CAPTURE_SCENARIOS))}")
    return _captured_process(arg, spec)


# --------------------------------------------------------------------------
# Online T_input estimation (server-side budgeting, ModiPick-style)
# --------------------------------------------------------------------------

class TInputEstimator:
    """Causal online estimate of the network's T_input, consulted by the
    `Router` to set per-request budgets.

    Protocol: `estimate(observed=...)` returns the budget-side T_input
    for the *current* request using only past observations (plus the
    prior / the current observation as cold-start fallbacks), then
    `observe(t)` feeds the request's measured upload time back.
    `estimate_series` runs the same protocol over a whole trace and is
    the vectorized hook the batched admission path uses.
    """

    name = "estimator"

    def __init__(self, prior: Optional[float] = None):
        self.prior = prior

    def observe(self, t_input: float) -> None:
        raise NotImplementedError

    def _state_estimate(self) -> Optional[float]:
        """Current estimate from past observations, None if cold."""
        raise NotImplementedError

    def estimate(self, observed: Optional[float] = None) -> float:
        est = self._state_estimate()
        if est is not None:
            return float(est)
        # Cold start: prior if configured, else the observation itself.
        if self.prior is not None:
            return float(self.prior)
        if observed is not None:
            return float(observed)
        raise ValueError(f"{self.name}: cold estimator with no prior "
                         f"and no observation")

    def estimate_series(self, observed) -> np.ndarray:
        observed = np.asarray(observed, np.float64)
        out = np.empty_like(observed)
        for i, x in enumerate(observed):
            out[i] = self.estimate(observed=float(x))
            self.observe(float(x))
        return out


class ObservedEstimator(TInputEstimator):
    """The paper's behaviour: budget from the actual measured upload
    time of the arriving request (identity on the observation)."""

    name = "observed"

    def observe(self, t_input: float) -> None:
        pass                          # stateless

    def _state_estimate(self):
        return None                   # always defer to the observation

    def estimate(self, observed: Optional[float] = None) -> float:
        if observed is not None:
            return float(observed)
        return super().estimate()

    def estimate_series(self, observed) -> np.ndarray:
        return np.asarray(observed, np.float64).copy()


class MeanEstimator(TInputEstimator):
    """The non-adaptive strawman: always the stationary prior mean (what
    a server trusting offline network measurements does)."""

    name = "mean"

    def observe(self, t_input: float) -> None:
        pass

    def _state_estimate(self):
        if self.prior is None:
            # Fail loudly rather than silently degrading to the
            # observation (which would be the *adaptive* behaviour).
            raise ValueError("mean estimator needs a prior")
        return self.prior

    def estimate_series(self, observed) -> np.ndarray:
        observed = np.asarray(observed, np.float64)
        if self.prior is None:
            raise ValueError("mean estimator needs a prior")
        return np.full_like(observed, float(self.prior))


class EWMAEstimator(TInputEstimator):
    """Exponentially-weighted moving average of observed upload times
    (ModiPick's estimator family): est <- (1-alpha)*est + alpha*obs."""

    name = "ewma"

    def __init__(self, alpha: float = 0.2, prior: Optional[float] = None):
        super().__init__(prior)
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"ewma alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._est: Optional[float] = None

    def observe(self, t_input: float) -> None:
        self._est = (float(t_input) if self._est is None else
                     (1.0 - self.alpha) * self._est
                     + self.alpha * float(t_input))

    def _state_estimate(self):
        return self._est

    def estimate_series(self, observed) -> np.ndarray:
        """Vectorized causal EWMA via the blocked closed form
        ``out[k] = r^k e + alpha r^{k-1} C[k-1]`` with ``r = 1-alpha``
        and ``C = cumsum(x[l] / r^l)`` — one numpy pass per block
        instead of a python step per request. Blocks are capped so
        ``r^{-l}`` stays inside float64 range; agreement with the
        sequential protocol is pinned by the estimator series test."""
        x = np.asarray(observed, np.float64)
        n = len(x)
        if n == 0:
            return x.copy()
        out = np.empty(n)
        r = 1.0 - self.alpha
        e = self._est
        i = 0
        if e is None:
            # Cold start answers the prior (or the observation itself),
            # and the first observe() *resets* the state to x[0].
            out[0] = (float(self.prior) if self.prior is not None
                      else float(x[0]))
            e = float(x[0])
            i = 1
        if r == 0.0:                   # alpha == 1: track the last obs
            if i == 0:
                out[0] = e
                i = 1
            out[i:] = x[i - 1:n - 1]
            self._est = float(x[-1])
            return out
        block = int(min(8192.0, max(1.0, -600.0 / np.log(r))))
        while i < n:
            m = min(block, n - i)
            xs = x[i:i + m]
            rk = r ** np.arange(m)
            c = np.cumsum(xs / rk)
            out[i] = e
            if m > 1:
                out[i + 1:i + m] = (rk[1:] * e
                                    + self.alpha * rk[:-1] * c[:-1])
            e = r ** m * e + self.alpha * r ** (m - 1) * c[-1]
            i += m
        self._est = float(e)
        return out


class PercentileEstimator(TInputEstimator):
    """Rolling-window percentile of observed upload times: a q>50
    percentile budgets conservatively against the heavy mobile tail."""

    name = "pctl"

    def __init__(self, q: float = 90.0, window: int = 64,
                 prior: Optional[float] = None):
        super().__init__(prior)
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.q = float(q)
        self.window = int(window)
        self._buf: deque = deque(maxlen=self.window)

    def observe(self, t_input: float) -> None:
        self._buf.append(float(t_input))

    def _state_estimate(self):
        if not self._buf:
            return None
        return float(np.percentile(np.asarray(self._buf), self.q))

    def estimate_series(self, observed) -> np.ndarray:
        """Vectorized causal rolling percentile: one strided
        `np.percentile` over all full windows, a short python loop only
        for the warm-up prefix."""
        x = np.asarray(observed, np.float64)
        n, w = len(x), self.window
        out = np.empty(n)
        pre = np.asarray(self._buf, np.float64)
        for i in range(min(n, w)):
            hist = np.concatenate([pre, x[:i]])[-w:]
            out[i] = (float(np.percentile(hist, self.q)) if len(hist)
                      else self.estimate(observed=float(x[i])))
        if n > w:
            wins = np.lib.stride_tricks.sliding_window_view(x, w)
            out[w:] = np.percentile(wins[:n - w], self.q, axis=1)
        for v in x[max(0, n - w):]:
            self.observe(float(v))
        return out


ESTIMATOR_REGISTRY = {
    "observed": lambda arg, prior: ObservedEstimator(prior=prior),
    "mean": lambda arg, prior: MeanEstimator(prior=prior),
    "ewma": lambda arg, prior: EWMAEstimator(
        alpha=float(arg) if arg else 0.2, prior=prior),
    "pctl": lambda arg, prior: PercentileEstimator(
        q=float(arg) if arg else 90.0, prior=prior),
}

# The ":<arg>"-taking estimator heads (numeric argument).
_ESTIMATOR_ARG_HEADS = ("ewma", "pctl")


def estimator_names() -> List[str]:
    """The spec forms `make_estimator` resolves (registry-error text)."""
    return ["observed", "mean", "ewma[:alpha]", "pctl[:q]"]


def validate_estimator_spec(spec: str) -> str:
    """Parse-check a string estimator spec, raising the registry-style
    `ValueError` (naming every valid spec form) on an unknown head, a
    stray ':<arg>', or a non-numeric argument — previously a bad
    argument surfaced as whatever the builder raised (an opaque
    `float()` conversion error), and `EstimatorBank` deferred even that
    to the first per-device use mid-run. Returns the head."""
    head, _ = parse_spec(spec, kind="t_input estimator",
                         heads=ESTIMATOR_REGISTRY,
                         known=estimator_names(),
                         arg_heads=_ESTIMATOR_ARG_HEADS,
                         numeric_arg_heads=_ESTIMATOR_ARG_HEADS)
    return head


def estimator_factory(spec: str):
    """Parse a string estimator spec ONCE and return a
    ``factory(prior=...) -> TInputEstimator`` closure. `EstimatorBank`
    instantiates one estimator per unseen device; routing every cold
    start through `make_estimator` re-partitioned and re-validated the
    spec string per device — noise at ten devices, real work at a
    million. The factory keeps the parsed (head, arg, builder) triple
    closed over instead."""
    head, _, arg = spec.partition(":")
    validate_estimator_spec(spec)
    builder = ESTIMATOR_REGISTRY[head]

    def factory(prior: Optional[float] = None) -> TInputEstimator:
        if head == "mean" and prior is None:
            raise ValueError("t_estimator 'mean' needs a prior; pass a "
                             "MeanEstimator(prior=...) instance instead")
        return builder(arg, prior)

    return factory


def make_estimator(spec: Union[str, TInputEstimator, None], *,
                   prior: Optional[float] = None
                   ) -> Optional[TInputEstimator]:
    """Resolve an estimator spec ("observed", "mean", "ewma[:alpha]",
    "pctl[:q]", an instance, or None -> None)."""
    if spec is None or isinstance(spec, TInputEstimator):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"t_input estimator spec must be a "
                         f"TInputEstimator, a str, or None, got "
                         f"{type(spec).__name__}")
    head, _, arg = spec.partition(":")
    validate_estimator_spec(spec)
    if head == "mean" and prior is None:
        # Fail at construction: a prior-less "mean" spec can never
        # answer. Callers without a network mean (Router, ServingLoop,
        # CNNSelectServer) must pass a MeanEstimator(prior=...) instance.
        raise ValueError("t_estimator 'mean' needs a prior; pass a "
                         "MeanEstimator(prior=...) instance instead")
    return ESTIMATOR_REGISTRY[head](arg, prior)


def resize_decision(size_kb: float, *, scale_ms_per_kb: float = 0.165,
                    upload_ms_per_kb: float = 0.214) -> bool:
    """Paper §3.1 'Impact of Image Size': downscale an input of size x1
    to x2 iff T_d(x1,x2) + T_n(x2) <= T_n(x1). Linear cost model fitted
    to the paper's measurements (36.83 ms per 172 KB upload; up to 38 ms
    to resize <=226 KB). Returns True if resizing before upload wins."""
    target_kb = 110.0  # post-resize size used in the paper's experiments
    if size_kb <= target_kb:
        return False
    t_resize = scale_ms_per_kb * size_kb
    t_up_full = upload_ms_per_kb * size_kb
    t_up_resized = upload_ms_per_kb * target_kb
    return t_resize + t_up_resized <= t_up_full
