"""Mobile network models (paper §4.1 "Impact of mobile network
conditions"). T_input is the request upload time; the paper estimates
T_nw conservatively as 2 * T_input (responses are small text labels)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.paper_zoo import NETWORKS, sample_network


@dataclass
class NetworkModel:
    name: str
    mean: float
    std: float

    @classmethod
    def named(cls, name: str) -> "NetworkModel":
        d = NETWORKS[name]
        return cls(name, d["mean"], d["std"])

    def sample_t_input(self, rng: np.random.Generator, n: int = 1):
        return sample_network(self.name, rng, n) if self.name in NETWORKS \
            else np.maximum(rng.normal(self.mean, self.std, n), 1.0)

    def estimate_t_input(self, observed: float | None = None) -> float:
        """Server-side estimate used for budgeting: the paper measures the
        actual upload time of the arriving request (observed); fall back
        to the distribution mean."""
        return observed if observed is not None else self.mean


def resize_decision(size_kb: float, *, scale_ms_per_kb: float = 0.165,
                    upload_ms_per_kb: float = 0.214) -> bool:
    """Paper §3.1 'Impact of Image Size': downscale an input of size x1
    to x2 iff T_d(x1,x2) + T_n(x2) <= T_n(x1). Linear cost model fitted
    to the paper's measurements (36.83 ms per 172 KB upload; up to 38 ms
    to resize <=226 KB). Returns True if resizing before upload wins."""
    target_kb = 110.0  # post-resize size used in the paper's experiments
    if size_kb <= target_kb:
        return False
    t_resize = scale_ms_per_kb * size_kb
    t_up_full = upload_ms_per_kb * size_kb
    t_up_resized = upload_ms_per_kb * target_kb
    return t_resize + t_up_resized <= t_up_full
