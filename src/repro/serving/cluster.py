"""Multi-tenant cluster control plane (DESIGN.md §16).

The paper adapts one serving stack to one request stream; a mobile
backend serves M device populations (tenants) from a shared cluster of
N replicas. This layer composes the repo's pieces at that scale,
through the `ServingStack` protocol alone:

- **Replicas** are any `ServingStack` (normally `SimReplicaStack`s
  scored by *measured* executed tokens/s when their profiles came from
  `measured_profiles` — PR 7's capacity numbers, not table lookups).
- **Tenants** (`TenantSpec`) pair a device population
  (`FLEET_SCENARIOS` fleet) with an SLA class
  (`TENANT_SLA_CLASSES`) — per-tenant SLA-aware selection after
  ModiPick (arXiv:1909.02053).
- **Placement** (`ClusterPlacer`) generalizes the `ModelZoo` LRU to a
  cluster-wide memory budget: a replica heating a model may evict the
  globally least-recently-used copy on *any* replica.
- **Scaling**: the cluster-level `AdaptiveController` watches every
  tenant-device stream; its switch events drive replica
  scale-up/scale-down, and sustained queueing scales up directly.
- **Load shedding**: when every active replica's queue would blow the
  SLA anyway, a device that can run its model locally is answered with
  an on-device advisory (the MDInference duality) instead of joining a
  doomed queue.
- **Cross-replica hedging**: a degraded-regime request is duplicated
  to the two least-loaded replicas and the first completion wins
  (MDInference, arXiv:2002.06603) — the cross-replica generalization
  of the simulator's ``hedge="outage"`` second-server re-issue.

Every placement / eviction / scale / shed decision lands in
`Cluster.events` in submit order, and `capture_run` persists them as
`Trace.meta["cluster_events"]` — the same switch-event discipline the
adaptive controller established: a fresh identically-configured
cluster replaying the captured workload reproduces the event log
bit-for-bit (pinned by tests/test_cluster.py).

Replica-level metrics double-count hedged requests by design (each
replica ledgers the work it executed, including losing duplicates);
`Cluster.metrics` is the authoritative tenant-facing view — one row
per request, tagged with tenant, winning replica, and hedge flag.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.paper_zoo import TENANT_MIXES, TENANT_SLA_CLASSES
from repro.serving.batching import Request
from repro.serving.control import AdaptiveController, make_controller
from repro.serving.fleet import ArrayFleet, make_fleet
from repro.serving.metrics import ServingMetrics
from repro.serving.stack import ServingStack, StackOutcome

__all__ = ["TenantSpec", "make_tenants", "make_tenant_workload",
           "TenantColumns", "make_tenant_columns",
           "requests_from_columns", "ClusterPlacer", "Cluster",
           "capture_run", "requests_from_cluster_trace",
           "replay_events"]


# --------------------------------------------------------------------------
# Tenants
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TenantSpec:
    """One tenant: a device population under an SLA class.

    `weight` is the tenant's share of cluster request volume; `phase`
    places the tenant's traffic peak (fraction of the horizon) and
    `burst` its peak/trough rate ratio — staggered peaks are what a
    shared cluster exploits and static pinning cannot."""

    name: str
    sla_class: str                    # TENANT_SLA_CLASSES key
    fleet: str = "mixed_fleet"        # FLEET_SCENARIOS name
    weight: float = 1.0
    phase: float = 0.0
    burst: float = 1.0

    def __post_init__(self):
        if self.sla_class not in TENANT_SLA_CLASSES:
            raise ValueError(
                f"unknown SLA class {self.sla_class!r}; known: "
                f"{', '.join(sorted(TENANT_SLA_CLASSES))}")
        if self.weight <= 0:
            raise ValueError("tenant weight must be positive")

    @property
    def t_sla(self) -> float:
        return float(TENANT_SLA_CLASSES[self.sla_class]["t_sla"])

    @property
    def shed_priority(self) -> int:
        return int(TENANT_SLA_CLASSES[self.sla_class]["shed_priority"])


def make_tenants(mix: Union[str, Sequence]) -> List[TenantSpec]:
    """Resolve a tenant mix: a `TENANT_MIXES` name, or a sequence of
    `TenantSpec`s / dicts (the registry entry format, ``tenant`` key
    naming the tenant)."""
    if isinstance(mix, str):
        if mix not in TENANT_MIXES:
            raise ValueError(f"unknown tenant mix {mix!r}; known: "
                             f"{', '.join(sorted(TENANT_MIXES))}")
        mix = TENANT_MIXES[mix]
    out = []
    for e in mix:
        if isinstance(e, TenantSpec):
            out.append(e)
        else:
            e = dict(e)
            out.append(TenantSpec(name=e.pop("tenant"), **e))
    names = [t.name for t in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    return out


@dataclass
class TenantColumns:
    """Columnar multi-tenant workload (the scan cluster engine's view).

    Devices live in one global *column* universe: tenant ``t``'s
    devices occupy columns ``[col_offsets[t], col_offsets[t+1])`` in
    fleet order, so per-column arrays (`col_prior`, `col_od_ms`) line
    up with the controller's device axis. Request rows are sorted by
    ``(arrival, tenant name)`` — exactly `make_tenant_workload`'s
    ordering — with row i playing rid i."""

    tenants: List[TenantSpec]
    arrival: np.ndarray       # (N,) f64, sorted
    t_input: np.ndarray       # (N,) f64
    col: np.ndarray           # (N,) int64 global device column
    tenant_idx: np.ndarray    # (N,) int64 into `tenants`
    sla_ms: np.ndarray        # (N,) f64 per-request deadline
    col_offsets: np.ndarray   # (T+1,) int64
    col_tenant: np.ndarray    # (D,) int64 owning tenant per column
    col_prior: np.ndarray     # (D,) f64 long-run mean T_input
    col_od_ms: np.ndarray     # (D,) f64 on-device latency (0 = none)
    col_local: List           # per-column local device token (str|int)

    def __len__(self) -> int:
        return len(self.arrival)

    def device_name(self, c: int) -> str:
        """The ``"<tenant>/<device>"`` id string for column `c`."""
        c = int(c)
        t = self.tenants[self.col_tenant[c]]
        return f"{t.name}/{self.col_local[c]}"

    def __getitem__(self, c: int) -> str:
        """Index-as-name view, so the columns object itself can serve
        as the scan engine's `device_names` table without materializing
        D id strings."""
        return self.device_name(c)


def _tenant_fleet_columns(fleet):
    """``(local_tokens, prior, od_ms)`` for one tenant's fleet —
    `FleetMixture` devices are keyed by id string, `ArrayFleet`
    devices by integer index (materializing 10^6 id strings would
    dwarf the workload)."""
    if isinstance(fleet, ArrayFleet):
        local = list(range(fleet.n_devices))
    else:
        local = list(fleet.device_ids)
    return local, fleet.prior_array(), fleet.on_device_arrays()[0]


def make_tenant_columns(mix: Union[str, Sequence], *,
                        n_requests: int, rate_hz: float,
                        seed: int = 0) -> TenantColumns:
    """`make_tenant_workload`'s sampler in columnar form: all-array
    arrival/T_input/device generation (no per-request python loop) plus
    the per-column prior / on-device tables. `make_tenant_workload`
    materializes `Request`s from this; the scan cluster engine consumes
    it directly."""
    tenants = make_tenants(mix)
    horizon_ms = n_requests / float(rate_hz) * 1000.0
    total_w = sum(t.weight for t in tenants)
    # Global device-column universe (all tenants, request share or not:
    # the controller priors prime every tenant's devices).
    fleets = [make_fleet(t.fleet) for t in tenants]
    per = [_tenant_fleet_columns(f) for f in fleets]
    col_offsets = np.cumsum([0] + [len(p[0]) for p in per])
    col_tenant = np.repeat(np.arange(len(tenants), dtype=np.int64),
                           [len(p[0]) for p in per])
    col_local = [tok for p in per for tok in p[0]]
    col_prior = np.concatenate([p[1] for p in per])
    col_od = np.concatenate([p[2] for p in per])
    arr_parts, ti_parts, col_parts, tid_parts = [], [], [], []
    root = np.random.SeedSequence(seed)
    for ti, (t, ss) in enumerate(zip(tenants,
                                     root.spawn(len(tenants)))):
        m = int(round(n_requests * t.weight / total_w))
        if m == 0:
            continue
        rng = np.random.default_rng(ss)
        # Arrival times by inverse-CDF over the tenant's intensity:
        # base 1 plus (burst-1) inside a window of width 0.25 around
        # `phase` (wrapped), integrated on a fixed grid.
        grid = np.linspace(0.0, 1.0, 513)
        mid = 0.5 * (grid[:-1] + grid[1:])
        dist = np.abs(((mid - t.phase + 0.5) % 1.0) - 0.5)
        lam = 1.0 + (t.burst - 1.0) * (dist < 0.125)
        cdf = np.concatenate([[0.0], np.cumsum(lam)])
        cdf /= cdf[-1]
        u = np.sort(rng.random(m))
        arrivals = np.interp(u, cdf, grid) * horizon_ms
        tr = fleets[ti].sample_trace(rng, m)
        arr_parts.append(arrivals)
        ti_parts.append(tr.t_input)
        col_parts.append(col_offsets[ti] + tr.device_index)
        tid_parts.append(np.full(m, ti, np.int64))
    arrival = np.concatenate(arr_parts)
    tenant_idx = np.concatenate(tid_parts)
    # Sort by (arrival, tenant name): lexsort is stable, so equal keys
    # keep concatenation (= mix) order, matching the python list sort.
    name_rank = np.argsort(
        np.argsort([t.name for t in tenants])).astype(np.int64)
    order = np.lexsort((name_rank[tenant_idx], arrival))
    t_sla = np.array([t.t_sla for t in tenants], np.float64)
    tenant_idx = tenant_idx[order]
    return TenantColumns(
        tenants=tenants, arrival=arrival[order],
        t_input=np.concatenate(ti_parts)[order],
        col=np.concatenate(col_parts)[order],
        tenant_idx=tenant_idx, sla_ms=t_sla[tenant_idx],
        col_offsets=col_offsets.astype(np.int64),
        col_tenant=col_tenant, col_prior=col_prior,
        col_od_ms=col_od, col_local=col_local)


def make_tenant_workload(mix: Union[str, Sequence], *,
                         n_requests: int, rate_hz: float,
                         seed: int = 0) -> List[Request]:
    """Sample a multi-tenant request trace: each tenant's share of
    `n_requests` arrives as a nonhomogeneous stream over the horizon
    ``n_requests / rate_hz`` (base load plus a `burst`-times peak in a
    window centred at `phase`), with T_input drawn from the tenant's
    own fleet. Requests carry ``device_id = "<tenant>/<device>"`` (so
    per-device estimation and control stay per-tenant-population),
    the tenant tag, and the SLA class's deadline. Deterministic in
    `seed`; returned in arrival order with sequential rids."""
    return requests_from_columns(make_tenant_columns(
        mix, n_requests=n_requests, rate_hz=rate_hz, seed=seed))


def requests_from_columns(cols: TenantColumns) -> List[Request]:
    """Materialize `Request` objects from a columnar workload (arrival
    order, sequential rids — `make_tenant_workload`'s output shape)."""
    reqs: List[Request] = []
    for i in range(len(cols)):
        t = cols.tenants[cols.tenant_idx[i]]
        reqs.append(Request(
            arrival=float(cols.arrival[i]), rid=i,
            prompt=np.zeros(4, np.int32),
            max_new_tokens=4, sla_ms=t.t_sla,
            t_input_ms=float(cols.t_input[i]),
            device_id=cols.device_name(cols.col[i]), tenant=t.name))
    return reqs


def tenant_on_device_ms(tenants: Sequence[TenantSpec]
                        ) -> Dict[str, float]:
    """``"<tenant>/<device>" -> on-device latency`` for every device
    in every tenant's fleet that can serve locally (the shed targets)."""
    out: Dict[str, float] = {}
    for t in tenants:
        fleet = make_fleet(t.fleet)
        if isinstance(fleet, ArrayFleet):
            od = fleet.on_device_arrays()[0]
            for i in np.flatnonzero(od > 0):
                out[f"{t.name}/{i}"] = float(od[i])
            continue
        for d in fleet.devices:
            if d.on_device_ms > 0:
                out[f"{t.name}/{d.device_id}"] = d.on_device_ms
    return out


def tenant_priors(tenants: Sequence[TenantSpec]) -> Dict[str, float]:
    """``"<tenant>/<device>" -> long-run mean T_input`` — the
    cluster controller's cold-start references."""
    out: Dict[str, float] = {}
    for t in tenants:
        for dev, mean in make_fleet(t.fleet).priors().items():
            out[f"{t.name}/{dev}"] = mean
    return out


# --------------------------------------------------------------------------
# Cluster-wide placement
# --------------------------------------------------------------------------

class ClusterPlacer:
    """The `ModelZoo` LRU generalized to a cluster-wide memory budget.

    Each replica's zoo keeps its own hot/cold state; the placer owns
    the *global* budget. Before a replica heats a model, the globally
    least-recently-used hot copy (across all replicas, excluding the
    copy being heated) is evicted until the new copy fits. Every
    place/evict lands in the shared `events` list with the admitting
    request index — the replay-pinned record."""

    def __init__(self, replicas: Sequence, *,
                 memory_budget_bytes: Optional[int] = None,
                 events: Optional[List[dict]] = None):
        self.replicas = list(replicas)
        self.budget = memory_budget_bytes
        self.events = [] if events is None else events
        self.request = -1      # admitting request index (set by Cluster)

    def hot_bytes(self) -> int:
        return sum(r.router.zoo.hot_bytes() for r in self.replicas)

    def _global_lru(self, skip_replica, skip_name):
        best = None
        for i, r in enumerate(self.replicas):
            exclude = (skip_name,) if r is skip_replica else ()
            e = r.router.zoo.lru_hot(exclude=exclude)
            if e is not None and (best is None
                                  or e.last_used < best[2].last_used):
                best = (i, r, e)
        return best

    def ensure_hot(self, replica, name: str, now: float) -> float:
        zoo = replica.router.zoo
        entry = zoo.entries[name]
        if not entry.hot and self.budget is not None:
            size = entry.profile.size_bytes
            while self.hot_bytes() + size > self.budget:
                victim = self._global_lru(replica, name)
                if victim is None:
                    break
                vi, vr, ve = victim
                vr.router.zoo.evict(ve.profile.name)
                self.events.append({
                    "kind": "evict", "request": self.request,
                    "replica": vi, "model": ve.profile.name})
        was_cold = not entry.hot
        startup = zoo.ensure_hot(name, now, replica.rng)
        if was_cold:
            self.events.append({
                "kind": "place", "request": self.request,
                "replica": self.replicas.index(replica),
                "model": name})
        return startup


# --------------------------------------------------------------------------
# The cluster
# --------------------------------------------------------------------------

class Cluster:
    """N replicas, M tenants, one `ServingStack` (module docstring).

    `replicas` are served in index order as a prefix: `n_active` of
    them take traffic, scale events move the boundary. Replica choice
    is least-queue-delay over the active prefix (ties: higher measured
    capacity, then lower index). The cluster itself implements
    `ServingStack`, so clusters nest anywhere a stack goes."""

    def __init__(self, replicas: Sequence, tenants: Union[str, Sequence],
                 *, memory_budget_bytes: Optional[int] = None,
                 controller: Union[str, AdaptiveController,
                                   None] = "reactive",
                 hedge: bool = True, shed_factor: float = 1.0,
                 scale_headroom: float = 0.25, min_active: int = 1,
                 engine: str = "python", shards: int = 1):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        if engine not in ("python", "scan"):
            raise ValueError(f"unknown cluster engine {engine!r}; "
                             f"known: python, scan")
        self.engine = engine
        self.shards = int(shards)
        self.replicas = list(replicas)
        self.tenants = {t.name: t for t in make_tenants(tenants)}
        self.events: List[dict] = []
        self.placer = ClusterPlacer(
            self.replicas, memory_budget_bytes=memory_budget_bytes,
            events=self.events)
        for r in self.replicas:
            if hasattr(r, "attach_placer"):
                r.attach_placer(self.placer)
        self.controller = make_controller(controller)
        if self.controller is not None:
            self.controller.prime(tenant_priors(self.tenants.values()))
        self.on_device_ms = tenant_on_device_ms(self.tenants.values())
        self.hedge = bool(hedge)
        self.shed_factor = float(shed_factor)
        self.scale_headroom = float(scale_headroom)
        self.min_active = max(1, min(int(min_active),
                                     len(self.replicas)))
        self.n_active = self.min_active
        self.metrics = ServingMetrics()
        self._n = 0               # requests admitted
        self._seen_switches = 0   # controller events already applied
        # Per-replica queue/capacity caches (None = stale). Replica
        # queue state only moves on submit/drain, so `submit` reads
        # cached `free_time` snapshots instead of recomputing O(R)
        # queue delays per request, and invalidates only the replicas
        # it touched. The delay expression is the replica's own
        # (max(0, free - arrive)), so cached decisions are bit-for-bit
        # the uncached ones (pinned by tests/test_cluster_engine.py).
        self._free_cache: List[Optional[float]] = [None] * len(replicas)
        self._cap_cache: List[Optional[float]] = [None] * len(replicas)

    # -- replica surface (lets clusters nest inside clusters) ---------
    @property
    def free_time(self) -> float:
        """Earliest child free-up — the raw queue state a parent
        cluster caches (min is monotone through max(0, .-now), so
        deriving the delay from this matches `queue_delay` bitwise)."""
        return min(r.free_time for r in self.replicas[:self.n_active])

    def queue_delay(self, now: float) -> float:
        """The best delay an arriving request would see here."""
        return min(r.queue_delay(now)
                   for r in self.replicas[:self.n_active])

    def capacity_score(self) -> float:
        return sum(r.capacity_score()
                   for r in self.replicas[:self.n_active])

    def _replica_delay(self, j: int, arrive: float) -> float:
        f = self._free_cache[j]
        if f is None:
            f = getattr(self.replicas[j], "free_time", None)
            if f is None:     # stack without queue-state exposure
                return self.replicas[j].queue_delay(arrive)
            self._free_cache[j] = f
        return max(0.0, f - arrive)

    def _replica_capacity(self, j: int) -> float:
        c = self._cap_cache[j]
        if c is None:
            c = self._cap_cache[j] = self.replicas[j].capacity_score()
        return c

    def _invalidate(self, j: int) -> None:
        self._free_cache[j] = None
        self._cap_cache[j] = None

    # -- scaling ------------------------------------------------------
    def _scale(self, delta: int, reason: str):
        new = min(max(self.n_active + delta, self.min_active),
                  len(self.replicas))
        if new == self.n_active:
            return
        self.events.append({
            "kind": "scale_up" if delta > 0 else "scale_down",
            "request": self._n, "n_active": new, "reason": reason})
        self.n_active = new

    def _apply_switches(self):
        """Controller mode switches drive replica scaling: an
        escalation (up-alarm) adds a replica, a recovery retires one.
        Events are consumed in order, once."""
        # Read the raw event list: the `events` property copies every
        # dict, which is O(total switches) per submit — O(N*S) over a
        # run. The tail is only read here, never mutated.
        ev = self.controller._events
        for e in ev[self._seen_switches:]:
            self._scale(1 if e["alarm"] > 0 else -1,
                        reason=f"switch:{e['device']}")
        self._seen_switches = len(ev)

    # -- ServingStack -------------------------------------------------
    def submit(self, req: Request, *, now: float = 0.0) -> StackOutcome:
        t = self.tenants.get(req.tenant or "")
        t_sla = req.sla_ms or (t.t_sla if t is not None else 1e9)
        self.placer.request = i = self._n
        self._n += 1
        mode = None
        if self.controller is not None:
            mode = self.controller.observe(req.device_id,
                                           req.t_input_ms)
            self._apply_switches()
        mode_name = mode.name if mode is not None else "static"
        degraded = bool(mode.degraded) if mode is not None else False
        arrive = now + req.t_input_ms
        delays = [self._replica_delay(j, arrive)
                  for j in range(self.n_active)]
        # Load-driven scale-up: queueing alone would eat the headroom
        # share of the SLA on every active replica.
        if (min(delays) > self.scale_headroom * t_sla
                and self.n_active < len(self.replicas)):
            self._scale(1, reason="load")
            delays.append(self._replica_delay(self.n_active - 1, arrive))
        # Load shedding: the cluster is saturated past the SLA itself;
        # a device with a local model serves on-device instead of
        # joining a doomed queue. Higher `shed_priority` classes need
        # proportionally deeper saturation before they shed (bronze
        # sheds first, gold last), and a shed whose local latency
        # already misses the SLA is only taken when the queue is
        # hopeless at twice the shed threshold (both paths miss, but
        # shedding protects the rest of the cluster).
        prio = t.shed_priority if t is not None else 0
        thresh = self.shed_factor * t_sla * (1 + prio)
        if min(delays) > thresh:
            od = self.on_device_ms.get(req.device_id or "", 0.0)
            if od > 0 and (od <= t_sla or min(delays) > 2 * thresh):
                self.events.append({
                    "kind": "shed", "request": i,
                    "tenant": req.tenant or "",
                    "device": req.device_id or ""})
                ok = od <= t_sla
                self.metrics.add(req, "<on-device>", mode=mode_name,
                                 e2e_ms=od, ok=ok, fallback=True)
                return StackOutcome("<on-device>", mode=mode_name,
                                    e2e_ms=od, ok=ok,
                                    tenant=req.tenant, fallback=True)
        order = sorted(
            range(self.n_active),
            key=lambda j: (delays[j], -self._replica_capacity(j), j))
        j = order[0]
        out = self.replicas[j].submit(req, now=now)
        self._invalidate(j)
        hedged = False
        if degraded and self.hedge and len(order) > 1:
            # Cross-replica hedge (MDInference): duplicate to the
            # second-least-loaded replica, first completion wins. Both
            # replicas' clocks advance — duplication costs capacity,
            # which is why only degraded-regime requests pay it.
            j2 = order[1]
            out2 = self.replicas[j2].submit(req, now=now)
            self._invalidate(j2)
            hedged = True
            if (out2.e2e_ms is not None and out.e2e_ms is not None
                    and out2.e2e_ms < out.e2e_ms):
                out, j = out2, j2
        win = (self.replicas[j].metrics.records[-1]
               if getattr(self.replicas[j], "metrics", None)
               and self.replicas[j].metrics.records else {})
        self.metrics.add(req, out.model,
                         queue_ms=win.get("queue_ms", 0.0),
                         exec_ms=win.get("exec_ms", 0.0),
                         mode=mode_name, e2e_ms=out.e2e_ms, ok=out.ok,
                         accuracy=win.get("accuracy"), hedged=hedged,
                         replica=j)
        return StackOutcome(out.model, mode=mode_name,
                            e2e_ms=out.e2e_ms, ok=out.ok,
                            tenant=req.tenant, hedged=hedged)

    def drain(self) -> None:
        for i, r in enumerate(self.replicas):
            r.drain()
            self._invalidate(i)

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        for i, r in enumerate(self.replicas):
            r.observe_outcome(name, latency_ms, cold=cold, now=now)
            self._invalidate(i)

    # -- convenience --------------------------------------------------
    def run(self, requests) -> ServingMetrics:
        """Serve a workload — a `Request` sequence or a
        `TenantColumns` — through the configured engine. The scan
        engine (serving/cluster_engine.py) reproduces the python
        loop's events and metrics bit-for-bit."""
        if self.engine == "scan":
            from repro.serving.cluster_engine import scan_cluster_run
            scan_cluster_run(self, requests, shards=self.shards)
            return self.metrics
        if isinstance(requests, TenantColumns):
            requests = requests_from_columns(requests)
        for req in sorted(requests, key=lambda r: r.arrival):
            self.submit(req, now=req.arrival)
        self.drain()
        return self.metrics


# --------------------------------------------------------------------------
# Capture / replay (the PR 5 switch-event discipline, cluster-wide)
# --------------------------------------------------------------------------

def capture_run(cluster: Cluster, requests: Sequence[Request], *,
                name: str = "cluster"):
    """Run a workload through the cluster and capture it as a `Trace`:
    the per-request workload columns plus
    ``meta["cluster_events"]`` (placement / eviction / scale / shed in
    submit order), ``meta["sla_ms"]`` (per-request deadlines), and
    ``meta["tenants"]`` — everything `requests_from_cluster_trace`
    needs to rebuild the workload and `replay_events` to verify the
    decisions replay bit-for-bit."""
    from repro.serving.trace import TraceRecorder
    rec = TraceRecorder()
    ordered = sorted(requests, key=lambda r: r.arrival)
    for req in ordered:
        cluster.submit(req, now=req.arrival)
        row = cluster.metrics.records[-1]
        rec.record(t_arrival=req.arrival, t_input_ms=req.t_input_ms,
                   device_id=req.device_id, model=row["model"],
                   sla_ok=row["ok"])
    cluster.drain()
    return rec.to_trace(
        name=name, source="cluster",
        meta={"cluster_events": cluster.events,
              "sla_ms": [float(r.sla_ms) for r in ordered],
              "tenants": sorted({r.tenant for r in ordered
                                 if r.tenant})})


def requests_from_cluster_trace(trace) -> List[Request]:
    """Rebuild the captured workload (arrival order; tenant recovered
    from the ``<tenant>/<device>`` id convention)."""
    sla = trace.meta["sla_ms"]
    out = []
    for i in range(len(trace)):
        dev = str(trace.device_id[i])
        tenant = dev.split("/", 1)[0] if "/" in dev else None
        out.append(Request(
            arrival=float(trace.t_arrival[i]), rid=i,
            prompt=np.zeros(4, np.int32), max_new_tokens=4,
            sla_ms=float(sla[i]),
            t_input_ms=float(trace.t_input_ms[i]),
            device_id=dev or None, tenant=tenant))
    return out


def replay_events(trace, make_cluster) -> bool:
    """Replay verification: rebuild the workload from `trace`, run it
    through a fresh cluster from the `make_cluster` factory, and
    compare the event log bit-for-bit against
    ``meta["cluster_events"]``."""
    cluster = make_cluster()
    cluster.run(requests_from_cluster_trace(trace))
    return cluster.events == trace.meta["cluster_events"]
