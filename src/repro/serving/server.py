"""The CNNSelect-fronted multi-model server (paper §5 end-to-end system).

Manages a zoo of real engines (small models on CPU here; pod-sharded on
the TPU target) and serves each request batch-of-one through the shared
per-request control step (`serving/control.py`, DESIGN.md §12):
estimate the remaining budget from the observed upload time, select a
model, execute, and record SLA attainment + the measured latency back
through the plane. With a `controller`, the server detects per-device
regime shifts online and switches its operating mode live — a
degraded-mode request whose device can serve locally is answered with
an on-device advisory (the MDInference duality at the prototype layer:
the server instructs the device to run its local model instead of
executing in the cloud)."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.selection import ModelProfile, Policy
from repro.serving.batching import Request
from repro.serving.control import ControlPlane
from repro.serving.engine import InferenceEngine
from repro.serving.metrics import ServingMetrics
from repro.serving.router import Router
from repro.serving.stack import StackOutcome


@dataclass
class ServedModel:
    name: str
    engine: InferenceEngine
    accuracy: float            # task accuracy measured offline
    size_bytes: int = 0


class ServerMetrics(ServingMetrics):
    """The server's ledger — now the unified `ServingMetrics` schema
    (serving/metrics.py); kept as a named subclass so `ServerMetrics`
    imports and `type(server.metrics)()` reconstruction keep working.
    The pre-unification counter fields live on as deprecated alias
    properties on the base class."""


class CNNSelectServer:
    def __init__(self, models: List[ServedModel], *, t_threshold: float,
                 policy="cnnselect", seed: int = 0,
                 n_tokens: int = 8, stage2_variant: str = "figure",
                 t_estimator=None, controller=None,
                 on_device_ms: Optional[Dict[str, float]] = None):
        self.models = {m.name: m for m in models}
        self.order = [m.name for m in models]
        self.n_tokens = n_tokens
        self.router = Router(policy=policy, t_threshold=t_threshold,
                             stage2_variant=stage2_variant, seed=seed,
                             min_sigma=0.5, t_estimator=t_estimator)
        for m in models:
            # mu=0: latency priors arrive online via profile_models().
            self.router.register(ModelProfile(
                name=m.name, accuracy=m.accuracy, mu=0.0, sigma=0.0,
                size_bytes=m.size_bytes))
        # The shared per-request control step (DESIGN.md §12).
        # `controller` is a CONTROLLER_SCENARIOS name or an
        # AdaptiveController; `on_device_ms` maps device ids to their
        # local-model latency (enables on-device advisories when a
        # degraded-mode device's cloud path cannot meet the SLA).
        self.control = ControlPlane(self.router, controller=controller,
                                    seed=seed, t_threshold=t_threshold,
                                    stage2_variant=stage2_variant)
        self.on_device_ms = dict(on_device_ms or {})
        self.metrics = ServerMetrics()
        # Optional trace capture (serving/trace.py, DESIGN.md §11):
        # `handle` records each served request, outcome included.
        self.recorder = None

    @property
    def store(self):
        return self.router.store

    @property
    def policy(self) -> Policy:
        return self.router.policy

    def profile_models(self, prompt_len: int = 16, reps: int = 5):
        """Measure each engine's hot latency (paper: profiles measured and
        managed by the inference server)."""
        for name, m in self.models.items():
            m.engine.warmup(prompt_len)
            p = m.engine.measured_profile(prompt_len, self.n_tokens, reps)
            # The router's min_sigma floor owns the clamp.
            self.router.set_profile(name, p["mu"], p["sigma"])
        self.router.prewarm()

    def current_profiles(self) -> List[ModelProfile]:
        return self.router.current_profiles()

    def select(self, t_sla: float, t_input: float,
               device_id: Optional[str] = None) -> str:
        """One control step (estimate → maybe adapt → select) through
        the shared plane; the static plane is exactly the pre-plane
        behaviour — budget from the observed upload time via the
        router's estimator, then select."""
        return self.control.step(t_sla, t_input,
                                 device_id=device_id).name

    def handle(self, req: Request, t_sla: float) -> dict:
        """Serve one request batch-of-one style (the prototype evaluation
        path, Fig 12). Returns the per-request record."""
        d = self.control.step(
            t_sla, req.t_input_ms, device_id=req.device_id,
            on_device_ms=self.on_device_ms.get(req.device_id or "", 0.0))
        if d.fallback:
            # On-device advisory: the device serves locally; no upload,
            # no cloud execution. Charged the device's known local
            # latency.
            e2e = self.on_device_ms[req.device_id or ""]
            ok = e2e <= t_sla
            self.metrics.add(req, d.name, mode=d.mode, e2e_ms=e2e,
                             ok=ok, fallback=True)
            if self.recorder is not None:
                self.recorder.record_request(req, model=d.name,
                                             sla_ok=ok)
            return {"model": d.name, "e2e_ms": e2e, "ok": ok,
                    "device": req.device_id, "mode": d.mode,
                    "tokens": []}
        name = d.name
        m = self.models[name]
        t0 = time.perf_counter()
        B = m.engine.batch_size
        prompts = np.tile(req.prompt[None, :], (B, 1)).astype(np.int32)
        toks = m.engine.generate(prompts, self.n_tokens)
        exec_ms = (time.perf_counter() - t0) * 1000.0
        self.control.observe_outcome(name, exec_ms)
        e2e = req.t_input_ms * 2.0 + exec_ms
        ok = e2e <= t_sla
        self.metrics.add(req, name, exec_ms=exec_ms, mode=d.mode,
                         e2e_ms=e2e, ok=ok, accuracy=m.accuracy)
        if self.recorder is not None:
            self.recorder.record_request(req, model=name, sla_ok=ok,
                                         exec_ms=exec_ms)
        return {"model": name, "e2e_ms": e2e, "ok": ok,
                "device": req.device_id, "mode": d.mode,
                "tokens": toks[0].tolist()}

    # -- ServingStack (serving/stack.py, DESIGN.md §16) ---------------

    def submit(self, req: Request, *, now: float = 0.0) -> StackOutcome:
        """Protocol admission: serve inline against the request's own
        SLA (``sla_ms == 0`` means no SLA)."""
        rec = self.handle(req, t_sla=req.sla_ms or 1e9)
        return StackOutcome(
            model=rec["model"], mode=rec["mode"], e2e_ms=rec["e2e_ms"],
            ok=rec["ok"], tenant=req.tenant,
            fallback=rec["model"] == "<on-device>")

    def drain(self) -> None:
        """Batch-of-one execution — nothing queued across submits."""

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        self.control.observe_outcome(name, latency_ms, cold=cold,
                                     now=now)
