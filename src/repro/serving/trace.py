"""Trace capture & replay: record real T_input sequences from the
serving stacks and replay them through the simulator (the sim-to-real
loop, ROADMAP "Trace capture").

The paper's core claim is that *variable* mobile network conditions
dominate cloud-based inference end-to-end time; ModiPick
(arXiv:1909.02053) and MDInference (arXiv:2002.06603) both evaluate
against *recorded* mobile network traces, not stationary assumptions.
Until now our `TraceReplayProcess` only ever replayed synthetic traces —
this module closes the gap:

- **`Trace`** — a versioned on-disk capture format: per-request
  ``(t_arrival, device_id, t_input_ms, regime_id, model, sla_ok)``
  columns plus a metadata header (schema version, source, regime
  names, free-form ``meta``). `save`/`load` round-trip bit-exact
  through two codecs, JSONL (line-oriented, diff-able, the committed
  reference format) and npz (binary, compact).
- **`TraceRecorder`** — hooks the live serving layers
  (`CNNSelectServer.handle`, `ServingLoop.run`, `Router.submit`) via
  their ``recorder`` attribute and accumulates records; `to_trace()`
  snapshots a `Trace`.
- **`CapturedTraceProcess`** — a `NetworkProcess` that replays a
  capture bit-for-bit (``mode="exact"``, including regime ids so
  `per_regime` attainment composes), or resampled: ``loop`` (cycle),
  ``bootstrap`` (block bootstrap, preserving local autocorrelation),
  ``timewarp:<factor>`` (stretch/compress regime dwell times).
- **`FleetMixture.from_capture`** (serving/fleet.py) — reconstructs
  per-device `DeviceProfile`s from a multi-device capture so recorded
  fleets replay through the device-keyed `EstimatorBank` path.

Named captures live in `configs/paper_zoo.CAPTURE_SCENARIOS` (files
under ``src/repro/configs/traces/``) and resolve through
``make_network("capture:<name>")`` / ``trace:<name>``. The
capture→persist→replay round trip is pinned in CI
(`benchmarks/trace_replay.py --check`). See DESIGN.md §11.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.serving.network import NetworkProcess

# Bump when the on-disk column set / header layout changes; `load`
# fails fast on any other version so old builds never misread captures.
TRACE_SCHEMA_VERSION = 1
_TRACE_KIND = "repro.trace"

# sla_ok is tri-state: admission-time hooks (Router.submit) cannot know
# the outcome yet.
SLA_UNKNOWN, SLA_MISS, SLA_MET = -1, 0, 1

CAPTURE_MODES = ("exact", "loop", "bootstrap", "timewarp")


@dataclass
class Trace:
    """One captured serving run: parallel per-request columns plus the
    header metadata that makes the capture self-describing."""

    t_arrival: np.ndarray              # (N,) float64 ms
    device_id: np.ndarray              # (N,) str ("" = untagged)
    t_input_ms: np.ndarray             # (N,) float64 ms
    regime_id: np.ndarray              # (N,) int64
    model: np.ndarray                  # (N,) str ("" = not yet routed)
    sla_ok: np.ndarray                 # (N,) int8, SLA_UNKNOWN/MISS/MET
    regime_names: List[str] = field(default_factory=lambda: ["live"])
    name: str = "capture"
    source: str = "unknown"            # server | loop | router | simulator
    meta: Dict = field(default_factory=dict)
    schema_version: int = TRACE_SCHEMA_VERSION

    # Fixed-width numpy unicode columns (npz-friendly); longer strings
    # must be rejected, never silently truncated — truncation could
    # merge distinct device keys.
    MAX_STR = 64

    @classmethod
    def _str_col(cls, values, col: str) -> np.ndarray:
        arr = np.asarray(values, dtype=np.str_)
        if arr.dtype.itemsize // 4 > cls.MAX_STR:
            raise ValueError(f"trace {col} strings must be <= "
                             f"{cls.MAX_STR} chars (truncating could "
                             f"merge distinct keys)")
        return arr.astype(f"U{cls.MAX_STR}")

    def __post_init__(self):
        self.t_arrival = np.asarray(self.t_arrival, np.float64)
        self.device_id = self._str_col(self.device_id, "device_id")
        self.t_input_ms = np.asarray(self.t_input_ms, np.float64)
        self.regime_id = np.asarray(self.regime_id, np.int64)
        self.model = self._str_col(self.model, "model")
        self.sla_ok = np.asarray(self.sla_ok, np.int8)
        self.validate()

    def validate(self):
        n = len(self.t_input_ms)
        for col in ("t_arrival", "device_id", "regime_id", "model",
                    "sla_ok"):
            if len(getattr(self, col)) != n:
                raise ValueError(f"trace column {col!r} has "
                                 f"{len(getattr(self, col))} rows, "
                                 f"expected {n}")
        if n == 0:
            raise ValueError("trace must hold at least one request")
        # NaN passes a `<= 0` test and would replay as an always-met
        # SLA (NaN latency compares False) — reject non-finite values
        # at the load/construction boundary.
        if not np.isfinite(self.t_input_ms).all() or (
                self.t_input_ms <= 0).any():
            raise ValueError("trace t_input_ms must be positive and "
                             "finite")
        if not np.isfinite(self.t_arrival).all():
            raise ValueError("trace t_arrival must be finite")
        if (self.regime_id < 0).any():
            raise ValueError("trace regime ids must be non-negative")
        if int(self.regime_id.max()) >= len(self.regime_names):
            raise ValueError(
                f"trace regime id {int(self.regime_id.max())} has no "
                f"name; regime_names covers {len(self.regime_names)}")
        bad = set(np.unique(self.sla_ok)) - {SLA_UNKNOWN, SLA_MISS,
                                             SLA_MET}
        if bad:
            raise ValueError(f"trace sla_ok values must be -1/0/1, "
                             f"got {sorted(bad)}")

    def __len__(self) -> int:
        return len(self.t_input_ms)

    # -- derived views ------------------------------------------------------

    @property
    def attainment(self) -> float:
        """SLA attainment over the requests whose outcome is known."""
        known = self.sla_ok != SLA_UNKNOWN
        if not known.any():
            return float("nan")
        return float((self.sla_ok[known] == SLA_MET).mean())

    def device_ids(self) -> List[str]:
        """Distinct issuing devices, in first-appearance order."""
        _, first = np.unique(self.device_id, return_index=True)
        return [str(self.device_id[i]) for i in sorted(first)]

    def per_device(self) -> Dict[str, np.ndarray]:
        """device_id -> row indices (order preserved)."""
        return {d: np.flatnonzero(self.device_id == d)
                for d in self.device_ids()}

    def header(self) -> Dict:
        return {
            "kind": _TRACE_KIND,
            "schema": int(self.schema_version),
            "name": self.name,
            "source": self.source,
            "n": len(self),
            "regime_names": list(self.regime_names),
            "meta": self.meta,
        }

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_sim(cls, result, *, name: str = "simulated",
                 meta: Optional[Dict] = None) -> "Trace":
        """Capture a `SimResult` (the simulator records its workload —
        `t_inputs`/`arrivals` — exactly for this). Model names are the
        selection indices' names when the caller stored them in
        `meta["models"]`; otherwise the raw index as text."""
        if result.t_inputs is None or result.arrivals is None:
            raise ValueError("SimResult carries no workload capture "
                             "(t_inputs/arrivals); re-run simulate()")
        n = len(result.t_inputs)
        models = (meta or {}).get("models")
        sel = np.asarray(result.selections, np.int64)
        if models is not None:
            name_of = np.asarray(list(models) + ["<on-device>"],
                                 np.str_)
            model_col = name_of[np.where(sel < 0, len(models), sel)]
        else:
            model_col = np.array([str(int(s)) for s in sel], np.str_)
        if result.device_index is not None and result.device_ids:
            dev = np.asarray(result.device_ids,
                             np.str_)[result.device_index]
        else:
            dev = np.full(n, "", np.str_)
        regimes = (result.regimes if result.regimes is not None
                   else np.zeros(n, np.int64))
        rnames = (list(result.regime_names) if result.regime_names
                  else ["live"])
        meta = dict(meta or {})
        if getattr(result, "switch_events", None):
            # Online-control adaptations (DESIGN.md §12): the
            # controller's mode-switch events ride in the capture, so a
            # replay can verify it reproduces the same adaptation
            # sequence (and analysis can line switches up with the
            # recorded regimes).
            meta.setdefault("control_events",
                            [dict(e) for e in result.switch_events])
            if result.mode_names is not None:
                meta.setdefault("control_modes",
                                list(result.mode_names))
        return cls(
            t_arrival=result.arrivals, device_id=dev,
            t_input_ms=result.t_inputs, regime_id=regimes,
            model=model_col,
            sla_ok=np.where(result.violations, SLA_MISS, SLA_MET).astype(
                np.int8),
            regime_names=rnames, name=name, source="simulator",
            meta=meta)

    # -- codecs -------------------------------------------------------------

    def save(self, path: Union[str, os.PathLike]) -> None:
        """Write the capture; the codec is chosen by extension
        (``.jsonl`` line-oriented text, ``.npz`` binary). Both
        round-trip bit-exact (json float text is shortest-repr, which
        python parses back to the identical double)."""
        path = os.fspath(path)
        if path.endswith(".jsonl"):
            self._save_jsonl(path)
        elif path.endswith(".npz"):
            self._save_npz(path)
        else:
            raise ValueError(f"unknown trace extension for {path!r}; "
                             f"use .jsonl or .npz")

    @classmethod
    def load(cls, path: Union[str, os.PathLike]) -> "Trace":
        path = os.fspath(path)
        if path.endswith(".jsonl"):
            return cls._load_jsonl(path)
        if path.endswith(".npz"):
            return cls._load_npz(path)
        raise ValueError(f"unknown trace extension for {path!r}; "
                         f"use .jsonl or .npz")

    def _save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps(self.header(), sort_keys=True) + "\n")
            for i in range(len(self)):
                f.write(json.dumps({
                    "t": float(self.t_arrival[i]),
                    "d": str(self.device_id[i]),
                    "ti": float(self.t_input_ms[i]),
                    "r": int(self.regime_id[i]),
                    "m": str(self.model[i]),
                    "ok": int(self.sla_ok[i]),
                }, sort_keys=True) + "\n")

    @classmethod
    def _load_jsonl(cls, path: str) -> "Trace":
        with open(path) as f:
            header = cls._check_header(json.loads(f.readline()), path)
            rows = [json.loads(line) for line in f if line.strip()]
        if len(rows) != header["n"]:
            raise ValueError(f"trace {path!r} declares {header['n']} "
                             f"requests but holds {len(rows)}")
        return cls(
            t_arrival=np.array([r["t"] for r in rows], np.float64),
            device_id=np.array([r["d"] for r in rows], np.str_),
            t_input_ms=np.array([r["ti"] for r in rows], np.float64),
            regime_id=np.array([r["r"] for r in rows], np.int64),
            model=np.array([r["m"] for r in rows], np.str_),
            sla_ok=np.array([r["ok"] for r in rows], np.int8),
            regime_names=list(header["regime_names"]),
            name=header["name"], source=header["source"],
            meta=header["meta"], schema_version=header["schema"])

    def _save_npz(self, path: str) -> None:
        np.savez(path, header=np.array(
            json.dumps(self.header(), sort_keys=True)),
            t_arrival=self.t_arrival, device_id=self.device_id,
            t_input_ms=self.t_input_ms, regime_id=self.regime_id,
            model=self.model, sla_ok=self.sla_ok)

    @classmethod
    def _load_npz(cls, path: str) -> "Trace":
        with np.load(path) as z:
            header = cls._check_header(json.loads(str(z["header"])), path)
            return cls(
                t_arrival=z["t_arrival"], device_id=z["device_id"],
                t_input_ms=z["t_input_ms"], regime_id=z["regime_id"],
                model=z["model"], sla_ok=z["sla_ok"],
                regime_names=list(header["regime_names"]),
                name=header["name"], source=header["source"],
                meta=header["meta"], schema_version=header["schema"])

    @staticmethod
    def _check_header(header: Dict, path: str) -> Dict:
        if header.get("kind") != _TRACE_KIND:
            raise ValueError(f"{path!r} is not a {_TRACE_KIND} capture "
                             f"(kind={header.get('kind')!r})")
        if header.get("schema") != TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"trace {path!r} has schema version "
                f"{header.get('schema')!r}; this build reads version "
                f"{TRACE_SCHEMA_VERSION} — re-capture it or load with "
                f"a matching build")
        return header


# --------------------------------------------------------------------------
# Live capture (the serving-layer hooks)
# --------------------------------------------------------------------------

class TraceRecorder:
    """Accumulates per-request records from the live serving layers.

    Attach with `attach(target)` — `CNNSelectServer`, `ServingLoop`,
    and `Router` all expose a ``recorder`` attribute their hot path
    consults — or feed records directly via `record(...)`. Layers that
    only see admission (`Router.submit`) record ``sla_ok=None``
    (stored as `SLA_UNKNOWN`); outcome-aware layers record the bool.
    """

    def __init__(self, *, name: str = "capture"):
        self.name = name
        self._rows: List[tuple] = []
        self._exec: List[Optional[float]] = []
        self._attached: List[object] = []

    def __len__(self) -> int:
        return len(self._rows)

    def record(self, *, t_arrival: float, t_input_ms: float,
               device_id: Optional[str] = None, regime_id: int = 0,
               model: str = "", sla_ok: Optional[bool] = None,
               exec_ms: Optional[float] = None) -> None:
        if not t_input_ms > 0:
            # Fail at the offending request, not at to_trace() after the
            # whole run is captured and unrecoverable. (Request defaults
            # t_input_ms to 0.0 — a capture needs it set.)
            raise ValueError(f"capture needs a positive t_input_ms, got "
                             f"{t_input_ms!r} (set Request.t_input_ms)")
        for col, v in (("device_id", device_id or ""), ("model", model)):
            if len(str(v)) > Trace.MAX_STR:
                raise ValueError(f"capture {col} {str(v)[:20]!r}... "
                                 f"exceeds {Trace.MAX_STR} chars")
        ok = SLA_UNKNOWN if sla_ok is None else (
            SLA_MET if sla_ok else SLA_MISS)
        self._rows.append((float(t_arrival), str(device_id or ""),
                           float(t_input_ms), int(regime_id),
                           str(model), ok))
        # Measured execution time is a side channel (outcome-aware
        # layers only): when every row has one, `to_trace` exports it
        # as meta["exec_ms"] so replays can inject the measured times.
        self._exec.append(None if exec_ms is None else float(exec_ms))

    def record_request(self, req, *, model: str = "",
                       sla_ok: Optional[bool] = None,
                       exec_ms: Optional[float] = None) -> None:
        """Record a `serving.batching.Request` (the shape every layer
        hook holds when it fires)."""
        self.record(t_arrival=req.arrival, t_input_ms=req.t_input_ms,
                    device_id=req.device_id, model=model, sla_ok=sla_ok,
                    exec_ms=exec_ms)

    def attach(self, target) -> "TraceRecorder":
        """Hook a serving layer: sets ``target.recorder = self``
        (`CNNSelectServer`, `ServingLoop`, `Router` all consult it).
        A `ServingLoop`/`CNNSelectServer` also covers its own router —
        attaching both would double-record admissions."""
        if not hasattr(target, "recorder"):
            raise ValueError(f"{type(target).__name__} exposes no "
                             f"recorder hook")
        target.recorder = self
        self._attached.append(target)
        return self

    def detach(self) -> None:
        for t in self._attached:
            t.recorder = None
        self._attached.clear()

    def __enter__(self) -> "TraceRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.detach()

    def clear(self) -> None:
        self._rows.clear()
        self._exec.clear()

    def to_trace(self, *, name: Optional[str] = None,
                 source: str = "server",
                 regime_names: Optional[Sequence[str]] = None,
                 meta: Optional[Dict] = None) -> Trace:
        if not self._rows:
            raise ValueError("recorder holds no requests yet")
        cols = list(zip(*self._rows))
        n_regimes = max(cols[3]) + 1
        names = (list(regime_names) if regime_names is not None
                 else ["live"] if n_regimes == 1
                 else [f"live:{k}" for k in range(n_regimes)])
        meta = dict(meta or {})
        if all(e is not None for e in self._exec):
            meta.setdefault("exec_ms", list(self._exec))
        return Trace(
            t_arrival=np.array(cols[0], np.float64),
            device_id=np.array(cols[1], "U64"),
            t_input_ms=np.array(cols[2], np.float64),
            regime_id=np.array(cols[3], np.int64),
            model=np.array(cols[4], "U64"),
            sla_ok=np.array(cols[5], np.int8),
            regime_names=names, name=name or self.name, source=source,
            meta=meta)


# --------------------------------------------------------------------------
# Replay (captures as NetworkProcesses)
# --------------------------------------------------------------------------

class CapturedTraceProcess(NetworkProcess):
    """Replay a captured T_input sequence as a `NetworkProcess`.

    Modes:
    - ``exact`` — bit-for-bit: position i replays the capture's request
      i (t_input *and* regime id, so `per_regime` attainment composes);
      asking for more requests than the capture holds fails fast.
    - ``loop`` — cycle the capture (the `TraceReplayProcess` behaviour,
      but over measured samples, jitter-free).
    - ``bootstrap`` — block bootstrap: concatenate random blocks of
      `block` consecutive captured requests, preserving the local
      autocorrelation (regime dwells) stationary resampling would lose.
    - ``timewarp:<factor>`` — stretch (>1) or compress (<1) dwell
      times: replay position i reads capture position ``i/factor``,
      cycling — the same dynamics, slower or faster.
    """

    def __init__(self, trace: Union[Trace, Sequence[float], np.ndarray],
                 *, mode: str = "exact", block: int = 64,
                 name: Optional[str] = None,
                 regimes: Optional[np.ndarray] = None,
                 regime_names: Optional[Sequence[str]] = None):
        head, _, arg = str(mode).partition(":")
        if head not in CAPTURE_MODES:
            raise ValueError(f"unknown capture replay mode {mode!r}; "
                             f"known: {', '.join(CAPTURE_MODES)} "
                             f"(timewarp takes ':<factor>')")
        if head == "timewarp":
            self.factor = float(arg) if arg else 1.0
            if self.factor <= 0:
                raise ValueError(f"timewarp factor must be positive, "
                                 f"got {self.factor}")
        elif arg:
            raise ValueError(f"mode {head!r} takes no ':{arg}' argument "
                             f"(only timewarp:<factor> does)")
        if isinstance(trace, Trace):
            if regimes is not None or regime_names is not None:
                raise ValueError("a Trace carries its own regimes; "
                                 "pass regimes only with a raw array")
            self._t = trace.t_input_ms.copy()
            self._regimes = trace.regime_id.copy()
            self._names = list(trace.regime_names)
            default_name = f"capture:{trace.name}"
        else:
            self._t = np.asarray(trace, np.float64)
            if self._t.ndim != 1 or len(self._t) == 0:
                raise ValueError("trace must be a non-empty 1-D array")
            if not np.isfinite(self._t).all() or (self._t <= 0).any():
                raise ValueError("trace t_input values must be positive "
                                 "and finite")
            if regimes is None:
                self._regimes = np.zeros(len(self._t), np.int64)
                self._names = (list(regime_names) if regime_names
                               else ["capture"])
            else:
                self._regimes = np.asarray(regimes, np.int64)
                if len(self._regimes) != len(self._t):
                    raise ValueError("regimes must align with the trace")
                if (self._regimes < 0).any():
                    raise ValueError("regime ids must be non-negative")
                n_reg = int(self._regimes.max()) + 1
                self._names = (list(regime_names) if regime_names
                               else [f"capture:{k}" for k in range(n_reg)])
                if len(self._names) < n_reg:
                    raise ValueError("regime_names must cover every "
                                     "regime id")
            default_name = "capture"
        self.mode = head
        self.block = int(block)
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        self.name = name or default_name

    def __len__(self) -> int:
        return len(self._t)

    def sample_trace(self, rng: np.random.Generator, n: int = 1):
        # Skip the base-class MIN_T_INPUT_MS clamp: captured values are
        # validated positive at construction, and clamping would
        # silently rewrite sub-1ms measurements — breaking the
        # bit-for-bit exact-replay contract.
        return self._raw_trace(rng, int(n))

    @property
    def mean(self) -> float:
        return float(self._t.mean())

    def regime_names(self) -> List[str]:
        return list(self._names)

    def _positions(self, rng: np.random.Generator, n: int) -> np.ndarray:
        L = len(self._t)
        if self.mode == "exact":
            if n > L:
                raise ValueError(
                    f"exact replay of {self.name!r} holds {L} requests "
                    f"but {n} were asked; use mode='loop' or "
                    f"'bootstrap' to extend it")
            return np.arange(n)
        if self.mode == "loop":
            return np.arange(n) % L
        if self.mode == "timewarp":
            return (np.arange(n) / self.factor).astype(np.int64) % L
        # bootstrap: random block starts, wrapped, until n covered.
        b = min(self.block, L)
        starts = rng.integers(0, L, size=n // b + 1)
        pos = (starts[:, None] + np.arange(b)[None, :]).ravel() % L
        return pos[:n]

    def _raw_trace(self, rng, n):
        pos = self._positions(rng, n)
        return self._t[pos].copy(), self._regimes[pos].copy()


def load_capture(name_or_path: Union[str, os.PathLike]) -> Trace:
    """Load a capture: a registered `CAPTURE_SCENARIOS` name or a
    direct ``.jsonl``/``.npz`` path."""
    from repro.configs.paper_zoo import capture_path
    p = os.fspath(name_or_path)
    if not (p.endswith(".jsonl") or p.endswith(".npz")):
        p = capture_path(p)
    return Trace.load(p)


def requests_from_trace(trace: Trace, *, prompt_len: int = 8,
                        max_new_tokens: int = 4, sla_ms: float = 0.0,
                        vocab: int = 50, seed: int = 0) -> List:
    """Materialize a capture as `serving.batching.Request`s (synthetic
    prompts; arrival/device/t_input from the capture) so recorded
    workloads replay through the *real* stacks (`ServingLoop.run`,
    `CNNSelectServer.handle`) too, not just the simulator."""
    from repro.serving.batching import Request
    rng = np.random.default_rng(seed)
    return [Request(
        arrival=float(trace.t_arrival[i]), rid=i,
        prompt=rng.integers(0, vocab, prompt_len).astype(np.int32),
        max_new_tokens=max_new_tokens, sla_ms=sla_ms,
        t_input_ms=float(trace.t_input_ms[i]),
        device_id=str(trace.device_id[i]) or None)
        for i in range(len(trace))]
