"""Event-driven serving simulator (paper §5.2: 10,000-request simulations
seeded with empirical CNN execution-time and network measurements).

Each request: T_input drawn from the network process (stationary,
regime-switching Markov, or trace replay — whole-trace vectorized; see
serving/network.py and DESIGN.md §9) or, with `SimConfig.fleet`, from
the issuing *device's* own process (`serving/fleet.py`, DESIGN.md §10);
the policy sees the budget-side upload time (the observation, or a
`TInputEstimator` / per-device `EstimatorBank` causal estimate) and the
profile store; the selected model's execution time is sampled from its
(mu, sigma); cold starts and queueing at `n_servers` fixed-capacity
replicas are modeled; SLA attainment and effective accuracy are
recorded.

Hedging/fallback (`SimConfig.hedge`):
- ``"p95"`` — legacy straggler mitigation: re-issue to the second
  replica when queueing alone would eat >5% of the SLA.
- ``"outage"`` — outage-aware (MDInference-style): a request whose
  device estimator has entered a degraded regime (estimate >
  `outage_factor` x the device's prior mean) is hedged to the second
  replica; if the device can run a model locally and the estimated
  cloud path cannot meet the SLA at all, it *falls back on-device*
  (`core.selection.on_device_fallback_decision`) and never uploads.

Selection is vectorized (DESIGN.md §3): the whole trace goes through the
Router's `route_batch` — for cnnselect that is the jit'd
`cnnselect_batch` Gumbel-max kernel in fixed-size chunks, not 10k
python-level `cnnselect` calls — and only the cold-start/queueing state
machine replays per request in event order."""

from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.selection import ModelProfile, Policy
from repro.serving.control import (HEDGE_MODES, AdaptiveController,
                                   ControlPlane, make_controller)
from repro.serving.fleet import EstimatorBank, FleetMixture, make_fleet
from repro.serving.metrics import group_stats
from repro.serving.network import (NetworkProcess, TInputEstimator,
                                   make_estimator, make_network)
from repro.serving.router import Router


@dataclass
class SimConfig:
    t_sla: float
    t_threshold: float = 50.0
    n_requests: int = 10000
    # A NETWORKS name (stationary, paper behaviour), a NETWORK_SCENARIOS
    # name (regime-switching Markov), "trace:<name>", or a prebuilt
    # NetworkProcess. Ignored when `fleet` is set.
    network: Union[str, NetworkProcess] = "campus_wifi"
    # Any registry spec (cnnselect | greedy | greedy_nw | random | oracle
    # | static:<name>) or a prebuilt Policy object.
    policy: Union[str, Policy] = "cnnselect"
    stage2_variant: str = "figure"
    seed: int = 0
    arrival_rate_hz: float = 0.0   # 0 = closed loop (no queueing)
    n_servers: int = 1
    # Hedging/fallback policy: "none" | "p95" | "outage" (see module
    # docstring). The legacy boolean `hedge_at_p95=True` maps to "p95"
    # and is deprecated (pinned DeprecationWarning).
    hedge: str = "none"
    hedge_at_p95: bool = False
    # A device estimate is "degraded" when it exceeds this factor times
    # the device's prior (long-run) mean — the outage-regime detector.
    outage_factor: float = 2.0
    # Allow degraded devices with an on-device profile to serve locally
    # when the estimated cloud path cannot meet the SLA (hedge="outage").
    on_device_fallback: bool = True
    memory_budget_bytes: Optional[int] = None
    prewarm: bool = True
    # Budget-side T_input source: None = the observed per-request upload
    # time (paper behaviour); or "mean" | "ewma[:alpha]" | "pctl[:q]" |
    # a TInputEstimator (online estimation under time-varying networks).
    # With `fleet` set, the spec is instantiated per device in an
    # `EstimatorBank` (each device's estimator sees only its own
    # observations, primed with its own process mean).
    t_estimator: Union[str, TInputEstimator, None] = None
    # Device fleet: a FLEET_SCENARIOS name or a prebuilt FleetMixture.
    # None (default) keeps the single shared network process — the
    # golden-pinned pre-fleet path.
    fleet: Union[str, FleetMixture, None] = None
    # Observation staleness fed to the estimator(s): 0 = server-side
    # view (previous upload already measured); 1 = ModiPick's
    # client-side pre-upload view (one RTT behind).
    estimator_lag: int = 0
    # "device": the bank keys estimation on each request's device
    # (default). "global": one shared estimator over the interleaved
    # fleet trace — the pre-fleet budgeting strawman, kept as an
    # ablation for benchmarks.
    estimator_scope: str = "device"
    # Online adaptation (serving/control.py, DESIGN.md §12): a
    # CONTROLLER_SCENARIOS name or a prebuilt `AdaptiveController` that
    # detects per-device regime shifts (change-point tests over the
    # monitor estimator's residuals) and switches budgeting policy /
    # hedge mode / estimator live from its mode table. None (default)
    # keeps the static configuration above — the golden-pinned path.
    # With a controller, `t_estimator`/`hedge` above configure nothing:
    # the active mode's table entries govern each request.
    controller: Union[str, AdaptiveController, None] = None
    # Simulation engine (DESIGN.md §13). "python": the per-request
    # reference loop (golden-pinned). "scan": the jit-compiled
    # `lax.scan` array program over per-device state columns
    # (serving/scan_engine.py) — same decisions, modes, and events;
    # estimator-derived floats agree to the estimator-series ULP
    # tolerance. Requires registry-spec estimators/detectors (or cold
    # instances) and no memory budget.
    engine: str = "python"
    # Shard the device axis of the scan program across this many jax
    # devices (repro.utils.shard_map; bitwise identical to shards=1).
    # CPU runs get a mesh via repro.utils.config.configure(
    # host_devices=N) before jax initializes.
    shards: int = 1


@dataclass
class SimResult:
    attainment: float            # fraction of requests meeting the SLA
    accuracy: float              # expected accuracy of selections
    mean_latency: float
    p50_latency: float
    p95_latency: float
    selections: np.ndarray       # (N,) model indices; -1 = on-device
    latencies: np.ndarray
    violations: np.ndarray       # bool
    cold_starts: int
    hedges: int = 0              # replica re-issues (max one/request)
    fallbacks: int = 0           # requests served on-device
    regimes: Optional[np.ndarray] = None       # (N,) network regime ids
    regime_names: Optional[Sequence[str]] = None
    accuracies: Optional[np.ndarray] = None    # (N,) selected A(m)
    degraded: Optional[np.ndarray] = None      # (N,) outage-detector bool
    device_index: Optional[np.ndarray] = None  # (N,) fleet device index
    device_ids: Optional[Sequence[str]] = None
    # Workload capture (serving/trace.py `Trace.from_sim`): the drawn
    # upload times and arrival clock of this run.
    t_inputs: Optional[np.ndarray] = None      # (N,) ms
    arrivals: Optional[np.ndarray] = None      # (N,) ms
    # Online control (SimConfig.controller, DESIGN.md §12): the mode
    # governing each request plus the controller's switch events
    # (persisted by `Trace.from_sim` as meta["control_events"]).
    modes: Optional[np.ndarray] = None         # (N,) int64 mode index
    mode_names: Optional[Sequence[str]] = None
    switch_events: Optional[List[dict]] = None
    # Model names in selection-index order (set by `simulate`); lets
    # `summary()` report name-keyed selections like the other stacks.
    model_names: Optional[Sequence[str]] = None

    def summary(self) -> dict:
        """The unified serving summary schema (serving/metrics.py) over
        this run — same keys as `ServingMetrics.summary()`; queueing is
        folded into latency here, so the queue columns report 0."""
        sel: Dict[str, int] = {}
        if self.model_names is not None:
            counts = np.bincount(self.selections[self.selections >= 0],
                                 minlength=len(self.model_names))
            sel = {n: int(c)
                   for n, c in zip(self.model_names, counts) if c}
            n_fb = int((self.selections < 0).sum())
            if n_fb:
                sel["<on-device>"] = n_fb
        out = {
            "served": int(len(self.latencies)),
            "attainment": self.attainment,
            "accuracy": self.accuracy,
            "mean_ms": self.mean_latency,
            "p95_ms": self.p95_latency,
            "mean_queue_ms": 0.0,
            "p95_queue_ms": 0.0,
            "selections": sel,
        }
        if self.device_index is not None:
            out["by_device"] = self.per_device()
        if self.modes is not None:
            out["by_mode"] = self.per_mode()
            out["fallbacks"] = self.fallbacks
        if self.hedges:
            out["hedges"] = self.hedges
        return out

    def per_tenant(self) -> Dict[str, Dict[str, float]]:
        """Schema parity with `ServingMetrics` — the simulator is
        single-tenant, so always empty."""
        return {}

    def selection_histogram(self, names: Sequence[str]) -> Dict[str, float]:
        cloud = self.selections[self.selections >= 0]
        h = np.bincount(cloud, minlength=len(names)) / len(self.selections)
        out = {n: float(f) for n, f in zip(names, h)}
        n_fb = int((self.selections < 0).sum())
        if n_fb:
            out["<on-device>"] = n_fb / len(self.selections)
        return out

    def _group_stats(self, index: np.ndarray, names: Sequence[str],
                     extras: Sequence = ()) -> Dict[str, Dict[str, float]]:
        """Delegates to the shared `serving.metrics.group_stats` — the
        one group-by-attainment aggregation behind `per_regime` /
        `per_device` / `per_mode` here and the record-based
        `ServingMetrics` groupers."""
        return group_stats(index, names, violations=self.violations,
                           latencies=self.latencies,
                           accuracies=self.accuracies, extras=extras)

    def per_regime(self) -> Dict[str, Dict[str, float]]:
        """Attainment / accuracy / latency split by network regime
        (time-varying processes; one bucket for stationary runs; fleet
        runs carry device-prefixed regime names)."""
        if self.regimes is None:
            return {}
        names = self.regime_names or [
            f"regime{k}" for k in range(int(self.regimes.max()) + 1)]
        return self._group_stats(self.regimes, names)

    def per_device(self) -> Dict[str, Dict[str, float]]:
        """Attainment / accuracy / latency / fallback share split by
        device (fleet runs only)."""
        if self.device_index is None:
            return {}
        names = self.device_ids or [
            f"device{d}" for d in range(int(self.device_index.max()) + 1)]
        return self._group_stats(
            self.device_index, names,
            extras=(("fallback_share", self.selections < 0),
                    ("degraded_share", self.degraded)))

    def per_mode(self) -> Dict[str, Dict[str, float]]:
        """Attainment split by governing controller mode (adaptive
        runs — SimConfig.controller; empty for static runs). The
        `share` column is the fraction of the run's requests served
        under each mode, `fallback_share` the on-device share."""
        if self.modes is None:
            return {}
        names = self.mode_names or [
            f"mode{k}" for k in range(int(self.modes.max()) + 1)]
        return self._group_stats(
            self.modes, names,
            extras=(("fallback_share", self.selections < 0),
                    ("degraded_share", self.degraded)))


def _hedge_mode(cfg: SimConfig) -> str:
    mode = cfg.hedge
    if mode not in HEDGE_MODES:
        raise ValueError(f"unknown hedge mode {mode!r}; known: "
                         f"{', '.join(HEDGE_MODES)}")
    if cfg.hedge_at_p95:                 # legacy boolean knob
        import warnings
        warnings.warn(
            "SimConfig.hedge_at_p95 is deprecated; use hedge='p95' "
            "instead (the boolean maps to exactly that mode)",
            DeprecationWarning, stacklevel=3)
        if mode not in ("none", "p95"):
            raise ValueError("hedge_at_p95=True conflicts with "
                             f"hedge={mode!r}; set one of them")
        mode = "p95"
    return mode


def _make_sim_estimator(cfg: SimConfig, fleet: Optional[FleetMixture],
                        net: Optional[NetworkProcess]):
    """Resolve SimConfig.t_estimator for the run: a per-device
    `EstimatorBank` when a fleet (or a lag) is involved, a plain
    deep-copied estimator otherwise. simulate() must never mutate a
    caller's estimator instance (sla_sweep reuses one config)."""
    spec = cfg.t_estimator
    if isinstance(spec, TInputEstimator):
        spec = copy.deepcopy(spec)
    if cfg.estimator_lag < 0:
        raise ValueError(f"estimator_lag must be >= 0, "
                         f"got {cfg.estimator_lag}")
    if fleet is None and cfg.estimator_lag == 0:
        # Pre-fleet path, bit-identical to the golden-pinned behaviour.
        if isinstance(spec, TInputEstimator) and spec.prior is None:
            spec.prior = net.mean        # instances get the same prior
        return make_estimator(spec, prior=net.mean)  # a string spec would
    if spec is None and cfg.estimator_lag > 0:
        # Stale view of raw observations = last *known* upload time.
        spec = "ewma:1.0"
    if spec is None:
        return None
    if fleet is not None:
        # The scan engine reads per-device priors from the fleet's
        # `prior_array` directly; materializing the O(D) dict here
        # would dominate setup at a million devices.
        priors = fleet.priors() if cfg.engine == "python" else {}
        return EstimatorBank(spec, priors=priors,
                             default_prior=fleet.mean,
                             lag=cfg.estimator_lag)
    # Single shared process but a stale (lagged) view: one bank entry.
    return EstimatorBank(spec, default_prior=net.mean,
                         lag=cfg.estimator_lag)


def simulate(profiles: Sequence[ModelProfile], cfg: SimConfig, *,
             exec_override: Optional[np.ndarray] = None) -> SimResult:
    """Run the simulation. `exec_override` replays *measured* execution
    times (trace capture/replay, DESIGN.md §11): an (N, K) array whose
    non-NaN entries replace the sampled execution time of model k for
    request i — a capture knows the measured time of the model it
    actually ran, so its column is filled and the rest stay NaN
    (sampled from the profile as usual)."""
    rng = np.random.default_rng(cfg.seed)
    if cfg.engine not in ("python", "scan"):
        raise ValueError(f"unknown engine {cfg.engine!r}; known: "
                         f"python, scan")
    if cfg.engine == "scan" and cfg.memory_budget_bytes is not None:
        raise ValueError("engine='scan' does not model the zoo memory "
                         "budget (LRU eviction is request-sequential); "
                         "use engine='python'")
    if cfg.shards < 1:
        raise ValueError(f"shards must be >= 1, got {cfg.shards}")
    fleet = make_fleet(cfg.fleet)
    net = make_network(cfg.network) if fleet is None else None
    hedge = _hedge_mode(cfg)
    # Decorrelate the policy's RNG stream from the trace rng above —
    # seeding both with cfg.seed would make e.g. the random baseline's
    # picks depend on the very draws that generated the workload.
    policy_seed = int(np.random.SeedSequence([cfg.seed, 1]).generate_state(1)[0])
    # The estimator's cold-start prior is the process's long-run mean —
    # exactly what a server trusting offline measurements would use.
    estimator = _make_sim_estimator(cfg, fleet, net)
    router = Router(profiles, policy=cfg.policy,
                    t_threshold=cfg.t_threshold,
                    stage2_variant=cfg.stage2_variant, seed=policy_seed,
                    memory_budget_bytes=cfg.memory_budget_bytes,
                    t_estimator=estimator)
    zoo = router.zoo
    if cfg.prewarm:
        router.prewarm()
    # The per-request control step — estimate, (adapt,) select, hedge,
    # fall back — lives in the shared ControlPlane (DESIGN.md §12).
    # A prebuilt controller instance is deep-copied: simulate() must
    # never mutate a caller's controller (plan reuse across runs).
    ctrl = make_controller(cfg.controller)
    if ctrl is not None and ctrl is cfg.controller:
        ctrl = copy.deepcopy(ctrl)
    plane = ControlPlane(
        router, hedge=hedge, outage_factor=cfg.outage_factor,
        on_device_fallback=cfg.on_device_fallback, controller=ctrl,
        priors=(fleet.priors()
                if fleet is not None and cfg.engine == "python"
                else {}),
        default_prior=fleet.mean if fleet is not None else net.mean,
        lag=cfg.estimator_lag, seed=policy_seed,
        t_threshold=cfg.t_threshold, stage2_variant=cfg.stage2_variant)

    N = cfg.n_requests
    if fleet is None:
        t_inputs, regimes = net.sample_trace(rng, N)
        device_index = device_keys = None
        regime_names = net.regime_names()
        device_ids: Optional[List[str]] = None
        prior_vec = None
        prior_mean = np.full(N, net.mean)
    else:
        ftrace = fleet.sample_trace(rng, N)
        t_inputs, regimes = ftrace.t_input, ftrace.regime
        device_index = ftrace.device_index
        device_keys = ftrace.device_keys()
        regime_names = ftrace.regime_names
        device_ids = ftrace.device_ids
        prior_vec = fleet.prior_array()
        prior_mean = prior_vec[device_index]
    # Pre-sample each model's hypothetical execution time per request so
    # the oracle and the actual run see consistent draws.
    exec_samples = np.empty((N, len(profiles)))  # (N, K), column-filled
    for k, p in enumerate(profiles):
        np.maximum(rng.normal(p.mu, p.sigma + 1e-9, N), 0.1 * p.mu,
                   out=exec_samples[:, k])
    if exec_override is not None:
        exec_override = np.asarray(exec_override, np.float64)
        if exec_override.shape != exec_samples.shape:
            raise ValueError(f"exec_override shape {exec_override.shape} "
                             f"does not match (N, K) = "
                             f"{exec_samples.shape}")
        known = ~np.isnan(exec_override)
        exec_samples[known] = exec_override[known]

    # Optional open-loop queueing.
    if cfg.arrival_rate_hz > 0:
        arrivals = np.cumsum(rng.exponential(1000.0 / cfg.arrival_rate_hz, N))
    else:
        arrivals = np.zeros(N)
    server_free = np.zeros(cfg.n_servers)

    # The whole trace's control plan (serving/control.py): vectorized
    # admission — estimates materialized first (router state advances
    # exactly once per observation), then chunked select_batch calls —
    # plus the outage/fallback masks and, with a controller, the
    # per-request modes and switch events. Static configs follow the
    # golden-pinned pre-extraction sequence exactly.
    if cfg.estimator_scope not in ("device", "global"):
        raise ValueError(f"unknown estimator_scope "
                         f"{cfg.estimator_scope!r}; known: device, global")
    on_device = None
    if fleet is not None:
        od_ms, od_sg, od_acc = fleet.on_device_arrays()
        on_device = (od_ms[device_index], od_sg[device_index],
                     od_acc[device_index])
    if cfg.engine == "scan":
        from repro.serving.scan_engine import scan_plan_batch
        plan = scan_plan_batch(
            plane, rng, cfg.t_sla, t_inputs,
            device_index=device_index,
            prior_vec=prior_vec if fleet is not None else None,
            device_names=device_ids,
            estimator_scope=cfg.estimator_scope,
            realized=exec_samples, prior_mean=prior_mean,
            on_device=on_device, shards=cfg.shards)
    else:
        plan = plane.plan_batch(rng, cfg.t_sla, t_inputs,
                                device_keys=device_keys,
                                realized=exec_samples,
                                prior_mean=prior_mean,
                                on_device=on_device,
                                estimator_scope=cfg.estimator_scope)
    sel = plan.sel
    degraded, fb_mask = plan.degraded, plan.fb_mask
    od_latency, od_accuracy = plan.od_latency, plan.od_accuracy

    if cfg.engine == "scan":
        from repro.serving.scan_engine import scan_event_phase
        lat, sel, hedges, fallbacks = scan_event_phase(
            cfg, plan, t_inputs, arrivals, exec_samples, profiles,
            zoo, rng)
        return _assemble_result(cfg, plan, lat, sel, hedges,
                                fallbacks, zoo, profiles, regimes,
                                regime_names, degraded, device_index,
                                device_ids, t_inputs, arrivals)
    lat = np.zeros(N)
    hedges = fallbacks = 0
    now = 0.0
    for i in range(N):
        now = arrivals[i]
        if fb_mask is not None and fb_mask[i]:
            # On-device fallback: no upload, no queue, no cold start.
            lat[i] = od_latency[i]
            sel[i] = -1
            fallbacks += 1
            continue
        ti = t_inputs[i]
        idx = sel[i]
        startup = zoo.ensure_hot(profiles[idx].name, now, rng)
        exec_t = exec_samples[i, idx] + startup
        if cfg.arrival_rate_hz > 0:
            # Open loop: queue at the earliest-free server.
            s = int(np.argmin(server_free))
            start = max(now + ti, server_free[s])
            queue_wait = start - (now + ti)
            do_hedge = cfg.n_servers > 1 and (
                (plan.p95_gate[i] and queue_wait > 0.05 * cfg.t_sla)
                or plan.outage_gate[i])
            if do_hedge:
                # Hedge: re-issue to the next server (straggler
                # mitigation); counted once per request whether or not
                # the second replica wins.
                s2 = int(np.argsort(server_free)[1])
                start2 = max(now + ti, server_free[s2])
                if start2 < start:
                    s, start = s2, start2
                hedges += 1
            server_free[s] = start + exec_t
            queue = start - (now + ti)
        else:
            queue = 0.0  # closed loop: requests are independent
        lat[i] = ti + queue + exec_t + ti  # up + queue + exec + down
    return _assemble_result(cfg, plan, lat, sel, hedges, fallbacks,
                            zoo, profiles, regimes, regime_names,
                            degraded, device_index, device_ids,
                            t_inputs, arrivals)


def _assemble_result(cfg, plan, lat, sel, hedges, fallbacks, zoo,
                     profiles, regimes, regime_names, degraded,
                     device_index, device_ids, t_inputs,
                     arrivals) -> SimResult:
    """Metrics + SimResult from a finished run — shared verbatim by
    the python event loop and the scan engine."""
    viol = lat > cfg.t_sla
    prof_acc = np.array([p.accuracy for p in profiles])
    acc = prof_acc[np.maximum(sel, 0)]
    if plan.od_accuracy is not None:
        acc = np.where(sel < 0, plan.od_accuracy, acc)
    return SimResult(
        attainment=float(1.0 - viol.mean()),
        accuracy=float(acc.mean()),
        mean_latency=float(lat.mean()),
        p50_latency=float(np.percentile(lat, 50)),
        p95_latency=float(np.percentile(lat, 95)),
        selections=sel,
        latencies=lat,
        violations=viol,
        cold_starts=zoo.total_cold_starts,
        hedges=hedges,
        fallbacks=fallbacks,
        regimes=regimes,
        regime_names=regime_names,
        accuracies=acc,
        degraded=degraded,
        device_index=device_index,
        device_ids=device_ids,
        t_inputs=t_inputs,
        arrivals=arrivals,
        modes=plan.modes,
        mode_names=plan.mode_names,
        switch_events=plan.events or None,
        model_names=[p.name for p in profiles],
    )


def sla_sweep(profiles, slas, policy="cnnselect", **kw) -> List[SimResult]:
    out = []
    for s in slas:
        cfg = SimConfig(t_sla=float(s), policy=policy, **kw)
        out.append(simulate(profiles, cfg))
    return out


def attainment_improvement(profiles, slas, *, base_policy="greedy",
                           target=0.95, **kw) -> dict:
    """Paper headline: fraction of SLA points where CNNSelect maintains
    attainment >= target vs. the greedy baseline ("88.5% more cases")."""
    ours = sla_sweep(profiles, slas, "cnnselect", **kw)
    base = sla_sweep(profiles, slas, base_policy, **kw)
    ours_ok = np.array([r.attainment >= target for r in ours])
    base_ok = np.array([r.attainment >= target for r in base])
    more = (ours_ok & ~base_ok).sum()
    return {
        "slas": list(map(float, slas)),
        "ours_attainment": [r.attainment for r in ours],
        "base_attainment": [r.attainment for r in base],
        "ours_accuracy": [r.accuracy for r in ours],
        "base_accuracy": [r.accuracy for r in base],
        "ours_ok_cases": int(ours_ok.sum()),
        "base_ok_cases": int(base_ok.sum()),
        "improvement_cases_pct": float(
            100.0 * more / max(base_ok.sum(), 1)) if base_ok.sum() else
        float(100.0 * more / max(len(slas), 1)),
    }
