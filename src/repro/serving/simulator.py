"""Event-driven serving simulator (paper §5.2: 10,000-request simulations
seeded with empirical CNN execution-time and network measurements).

Each request: T_input drawn from the network process (stationary,
regime-switching Markov, or trace replay — whole-trace vectorized; see
serving/network.py and DESIGN.md §9); the policy sees the budget-side
upload time (the observation, or a `TInputEstimator`'s causal estimate
when `SimConfig.t_estimator` is set) and the profile store; the selected
model's
execution time is sampled from its (mu, sigma); cold starts and queueing
at a fixed-capacity server are modeled; SLA attainment and effective
accuracy are recorded. Hedged requests (straggler mitigation) optionally
re-issue to a second replica at the p95 mark.

Selection is vectorized (DESIGN.md §3): the whole trace goes through the
Router's `route_batch` — for cnnselect that is the jit'd
`cnnselect_batch` Gumbel-max kernel in fixed-size chunks, not 10k
python-level `cnnselect` calls — and only the cold-start/queueing state
machine replays per request in event order."""

from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.selection import ModelProfile, Policy
from repro.serving.network import (NetworkProcess, TInputEstimator,
                                   make_estimator, make_network)
from repro.serving.router import Router


@dataclass
class SimConfig:
    t_sla: float
    t_threshold: float = 50.0
    n_requests: int = 10000
    # A NETWORKS name (stationary, paper behaviour), a NETWORK_SCENARIOS
    # name (regime-switching Markov), "trace:<name>", or a prebuilt
    # NetworkProcess.
    network: Union[str, NetworkProcess] = "campus_wifi"
    # Any registry spec (cnnselect | greedy | greedy_nw | random | oracle
    # | static:<name>) or a prebuilt Policy object.
    policy: Union[str, Policy] = "cnnselect"
    stage2_variant: str = "figure"
    seed: int = 0
    arrival_rate_hz: float = 0.0   # 0 = closed loop (no queueing)
    n_servers: int = 1
    hedge_at_p95: bool = False
    memory_budget_bytes: Optional[int] = None
    prewarm: bool = True
    # Budget-side T_input source: None = the observed per-request upload
    # time (paper behaviour); or "mean" | "ewma[:alpha]" | "pctl[:q]" |
    # a TInputEstimator (online estimation under time-varying networks).
    t_estimator: Union[str, TInputEstimator, None] = None


@dataclass
class SimResult:
    attainment: float            # fraction of requests meeting the SLA
    accuracy: float              # expected accuracy of selections
    mean_latency: float
    p50_latency: float
    p95_latency: float
    selections: np.ndarray       # (N,) model indices
    latencies: np.ndarray
    violations: np.ndarray       # bool
    cold_starts: int
    hedges: int = 0
    regimes: Optional[np.ndarray] = None       # (N,) network regime ids
    regime_names: Optional[Sequence[str]] = None
    accuracies: Optional[np.ndarray] = None    # (N,) selected A(m)

    def selection_histogram(self, names: Sequence[str]) -> Dict[str, float]:
        h = np.bincount(self.selections, minlength=len(names)) / len(
            self.selections)
        return {n: float(f) for n, f in zip(names, h)}

    def per_regime(self) -> Dict[str, Dict[str, float]]:
        """Attainment / accuracy / latency split by network regime
        (time-varying processes; one bucket for stationary runs)."""
        if self.regimes is None:
            return {}
        names = self.regime_names or [
            f"regime{k}" for k in range(int(self.regimes.max()) + 1)]
        out: Dict[str, Dict[str, float]] = {}
        for k, name in enumerate(names):
            mask = self.regimes == k
            if not mask.any():
                continue
            out[name] = {
                "share": float(mask.mean()),
                "attainment": float(1.0 - self.violations[mask].mean()),
                "mean_latency": float(self.latencies[mask].mean()),
            }
            if self.accuracies is not None:
                out[name]["accuracy"] = float(self.accuracies[mask].mean())
        return out


def simulate(profiles: Sequence[ModelProfile], cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    net = make_network(cfg.network)
    # Decorrelate the policy's RNG stream from the trace rng above —
    # seeding both with cfg.seed would make e.g. the random baseline's
    # picks depend on the very draws that generated the workload.
    policy_seed = int(np.random.SeedSequence([cfg.seed, 1]).generate_state(1)[0])
    # The estimator's cold-start prior is the process's long-run mean —
    # exactly what a server trusting offline measurements would use. A
    # prebuilt instance is copied: simulate() must not leak estimator
    # state across runs (sla_sweep reuses one config's estimator).
    est_spec = cfg.t_estimator
    if isinstance(est_spec, TInputEstimator):
        est_spec = copy.deepcopy(est_spec)
        if est_spec.prior is None:      # instances get the same prior
            est_spec.prior = net.mean   # a string spec would
    estimator = make_estimator(est_spec, prior=net.mean)
    router = Router(profiles, policy=cfg.policy,
                    t_threshold=cfg.t_threshold,
                    stage2_variant=cfg.stage2_variant, seed=policy_seed,
                    memory_budget_bytes=cfg.memory_budget_bytes,
                    t_estimator=estimator)
    zoo = router.zoo
    if cfg.prewarm:
        router.prewarm()

    N = cfg.n_requests
    t_inputs, regimes = net.sample_trace(rng, N)
    # Pre-sample each model's hypothetical execution time per request so
    # the oracle and the actual run see consistent draws.
    exec_samples = np.stack(
        [np.maximum(rng.normal(p.mu, p.sigma + 1e-9, N), 0.1 * p.mu)
         for p in profiles], axis=1)  # (N, K)

    # Optional open-loop queueing.
    if cfg.arrival_rate_hz > 0:
        arrivals = np.cumsum(rng.exponential(1000.0 / cfg.arrival_rate_hz, N))
    else:
        arrivals = np.zeros(N)
    server_free = np.zeros(cfg.n_servers)

    # Vectorized admission: the entire trace in chunked select_batch
    # calls. Profiles are static within a run, so batching the policy up
    # front is equivalent to asking it per event.
    sel = np.asarray(router.route_batch(
        np.full(N, cfg.t_sla), t_inputs, realized=exec_samples), np.int64)

    lat = np.zeros(N)
    hedges = 0
    now = 0.0
    for i in range(N):
        now = arrivals[i]
        ti = t_inputs[i]
        idx = sel[i]
        startup = zoo.ensure_hot(profiles[idx].name, now, rng)
        exec_t = exec_samples[i, idx] + startup
        if cfg.arrival_rate_hz > 0:
            # Open loop: queue at the earliest-free server.
            s = int(np.argmin(server_free))
            start = max(now + ti, server_free[s])
            queue_wait = start - (now + ti)
            if (cfg.hedge_at_p95 and cfg.n_servers > 1
                    and queue_wait > 0.05 * cfg.t_sla):
                # Hedge: re-issue to the next server if queueing alone
                # would eat >5% of the SLA (straggler mitigation).
                s2 = int(np.argsort(server_free)[1])
                start2 = max(now + ti, server_free[s2])
                if start2 < start:
                    s, start = s2, start2
                hedges += 1
            server_free[s] = start + exec_t
            queue = start - (now + ti)
        else:
            queue = 0.0  # closed loop: requests are independent
        lat[i] = ti + queue + exec_t + ti  # up + queue + exec + down

    viol = lat > cfg.t_sla
    acc = np.array([profiles[j].accuracy for j in sel])
    return SimResult(
        attainment=float(1.0 - viol.mean()),
        accuracy=float(acc.mean()),
        mean_latency=float(lat.mean()),
        p50_latency=float(np.percentile(lat, 50)),
        p95_latency=float(np.percentile(lat, 95)),
        selections=sel,
        latencies=lat,
        violations=viol,
        cold_starts=zoo.total_cold_starts,
        hedges=hedges,
        regimes=regimes,
        regime_names=net.regime_names(),
        accuracies=acc,
    )


def sla_sweep(profiles, slas, policy="cnnselect", **kw) -> List[SimResult]:
    out = []
    for s in slas:
        cfg = SimConfig(t_sla=float(s), policy=policy, **kw)
        out.append(simulate(profiles, cfg))
    return out


def attainment_improvement(profiles, slas, *, base_policy="greedy",
                           target=0.95, **kw) -> dict:
    """Paper headline: fraction of SLA points where CNNSelect maintains
    attainment >= target vs. the greedy baseline ("88.5% more cases")."""
    ours = sla_sweep(profiles, slas, "cnnselect", **kw)
    base = sla_sweep(profiles, slas, base_policy, **kw)
    ours_ok = np.array([r.attainment >= target for r in ours])
    base_ok = np.array([r.attainment >= target for r in base])
    more = (ours_ok & ~base_ok).sum()
    return {
        "slas": list(map(float, slas)),
        "ours_attainment": [r.attainment for r in ours],
        "base_attainment": [r.attainment for r in base],
        "ours_accuracy": [r.accuracy for r in ours],
        "base_accuracy": [r.accuracy for r in base],
        "ours_ok_cases": int(ours_ok.sum()),
        "base_ok_cases": int(base_ok.sum()),
        "improvement_cases_pct": float(
            100.0 * more / max(base_ok.sum(), 1)) if base_ok.sum() else
        float(100.0 * more / max(len(slas), 1)),
    }
