"""Continuous batching scheduler.

The paper notes that throughput-batching serving systems "may increase
waiting time of some requests" — this scheduler bounds that: requests
join the next decode group as slots free, instead of waiting for a whole
batch to drain. Decode steps are aligned per group (engine constraint);
the scheduler's job is slot assignment, padding, and retirement."""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass(order=True)
class Request:
    arrival: float
    rid: int = field(compare=False)
    prompt: np.ndarray = field(compare=False, repr=False)
    max_new_tokens: int = field(compare=False, default=16)
    sla_ms: float = field(compare=False, default=0.0)
    t_input_ms: float = field(compare=False, default=0.0)
    # Issuing device (fleet serving, DESIGN.md §10): keys the Router's
    # per-device EstimatorBank; None = single shared estimator.
    device_id: Optional[str] = field(compare=False, default=None)
    # outputs
    tokens: list = field(compare=False, default_factory=list)
    start_exec: float = field(compare=False, default=0.0)
    finish: float = field(compare=False, default=0.0)
    model: str = field(compare=False, default="")


class FifoQueue:
    """Minimal per-model queue with the same `submit` protocol as
    `ContinuousBatcher` — the Router's default when a stack doesn't
    attach its own batcher."""

    def __init__(self):
        self.items: Deque[Request] = deque()

    def submit(self, req: Request):
        self.items.append(req)

    def pop(self) -> Request:
        return self.items.popleft()

    def __len__(self) -> int:
        return len(self.items)


class ContinuousBatcher:
    """Groups requests into aligned decode batches of size `batch_size`.

    step(now) returns work items: ("prefill", [reqs]) when a fresh group
    forms, then ("decode", group) while any member needs tokens. Members
    finishing early free their slot for the next group formation."""

    def __init__(self, batch_size: int, prompt_len: int):
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.queue: List[Request] = []
        # slots[i] is the request bound to engine batch slot i (or None).
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.done: List[Request] = []

    def submit(self, req: Request):
        heapq.heappush(self.queue, req)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def form_group(self, now: float) -> Optional[List[Request]]:
        """Take up to batch_size arrived requests into a fresh group.
        (The aligned-decode engine prefills a whole group at once, so new
        groups form only when the previous group has fully drained.)"""
        if self.n_active > 0:
            return None
        ready = []
        while self.queue and len(ready) < self.batch_size:
            if self.queue[0].arrival <= now:
                ready.append(heapq.heappop(self.queue))
            else:
                break
        if not ready:
            return None
        self.slots = [None] * self.batch_size
        for i, r in enumerate(ready):
            self.slots[i] = r
            r.start_exec = now
        return ready

    def pad_prompts(self) -> np.ndarray:
        out = np.zeros((self.batch_size, self.prompt_len), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                p = r.prompt[-self.prompt_len:]
                out[i, -len(p):] = p
        return out

    def record_tokens(self, toks: np.ndarray, now: float):
        """toks: (batch_size,) — append per slot; retire finished slots."""
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.tokens.append(int(toks[i]))
            if len(r.tokens) >= r.max_new_tokens:
                r.finish = now
                self.done.append(r)
                self.slots[i] = None
