"""Continuous batching scheduler.

The paper notes that throughput-batching serving systems "may increase
waiting time of some requests" — this scheduler bounds that: requests
join the next decode group as slots free, instead of waiting for a whole
batch to drain. Decode steps are aligned per group (engine constraint);
the scheduler's job is slot assignment, padding, and retirement."""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass(order=True)
class Request:
    arrival: float
    rid: int = field(compare=False)
    prompt: np.ndarray = field(compare=False, repr=False)
    max_new_tokens: int = field(compare=False, default=16)
    sla_ms: float = field(compare=False, default=0.0)
    t_input_ms: float = field(compare=False, default=0.0)
    # Issuing device (fleet serving, DESIGN.md §10): keys the Router's
    # per-device EstimatorBank; None = single shared estimator.
    device_id: Optional[str] = field(compare=False, default=None)
    # Tenant tag (multi-tenant cluster serving, DESIGN.md §16): names
    # the device population / SLA class this request bills to; None =
    # single-tenant stack.
    tenant: Optional[str] = field(compare=False, default=None)
    # outputs
    tokens: list = field(compare=False, default_factory=list)
    start_exec: float = field(compare=False, default=0.0)
    finish: float = field(compare=False, default=0.0)
    model: str = field(compare=False, default="")


class FifoQueue:
    """Minimal per-model queue with the same `submit` protocol as
    `ContinuousBatcher` — the Router's default when a stack doesn't
    attach its own batcher."""

    def __init__(self):
        self.items: Deque[Request] = deque()

    def submit(self, req: Request):
        self.items.append(req)

    def pop(self) -> Request:
        return self.items.popleft()

    def __len__(self) -> int:
        return len(self.items)


class ContinuousBatcher:
    """Groups requests into aligned decode batches of size `batch_size`.

    `form_group(now)` seeds a fresh group when the engine is idle;
    `backfill(now, ...)` joins queued arrivals into slots freed by
    early-retiring members *mid-group* (true continuous batching — the
    engine prefills the newcomer's row into the live cache via
    `InferenceEngine.prefill_row`). Decode steps stay aligned across the
    group (engine constraint); the scheduler's job is slot assignment,
    padding, and retirement."""

    def __init__(self, batch_size: int, prompt_len: int):
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.queue: List[Request] = []
        # slots[i] is the request bound to engine batch slot i (or None).
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.done: List[Request] = []

    def submit(self, req: Request):
        heapq.heappush(self.queue, req)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def form_group(self, now: float) -> Optional[List[Request]]:
        """Take up to batch_size arrived requests into a fresh group.
        (The aligned-decode engine prefills a whole group at once, so new
        groups form only when the previous group has fully drained.)"""
        if self.n_active > 0:
            return None
        ready = []
        while self.queue and len(ready) < self.batch_size:
            if self.queue[0].arrival <= now:
                ready.append(heapq.heappop(self.queue))
            else:
                break
        if not ready:
            return None
        self.slots = [None] * self.batch_size
        for i, r in enumerate(ready):
            self.slots[i] = r
            r.start_exec = now
        return ready

    def backfill(self, now: float, budget: Optional[int] = None):
        """Join queued arrivals into freed slots mid-group.

        Returns [(slot_index, request)] for the engine to `prefill_row`.
        budget: optional cap on decode steps the group can still take
        (engine free context) — a joiner needing more tokens than the
        cache has room for must wait for the next fresh group."""
        if self.n_active == 0:
            return []        # nothing live to join; use form_group
        joins = []
        deferred = []
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            while self.queue and self.queue[0].arrival <= now:
                r = heapq.heappop(self.queue)
                if budget is not None and r.max_new_tokens > budget:
                    deferred.append(r)
                    continue
                self.slots[i] = r
                r.start_exec = now
                joins.append((i, r))
                break
            if self.slots[i] is None:
                break        # queue exhausted (or all remaining deferred)
        for r in deferred:
            heapq.heappush(self.queue, r)
        return joins

    def pad_prompts(self) -> np.ndarray:
        """Left-pad live prompts to (batch_size, prompt_len). Pad token is
        0 — harmless only because the engine masks positions below each
        row's real length (see `prompt_lengths`)."""
        out = np.zeros((self.batch_size, self.prompt_len), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                p = r.prompt[-self.prompt_len:]
                out[i, -len(p):] = p
        return out

    def prompt_lengths(self) -> np.ndarray:
        """(batch_size,) real token count per row of `pad_prompts` output
        (1 for empty slots — a full-mask row would NaN the softmax)."""
        out = np.ones(self.batch_size, np.int64)
        for i, r in enumerate(self.slots):
            if r is not None:
                out[i] = min(len(r.prompt), self.prompt_len)
        return out

    def record_token(self, slot: int, tok: int, now: float):
        """Append one token to the request in `slot`; retire it (freeing
        the slot) once it has max_new_tokens."""
        r = self.slots[slot]
        if r is None:
            return
        r.tokens.append(int(tok))
        if len(r.tokens) >= r.max_new_tokens:
            r.finish = now
            self.done.append(r)
            self.slots[slot] = None

    def record_tokens(self, toks: np.ndarray, now: float):
        """toks: (batch_size,) — append per slot; retire finished slots."""
        for i in range(self.batch_size):
            self.record_token(i, toks[i], now)
