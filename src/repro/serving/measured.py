"""Measured execution backend: the runnable model zoo behind the Router.

Builds `InferenceEngine`s from `configs.paper_zoo.MEASURED_ZOO` (reduced
attention-only LMs, fp32 + int8 variants as distinct selection
candidates) and turns their `measured_profile` outputs into the
`ModelProfile` list every serving stack consumes — the `ProfileStore`
source selected with ``profiles="measured"``. This is what moves the
control plane from Table 5 lookups to latencies executed on this host
(DESIGN.md §14)."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax

from repro.configs import reduced_config
from repro.configs.paper_zoo import MEASURED_ZOO, measured_zoo_names
from repro.core.selection import ModelProfile
from repro.models import init_params
from repro.quant.int8 import quantize_exec_tree
from repro.serving.engine import InferenceEngine


@dataclass
class MeasuredModel:
    """One runnable selection candidate: engine + offline metadata."""
    name: str
    engine: InferenceEngine
    accuracy: float
    size_bytes: int
    quant: Optional[str] = None


def build_model(name: str, *, batch_size: int = 4, max_seq: int = 64,
                seed: int = 0, attn_impl: str = "pallas") -> MeasuredModel:
    """Build one zoo engine. attn_impl defaults to the pallas fast path
    (valid_from-masked flash/decode kernels — interpret mode on CPU);
    'naive'/'jax_chunked' keep the reference paths for A/B runs
    (benchmarks/measured_serving.py). int8 candidates hold their weights
    as resident (int8, scale) execution trees — projection matmuls run
    the int8 kernel, and size_bytes is the bytes this engine actually
    holds (no dequantized fp32 round-trip)."""
    spec = MEASURED_ZOO[name]
    cfg = reduced_config(spec["arch"])
    cfg = dataclasses.replace(cfg, d_model=spec["d_model"],
                              d_ff=spec["d_ff"], n_layers=spec["n_layers"],
                              attn_impl=attn_impl)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    if spec["quant"] == "int8":
        params = quantize_exec_tree(params)
    engine = InferenceEngine(cfg, params, batch_size=batch_size,
                             max_seq=max_seq)
    return MeasuredModel(name=name, engine=engine,
                         accuracy=spec["accuracy"],
                         size_bytes=engine.resident_bytes,
                         quant=spec["quant"])


def build_zoo(names=None, *, batch_size: int = 4, max_seq: int = 64,
              seed: int = 0, attn_impl: str = "pallas"
              ) -> Dict[str, MeasuredModel]:
    """{name: MeasuredModel} for the requested zoo subset, in registry
    order. Engines share batch/seq geometry so one batcher config fits
    all; params are seeded per model (seed + registry index)."""
    out = {}
    for i, n in enumerate(measured_zoo_names(names)):
        out[n] = build_model(n, batch_size=batch_size, max_seq=max_seq,
                             seed=seed + i, attn_impl=attn_impl)
    return out


def measured_profiles(zoo: Dict[str, MeasuredModel], *,
                      prompt_len: int = 8, n_tokens: int = 4,
                      reps: int = 3, warmup: bool = True,
                      detail: Optional[dict] = None) -> List[ModelProfile]:
    """Profile every engine on THIS host and return the `ModelProfile`
    list the Router/simulator consume — the ``profiles="measured"``
    source. Cold start is the measured jit-compile time (the serving
    analogue of the paper's model-load phase). `detail`, if given, is
    filled with each engine's raw measured_profile dict (prefill_ms /
    per_token_ms split)."""
    out = []
    for name, m in zoo.items():
        cold_ms = (m.engine.warmup(prompt_len) * 1000.0) if warmup else 0.0
        p = m.engine.measured_profile(prompt_len, n_tokens, reps)
        if detail is not None:
            detail[name] = dict(p, cold_ms=cold_ms)
        out.append(ModelProfile(
            name=name, accuracy=m.accuracy, mu=p["mu"],
            sigma=max(p["sigma"], 1e-3), cold_mu=cold_ms,
            cold_sigma=0.1 * cold_ms, size_bytes=m.size_bytes))
    return out


def served_models(zoo: Dict[str, MeasuredModel]):
    """Adapt the zoo to `CNNSelectServer`'s ServedModel list."""
    from repro.serving.server import ServedModel
    return [ServedModel(name=m.name, engine=m.engine, accuracy=m.accuracy,
                        size_bytes=m.size_bytes) for m in zoo.values()]
