"""Measured execution backend: the runnable model zoo behind the Router.

Builds `InferenceEngine`s from `configs.paper_zoo.MEASURED_ZOO` (reduced
attention-only LMs, fp32 + int8 variants as distinct selection
candidates) and turns their `measured_profile` outputs into the
`ModelProfile` list every serving stack consumes — the `ProfileStore`
source selected with ``profiles="measured"``. This is what moves the
control plane from Table 5 lookups to latencies executed on this host
(DESIGN.md §14)."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax

from repro.configs import reduced_config
from repro.configs.paper_zoo import MEASURED_ZOO, measured_zoo_names
from repro.core.selection import ModelProfile
from repro.models import init_params
from repro.quant.int8 import dequantize_tree, quantize_tree, \
    tree_bytes_quantized
from repro.serving.engine import InferenceEngine
from repro.utils import tree_bytes


@dataclass
class MeasuredModel:
    """One runnable selection candidate: engine + offline metadata."""
    name: str
    engine: InferenceEngine
    accuracy: float
    size_bytes: int
    quant: Optional[str] = None


def build_model(name: str, *, batch_size: int = 4, max_seq: int = 64,
                seed: int = 0) -> MeasuredModel:
    spec = MEASURED_ZOO[name]
    cfg = reduced_config(spec["arch"])
    cfg = dataclasses.replace(cfg, d_model=spec["d_model"],
                              d_ff=spec["d_ff"], n_layers=spec["n_layers"])
    params = init_params(cfg, jax.random.PRNGKey(seed))
    size = tree_bytes(params)
    if spec["quant"] == "int8":
        # Real quantization error in the weights (round-trip through
        # int8), real storage accounting for the memory budget.
        q = quantize_tree(params, min_size=256)
        size = tree_bytes_quantized(q)
        params = dequantize_tree(q, like=params)
    engine = InferenceEngine(cfg, params, batch_size=batch_size,
                             max_seq=max_seq)
    return MeasuredModel(name=name, engine=engine,
                         accuracy=spec["accuracy"], size_bytes=size,
                         quant=spec["quant"])


def build_zoo(names=None, *, batch_size: int = 4, max_seq: int = 64,
              seed: int = 0) -> Dict[str, MeasuredModel]:
    """{name: MeasuredModel} for the requested zoo subset, in registry
    order. Engines share batch/seq geometry so one batcher config fits
    all; params are seeded per model (seed + registry index)."""
    out = {}
    for i, n in enumerate(measured_zoo_names(names)):
        out[n] = build_model(n, batch_size=batch_size, max_seq=max_seq,
                             seed=seed + i)
    return out


def measured_profiles(zoo: Dict[str, MeasuredModel], *,
                      prompt_len: int = 8, n_tokens: int = 4,
                      reps: int = 3, warmup: bool = True,
                      detail: Optional[dict] = None) -> List[ModelProfile]:
    """Profile every engine on THIS host and return the `ModelProfile`
    list the Router/simulator consume — the ``profiles="measured"``
    source. Cold start is the measured jit-compile time (the serving
    analogue of the paper's model-load phase). `detail`, if given, is
    filled with each engine's raw measured_profile dict (prefill_ms /
    per_token_ms split)."""
    out = []
    for name, m in zoo.items():
        cold_ms = (m.engine.warmup(prompt_len) * 1000.0) if warmup else 0.0
        p = m.engine.measured_profile(prompt_len, n_tokens, reps)
        if detail is not None:
            detail[name] = dict(p, cold_ms=cold_ms)
        out.append(ModelProfile(
            name=name, accuracy=m.accuracy, mu=p["mu"],
            sigma=max(p["sigma"], 1e-3), cold_mu=cold_ms,
            cold_sigma=0.1 * cold_ms, size_bytes=m.size_bytes))
    return out


def served_models(zoo: Dict[str, MeasuredModel]):
    """Adapt the zoo to `CNNSelectServer`'s ServedModel list."""
    from repro.serving.server import ServedModel
    return [ServedModel(name=m.name, engine=m.engine, accuracy=m.accuracy,
                        size_bytes=m.size_bytes) for m in zoo.values()]
