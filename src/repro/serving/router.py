"""Admission router: the one front door shared by all serving stacks.

The paper evaluates CNNSelect in three settings — a live prototype
server (batch-of-one), a continuous-batching loop, and 10k-request
simulations. Pre-refactor each reimplemented the same admission logic:
read profiles, dispatch on a policy string, pay cold start, enqueue.
The Router centralizes it (DESIGN.md §3): it owns the online
`ProfileStore`, the cold/warm `ModelZoo` state, and per-model request
queues, and answers selection either per request (`route`) or
vectorized over a whole trace (`route_batch`, which drives the jit'd
`cnnselect_batch` Gumbel-max path in fixed-size chunks).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.profiles import ProfileStore
from repro.core.selection import ModelProfile, Policy, make_policy
from repro.core.zoo import ModelZoo
from repro.serving.batching import FifoQueue, Request
from repro.serving.fleet import EstimatorBank
from repro.serving.network import TInputEstimator, make_estimator


@dataclass
class RouteDecision:
    index: int                 # position in the router's model order
    name: str
    startup_ms: float = 0.0    # cold-start penalty paid by this request


class Router:
    """Policy-driven admission over a registered model zoo.

    Queues are pluggable: anything with a ``submit(req)`` method can be
    attached per model (the serving loop attaches its
    ``ContinuousBatcher``s); the default is a ``FifoQueue``.
    """

    def __init__(self, profiles: Optional[Sequence[ModelProfile]] = None, *,
                 policy: Union[str, Policy] = "cnnselect",
                 t_threshold: float = 50.0, stage2_variant: str = "figure",
                 seed: int = 0, chunk: int = 2048,
                 memory_budget_bytes: Optional[int] = None,
                 min_sigma: float = 0.0,
                 t_estimator: Union[str, TInputEstimator, EstimatorBank,
                                    None] = None):
        self.policy = make_policy(policy, t_threshold=t_threshold,
                                  stage2_variant=stage2_variant, seed=seed,
                                  chunk=chunk)
        # Optional online T_input estimator (DESIGN.md §9): when set,
        # per-request budgets come from its causal estimate of recent
        # upload times, not from the raw per-request observation. An
        # `EstimatorBank` keys estimation per device (DESIGN.md §10) —
        # pass each request's `device_id` through route/route_batch.
        self.t_estimator = (t_estimator if isinstance(t_estimator,
                                                      EstimatorBank)
                            else make_estimator(t_estimator))
        self.store = ProfileStore()
        self.zoo = ModelZoo(memory_budget_bytes)
        self.order: List[str] = []
        self.queues: Dict[str, object] = {}
        self.min_sigma = min_sigma
        # Optional trace capture (serving/trace.py `TraceRecorder`,
        # DESIGN.md §11): when attached, `submit`/`submit_many` record
        # each admitted request (sla outcome unknown at admission).
        self.recorder = None
        for p in profiles or []:
            self.register(p)

    # -- zoo / profile management -----------------------------------------

    def register(self, profile: ModelProfile, *, queue=None):
        """Add a model. A profile with mu > 0 seeds the store's prior;
        mu == 0 means "profile online later" (via `set_profile`)."""
        self.zoo.register(profile)
        self.order.append(profile.name)
        self.queues[profile.name] = FifoQueue() if queue is None else queue
        if profile.mu > 0:
            self.store.set_prior(profile.name, profile.mu, profile.sigma,
                                 profile.cold_mu, profile.cold_sigma)

    def attach_queue(self, name: str, queue):
        self.queues[name] = queue

    def set_profile(self, name: str, mu: float, sigma: float,
                    cold_mu: float = 0.0, cold_sigma: float = 0.0):
        """(Re)seed a model's latency prior, e.g. from live measurement."""
        self.store.set_prior(name, mu, sigma, cold_mu, cold_sigma)

    def record(self, name: str, latency_ms: float, *, cold: bool = False,
               now: float = 0.0):
        """Feed one measured latency back into the online profile."""
        self.store.record(name, latency_ms, cold=cold, now=now)

    def prewarm(self, names: Optional[Sequence[str]] = None):
        self.zoo.prewarm(list(names) if names is not None else self.order)

    def current_profiles(self) -> List[ModelProfile]:
        """The live view the policy sees: online mu/sigma blended with
        the registered accuracy / cold-start / size metadata."""
        out = []
        for name in self.order:
            p = self.zoo.entries[name].profile
            mu, sg = self.store.mu_sigma(name)
            out.append(ModelProfile(
                name=name, accuracy=p.accuracy, mu=mu,
                sigma=max(sg, self.min_sigma), cold_mu=p.cold_mu,
                cold_sigma=p.cold_sigma, size_bytes=p.size_bytes))
        return out

    # -- admission --------------------------------------------------------

    def observe_t_input(self, t_input: float,
                        device_id: Optional[str] = None) -> float:
        """Feed one observed upload time to the attached estimator and
        return the budget-side T_input for this request (the raw
        observation when no estimator is attached). With an
        `EstimatorBank`, `device_id` selects the device's estimator."""
        if self.t_estimator is None:
            return float(t_input)
        if isinstance(self.t_estimator, EstimatorBank):
            est = self.t_estimator.estimate(device_id, observed=t_input)
            self.t_estimator.observe(device_id, float(t_input))
            return est
        est = self.t_estimator.estimate(observed=t_input)
        self.t_estimator.observe(float(t_input))
        return est

    def estimate_series(self, t_input, *, device_ids=None) -> np.ndarray:
        """Causal budget-side estimates for a whole observed trace
        (identity when no estimator is attached). Mutates estimator
        state — each observation is fed exactly once."""
        t_input = np.asarray(t_input, np.float64)
        if self.t_estimator is None:
            return t_input
        if isinstance(self.t_estimator, EstimatorBank):
            return self.t_estimator.estimate_series(t_input, device_ids)
        return self.t_estimator.estimate_series(t_input)

    def select(self, t_sla: float, t_input: float, *,
               realized: Optional[np.ndarray] = None) -> int:
        """Pure policy decision for one request (no zoo or estimator
        side effects; `t_input` is taken as the budget-side value)."""
        return self.policy.select(self.current_profiles(), t_sla, t_input,
                                  realized=realized)

    def route(self, t_sla: float, t_input: float, *, now: float = 0.0,
              realized: Optional[np.ndarray] = None,
              rng: Optional[np.random.Generator] = None,
              device_id: Optional[str] = None) -> RouteDecision:
        """Select a model and transition it hot, charging this request
        the cold-start penalty if it wasn't. The observed `t_input`
        passes through the estimator (if any) for budgeting; with an
        `EstimatorBank`, keyed by the request's `device_id`."""
        idx = self.select(t_sla,
                          self.observe_t_input(t_input, device_id),
                          realized=realized)
        name = self.order[idx]
        startup = self.zoo.ensure_hot(name, now, rng)
        return RouteDecision(idx, name, startup)

    def route_batch(self, t_sla, t_input, *,
                    realized: Optional[np.ndarray] = None,
                    detail: bool = False, device_ids=None,
                    estimated: bool = False):
        """Vectorized admission over N requests: one `select_batch` call
        (chunked jit for cnnselect), no zoo side effects — callers
        replay cold/warm transitions in event order via `zoo`. With an
        estimator attached, the observed `t_input` trace is replaced by
        its causal `estimate_series` for budgeting (per device when the
        estimator is an `EstimatorBank` and `device_ids` is given).
        `estimated=True` marks `t_input` as already budget-side (the
        caller ran `estimate_series` itself, e.g. to inspect the
        estimates for outage detection) — estimation is skipped so
        observations are never fed twice."""
        t_input = np.asarray(t_input, np.float64)
        if not estimated:
            t_input = self.estimate_series(t_input, device_ids=device_ids)
        return self.policy.select_batch(
            self.current_profiles(), np.asarray(t_sla, np.float64),
            t_input, realized=realized, detail=detail)

    def _admit(self, req: Request, name: str) -> None:
        """Admission bookkeeping for an already-routed request — bind
        the model, queue it, record the admission. One copy shared by
        `submit`/`submit_many`. Requests are the canonical
        `batching.Request` — one dataclass end to end, so device_id/sla
        metadata cannot drift between admission and execution."""
        req.model = name
        self.queues[name].submit(req)
        if self.recorder is not None:
            self.recorder.record_request(req, model=name)

    def enqueue(self, req: Request, name: str) -> None:
        """Deprecated: call ``submit(req, name=name)`` — `submit` is the
        one canonical admission path (pre-decided admissions included),
        so admission bookkeeping cannot fork."""
        warnings.warn(
            "Router.enqueue is deprecated; use Router.submit(req, "
            "name=name)", DeprecationWarning, stacklevel=2)
        self._admit(req, name)

    def submit(self, req: Request, *, now: float = 0.0,
               name: Optional[str] = None) -> RouteDecision:
        """The canonical admission path: route one request and enqueue
        it on its model's queue. A caller that already decided the
        model (e.g. the control plane's adaptive per-request step,
        serving/control.py) passes ``name=`` to skip routing and admit
        directly — same bookkeeping, no second selection."""
        if name is not None:
            self._admit(req, name)
            return RouteDecision(self.order.index(name), name, 0.0)
        d = self.route(req.sla_ms or 1e9, req.t_input_ms, now=now,
                       device_id=req.device_id)
        self._admit(req, d.name)
        return d

    def submit_many(self, requests: Sequence[Request]) -> List[str]:
        """Vectorized admission of a whole trace: one `route_batch` over
        the requests' (sla, t_input) vectors, then enqueue in arrival
        order. Returns the chosen model name per request."""
        if not requests:
            return []
        t_sla = np.array([r.sla_ms or 1e9 for r in requests])
        t_in = np.array([r.t_input_ms for r in requests])
        devs = [r.device_id for r in requests]
        idx = self.route_batch(t_sla, t_in, device_ids=devs)
        names = []
        for r, i in zip(requests, idx):
            name = self.order[int(i)]
            self._admit(r, name)
            names.append(name)
        return names
