"""Online control plane: the per-request serving step, shared by all
three stacks, plus live regime-shift adaptation (DESIGN.md §12).

The per-request control step — estimate the budget-side T_input, select
a model, decide hedging/fallback, observe the outcome — was previously
re-implemented three times: inline in `simulate()`'s event loop, in
`ServingLoop.run`, and in `CNNSelectServer.handle`. `ControlPlane`
extracts it once:

- **scalar** — `step()` answers one request (`ControlDecision`), and
  `observe_outcome()` feeds the measured latency back; the prototype
  server and the continuous-batching loop drive this path.
- **vectorized** — `plan_batch()` answers a whole trace (`BatchPlan`)
  for the simulator; with no controller attached it performs *exactly*
  the pre-refactor estimate→route_batch→outage-mask sequence (same
  operations, same RNG consumption order), so the PR 2/PR 3 golden
  regression pins stay bit-for-bit.

On top of the shared step sits *online adaptation* — the regime
MDInference (arXiv:2002.06603) and ModiPick (arXiv:1909.02053) argue
for: the server must react to shifting network conditions per request,
not be configured once offline.

- **Change-point detectors** (`CusumDetector`, `PageHinkleyDetector`)
  watch the per-device residual stream of a *monitor* estimator
  (observed upload − causal estimate, the `EstimatorBank` residuals):
  pure numpy, causal, self-normalizing (EWMA of |residual|) unless a
  fixed scale is given. A positive-side alarm signals degradation, a
  negative-side alarm signals recovery.
- **`AdaptiveController`** maps alarms to an *ordered mode table*
  (`core.selection.ControlMode`, least → most conservative): an
  up-alarm escalates the device one mode, a down-alarm de-escalates;
  each mode fixes the budgeting estimator, the hedge behaviour,
  on-device fallback, and optionally the selection policy. Every
  switch is recorded as an event `{request, device, from, to, alarm}`
  — `simulate()` stores them on `SimResult.switch_events` and
  `Trace.from_sim` persists them as ``meta["control_events"]``, so
  adaptations replay with the capture.

Named controller presets live in
`configs/paper_zoo.CONTROLLER_SCENARIOS` and resolve through
`make_controller`; `benchmarks/adaptive_control.py` scores the
adaptive controller against every static (policy, hedge, estimator)
configuration.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.registry import parse_spec
from repro.core.selection import (ControlMode, Policy, make_mode,
                                  make_policy, on_device_fallback_decision)
from repro.serving.fleet import EstimatorBank
from repro.serving.network import validate_estimator_spec

HEDGE_MODES = ("none", "p95", "outage")


# --------------------------------------------------------------------------
# Change-point detection (per-device, over estimator residuals)
# --------------------------------------------------------------------------

class ChangePointDetector:
    """Causal online detector over a residual stream.

    `update(residual)` consumes one residual (observed − predicted
    upload time) and returns ``+1`` (upward mean shift — degradation),
    ``-1`` (downward shift — recovery), or ``0``. The statistic resets
    itself after an alarm. With ``scale=None`` residuals are
    self-normalized by an EWMA of |residual| (primed on the first
    residual); a fixed ``scale`` makes the statistic exactly the
    textbook form — the calibration property tests pin false-positive
    rate and detection delay through that path.
    """

    name = "detector"

    def __init__(self, *, scale: Optional[float] = None,
                 scale_beta: float = 0.05, min_scale: float = 1e-3):
        if scale is not None and scale <= 0:
            raise ValueError(f"scale must be positive, got {scale}")
        if not 0.0 < scale_beta <= 1.0:
            raise ValueError(f"scale_beta must be in (0, 1], "
                             f"got {scale_beta}")
        self.fixed_scale = scale
        self.scale_beta = float(scale_beta)
        self.min_scale = float(min_scale)
        self._scale: Optional[float] = scale

    def prime_scale(self, scale: float) -> None:
        """Seed the self-normalizing scale (e.g. from the device's
        prior dispersion) so early residuals are not standardized by
        one arbitrary first draw. No-op with a fixed scale."""
        if self.fixed_scale is None and scale > 0:
            self._scale = max(float(scale), self.min_scale)

    def _standardize(self, residual: float,
                     scale_sample: Optional[float] = None) -> float:
        """z-score the residual against the current scale, then let the
        scale track the noise (slowly; after standardization, so a
        shift burst is measured against the pre-shift scale).
        `scale_sample` is the magnitude the scale should learn from —
        the controller passes the *tracker* residual |obs − tracker|,
        which measures process noise; the detection residual
        (obs − reference) would inflate the scale with the very offset
        being detected and bury the recovery signal. Defaults to
        |residual| for standalone use."""
        r = float(residual)
        if self.fixed_scale is not None:
            return r / self.fixed_scale
        s_obs = abs(r) if scale_sample is None else abs(
            float(scale_sample))
        if self._scale is None:
            self._scale = max(s_obs, self.min_scale)
        z = r / self._scale
        self._scale = max((1.0 - self.scale_beta) * self._scale
                          + self.scale_beta * s_obs, self.min_scale)
        return z

    def update(self, residual: float,
               scale_sample: Optional[float] = None) -> int:
        raise NotImplementedError

    def reset(self) -> None:
        """Clear the decision statistic (the scale survives)."""
        raise NotImplementedError


class CusumDetector(ChangePointDetector):
    """Two-sided CUSUM (Page's test): ``S+ = max(0, S+ + z - k)``,
    ``S- = max(0, S- - z - k)``; alarm when either exceeds the
    threshold ``h``. With standardized residuals, `drift` ``k`` is in
    sigma units (detects shifts larger than ~2k) and `threshold` ``h``
    trades detection delay against false-positive rate (for N(0,1)
    residuals with k=0.5, h=8 the in-control ARL is astronomically
    large; out of control, delay ≈ h / (shift/sigma - k))."""

    name = "cusum"

    def __init__(self, threshold: float = 8.0, drift: float = 0.5, **kw):
        super().__init__(**kw)
        if threshold <= 0:
            raise ValueError(f"threshold must be positive, "
                             f"got {threshold}")
        if drift < 0:
            raise ValueError(f"drift must be >= 0, got {drift}")
        self.threshold = float(threshold)
        self.drift = float(drift)
        self._pos = 0.0
        self._neg = 0.0

    @property
    def statistic(self) -> float:
        return max(self._pos, self._neg)

    def update(self, residual: float,
               scale_sample: Optional[float] = None) -> int:
        z = self._standardize(residual, scale_sample)
        self._pos = max(0.0, self._pos + z - self.drift)
        self._neg = max(0.0, self._neg - z - self.drift)
        if self._pos > self.threshold:
            self.reset()
            return 1
        if self._neg > self.threshold:
            self.reset()
            return -1
        return 0

    def reset(self) -> None:
        self._pos = self._neg = 0.0


class PageHinkleyDetector(ChangePointDetector):
    """Two-sided Page–Hinkley test, one drift-corrected cumulative sum
    per side (a single shared sum would false-alarm on zero-mean
    streams — its own drift term walks it away from the extremum):
    upward, ``mU = sum(z - delta)`` alarms when it rises `threshold`
    above its running minimum; downward, ``mD = sum(z + delta)`` alarms
    when it falls `threshold` below its running maximum."""

    name = "ph"

    def __init__(self, threshold: float = 8.0, delta: float = 0.25, **kw):
        super().__init__(**kw)
        if threshold <= 0:
            raise ValueError(f"threshold must be positive, "
                             f"got {threshold}")
        if delta < 0:
            raise ValueError(f"delta must be >= 0, got {delta}")
        self.threshold = float(threshold)
        self.delta = float(delta)
        self._up = 0.0
        self._up_min = 0.0
        self._dn = 0.0
        self._dn_max = 0.0

    @property
    def statistic(self) -> float:
        return max(self._up - self._up_min, self._dn_max - self._dn)

    def update(self, residual: float,
               scale_sample: Optional[float] = None) -> int:
        z = self._standardize(residual, scale_sample)
        self._up += z - self.delta
        self._up_min = min(self._up_min, self._up)
        self._dn += z + self.delta
        self._dn_max = max(self._dn_max, self._dn)
        if self._up - self._up_min > self.threshold:
            self.reset()
            return 1
        if self._dn_max - self._dn > self.threshold:
            self.reset()
            return -1
        return 0

    def reset(self) -> None:
        self._up = self._up_min = 0.0
        self._dn = self._dn_max = 0.0


DETECTOR_REGISTRY = {
    "cusum": lambda arg: CusumDetector(
        threshold=float(arg) if arg else 8.0),
    "ph": lambda arg: PageHinkleyDetector(
        threshold=float(arg) if arg else 8.0),
}


def detector_names() -> List[str]:
    return ["cusum[:threshold]", "ph[:threshold]"]


def make_detector(spec: Union[str, ChangePointDetector]
                  ) -> ChangePointDetector:
    """Resolve a detector spec ("cusum[:threshold]", "ph[:threshold]",
    or a prebuilt instance — used as a per-device template)."""
    if isinstance(spec, ChangePointDetector):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"detector spec must be a ChangePointDetector "
                         f"or a str, got {type(spec).__name__}")
    head, arg = parse_spec(spec, kind="change-point detector",
                           heads=DETECTOR_REGISTRY,
                           known=detector_names(),
                           arg_heads=tuple(DETECTOR_REGISTRY),
                           numeric_arg_heads=tuple(DETECTOR_REGISTRY))
    return DETECTOR_REGISTRY[head](arg)


# --------------------------------------------------------------------------
# Adaptive controller: detector alarms -> mode-table walks
# --------------------------------------------------------------------------

class AdaptiveController:
    """Per-device regime-shift detection driving live mode switches.

    `modes` is an *ordered* table (least → most conservative) of
    `core.selection.ControlMode`s / registry names; every device starts
    at position `start`. Each device carries a **reference level** (its
    long-run prior mean initially) and a *tracker* estimator (spec
    `monitor`, one per device via an `EstimatorBank`) following the
    current level; every observed upload time feeds the residual
    ``observed − reference`` to the device's own change-point detector.
    An up-alarm escalates the device one mode, a down-alarm
    de-escalates, and on every accepted alarm the reference
    *re-anchors* to the tracker's current level — so a sustained shift
    fires exactly once and the detector is re-armed against the new
    level (the return shift shows up as a sustained residual of the
    opposite sign; a fast-adapting monitor alone would wash it out).
    `cooldown` further observations must pass before the device may
    switch again (anti-thrash). Switches are recorded in `events` with
    the global observation index, so captures can replay the
    adaptation sequence.
    """

    def __init__(self, modes: Sequence[Union[str, ControlMode]] =
                 ("stationary", "degraded"), *,
                 detector: Union[str, ChangePointDetector] = "cusum",
                 monitor: str = "ewma:0.2", cooldown: int = 8,
                 start: int = 0, scale_frac: float = 0.25,
                 name: str = "adaptive"):
        self.modes = [make_mode(m) for m in modes]
        if len(self.modes) < 2:
            raise ValueError("AdaptiveController needs at least two "
                             "modes (nothing to switch between)")
        names = [m.name for m in self.modes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mode names in table: {names}")
        for m in self.modes:
            if m.hedge not in HEDGE_MODES:
                raise ValueError(f"mode {m.name!r} has unknown hedge "
                                 f"{m.hedge!r}; known: "
                                 f"{', '.join(HEDGE_MODES)}")
            if m.t_estimator is not None:
                validate_estimator_spec(m.t_estimator)
        if not 0 <= start < len(self.modes):
            raise ValueError(f"start mode {start} out of range")
        if cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {cooldown}")
        self._detector_template = make_detector(detector)
        validate_estimator_spec(monitor)
        self.monitor = monitor
        self.cooldown = int(cooldown)
        self.start = int(start)
        if scale_frac <= 0:
            raise ValueError(f"scale_frac must be positive, "
                             f"got {scale_frac}")
        self.scale_frac = float(scale_frac)
        self.name = name
        self._priors: Optional[Dict] = None
        self._default_prior: Optional[float] = None
        self._bank: Optional[EstimatorBank] = None
        self._state: Dict[object, dict] = {}
        self._events: List[dict] = []
        self._n_seen = 0

    # -- lifecycle ---------------------------------------------------------

    def prime(self, priors: Optional[Dict] = None,
              default_prior: Optional[float] = None) -> None:
        """Install cold-start priors (device long-run means) for the
        monitor estimator and reset all per-device state — the start of
        a fresh run."""
        self._priors = dict(priors or {})
        self._default_prior = default_prior
        self.reset()

    def reset(self) -> None:
        self._bank = EstimatorBank(self.monitor, priors=self._priors,
                                   default_prior=self._default_prior)
        self._state.clear()
        self._events = []
        self._n_seen = 0

    @property
    def events(self) -> List[dict]:
        """Mode-switch events so far (copies; chronological)."""
        return [dict(e) for e in self._events]

    def mode_names(self) -> List[str]:
        return [m.name for m in self.modes]

    def mode_of(self, key) -> ControlMode:
        """The mode currently governing `key` (no state advance)."""
        st = self._state.get(key)
        return self.modes[self.start if st is None else st["mode"]]

    # -- the control step --------------------------------------------------

    def observe(self, key, t_input: float) -> ControlMode:
        """One scalar control step: residual against the device's
        reference level, detector update, possible switch (with
        re-anchoring), tracker update. Returns the mode governing the
        request that carried this observation (the measured upload time
        of the arriving request is available at admission, exactly like
        the 'observed' estimator's input)."""
        if self._bank is None:
            self.reset()
        x = float(t_input)
        pred = self._bank.estimate(key, observed=x)
        self._bank.observe(key, x)
        post = self._bank.estimate(key, observed=x)  # post-obs level
        return self._step(key, x, pred, post)

    def run_series(self, t_inputs, keys=None) -> np.ndarray:
        """Vectorized control steps over a whole trace: tracker
        estimates per device via the bank's `estimate_series`
        (identical to the interleaved scalar protocol — each device's
        estimator sees only its own stream), then the detectors walked
        causally in arrival order. Returns the (N,) per-request mode
        indices."""
        if self._bank is None:
            self.reset()
        t_inputs = np.asarray(t_inputs, np.float64)
        n = len(t_inputs)
        key_list = [None] * n if keys is None else list(keys)
        preds = self._bank.estimate_series(t_inputs, keys)
        # Post-observation tracker levels (the re-anchor targets):
        # within a device's positions the post-level after observation
        # j is the pre-estimate at its next position; the final
        # position reads the bank's current state.
        post = np.empty(n, np.float64)
        groups: Dict[object, list] = {}
        for i, k in enumerate(key_list):
            groups.setdefault(k, []).append(i)
        for k, pos_list in groups.items():
            pos = np.asarray(pos_list, np.intp)
            if len(pos) > 1:
                post[pos[:-1]] = preds[pos[1:]]
            post[pos[-1]] = self._bank.estimate(
                k, observed=float(t_inputs[pos[-1]]))
        out = np.empty(n, np.int64)
        for i in range(n):
            mode = self._step(key_list[i], float(t_inputs[i]),
                              float(preds[i]), float(post[i]))
            out[i] = self.modes.index(mode)
        return out

    def _init_state(self, key, pred: float) -> dict:
        det = copy.deepcopy(self._detector_template)
        prior = (self._priors or {}).get(key, self._default_prior)
        ref = float(prior) if prior is not None else float(pred)
        # Seed the detector's self-normalizing scale from the
        # reference level (mobile T_input dispersion is roughly
        # proportional to the mean) so one arbitrary first residual
        # does not define the unit.
        det.prime_scale(self.scale_frac * abs(ref))
        st = {"mode": self.start, "det": det, "cool": 0, "ref": ref}
        self._state[key] = st
        return st

    def _step(self, key, x: float, pred: float,
              post: float) -> ControlMode:
        st = self._state.get(key)
        if st is None:
            st = self._init_state(key, pred)
        i = self._n_seen
        self._n_seen += 1
        # Detect on the residual against the reference level; learn the
        # noise scale from the residual against the *tracker* (which
        # follows the current level, so its residuals measure process
        # noise even while the reference is offset by a shift).
        alarm = st["det"].update(x - st["ref"],
                                 scale_sample=abs(x - pred))
        if st["cool"] > 0:
            st["cool"] -= 1
        elif alarm:
            new = min(max(st["mode"] + (1 if alarm > 0 else -1), 0),
                      len(self.modes) - 1)
            if new != st["mode"]:
                # Switch: walk the mode table and re-anchor the
                # reference to the tracker's current level, so the
                # detector re-arms against the *new* regime (the return
                # shift is detected from here).
                self._events.append({
                    "request": i, "device": "" if key is None else
                    str(key), "from": self.modes[st["mode"]].name,
                    "to": self.modes[new].name, "alarm": int(alarm),
                    "ref": float(st["ref"]), "level": float(post)})
                st["mode"] = new
                st["cool"] = self.cooldown
                st["ref"] = float(post)
            elif alarm < 0:
                # Down-alarm at the bottom mode: conditions improved
                # below the reference (e.g. a prior that overstated the
                # radio) — track the better level. The symmetric case
                # (up-alarm at the top mode) deliberately does NOT
                # re-anchor: the alarm-conditioned tracker level is
                # spike-biased upward under heavy-tailed traffic, and
                # anchoring to it makes normal traffic look like a
                # recovery — the de-escalation thrash the cooldown
                # alone cannot prevent.
                st["ref"] = float(post)
        return self.modes[st["mode"]]


def controller_names() -> List[str]:
    from repro.configs.paper_zoo import CONTROLLER_SCENARIOS
    return sorted(CONTROLLER_SCENARIOS)


def make_controller(spec: Union[str, AdaptiveController, None]
                    ) -> Optional[AdaptiveController]:
    """Resolve a controller spec: None -> None, an instance passes
    through, a string names a `configs/paper_zoo.CONTROLLER_SCENARIOS`
    preset."""
    if spec is None or isinstance(spec, AdaptiveController):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"controller spec must be an "
                         f"AdaptiveController, a str, or None, got "
                         f"{type(spec).__name__}")
    from repro.configs.paper_zoo import CONTROLLER_SCENARIOS
    if spec not in CONTROLLER_SCENARIOS:
        raise ValueError(f"unknown controller {spec!r}; known: "
                         f"{', '.join(controller_names())}")
    d = CONTROLLER_SCENARIOS[spec]
    return AdaptiveController(
        modes=d.get("modes", ("stationary", "degraded")),
        detector=d.get("detector", "cusum"),
        monitor=d.get("monitor", "ewma:0.2"),
        cooldown=d.get("cooldown", 8), start=d.get("start", 0),
        name=spec)


# --------------------------------------------------------------------------
# The control plane (the shared per-request serving step)
# --------------------------------------------------------------------------

@dataclass
class ControlDecision:
    """One request's control-step outcome (scalar path)."""

    index: int                 # model index; meaningless when fallback
    name: str                  # model name ("<on-device>" on fallback)
    t_est: float               # budget-side T_input used for selection
    mode: str = "static"       # governing mode name
    degraded: bool = False     # degraded-regime flag
    hedge: bool = False        # replica hedge recommended (outage mode)
    fallback: bool = False     # serve on-device, do not upload


@dataclass
class BatchPlan:
    """A whole trace's control plan (the simulator path): budget
    estimates, selections, hedging gates, fallback masks, and — with a
    controller — per-request modes plus the switch events."""

    t_est: np.ndarray                       # (N,) budget-side estimates
    sel: np.ndarray                         # (N,) int64 model indices
    p95_gate: np.ndarray                    # (N,) bool: p95 hedging armed
    outage_gate: np.ndarray                 # (N,) bool: hedge this request
    degraded: Optional[np.ndarray] = None   # (N,) bool
    fb_mask: Optional[np.ndarray] = None    # (N,) bool: serve on-device
    od_latency: Optional[np.ndarray] = None
    od_accuracy: Optional[np.ndarray] = None
    modes: Optional[np.ndarray] = None      # (N,) int64 mode indices
    mode_names: Optional[List[str]] = None
    events: List[dict] = field(default_factory=list)


class ControlPlane:
    """The per-request serving step, extracted once for all stacks.

    Wraps a `Router` (which owns profiles, policy, zoo, queues, and the
    base estimator) with the hedging/fallback decision logic and an
    optional `AdaptiveController`. Scalar drivers (`CNNSelectServer`,
    `ServingLoop`) call `step` / `observe_outcome` per request; the
    simulator calls `plan_batch` over the whole trace. With
    ``controller=None`` both paths reproduce the pre-refactor behaviour
    exactly (the static `plan_batch` is RNG-flow-identical to the old
    inline simulator sequence — the golden pins depend on it).
    """

    def __init__(self, router, *, hedge: str = "none",
                 outage_factor: float = 2.0,
                 on_device_fallback: bool = True,
                 controller: Union[str, AdaptiveController, None] = None,
                 priors: Optional[Dict] = None,
                 default_prior: Optional[float] = None,
                 lag: int = 0, seed: int = 0,
                 t_threshold: float = 50.0,
                 stage2_variant: str = "figure", chunk: int = 2048):
        if hedge not in HEDGE_MODES:
            raise ValueError(f"unknown hedge mode {hedge!r}; known: "
                             f"{', '.join(HEDGE_MODES)}")
        self.router = router
        self.hedge = hedge
        self.outage_factor = float(outage_factor)
        self.on_device_fallback = bool(on_device_fallback)
        self.controller = make_controller(controller)
        self.priors = dict(priors or {})
        self.default_prior = default_prior
        self.lag = int(lag)
        self._policy_kw = dict(t_threshold=t_threshold,
                               stage2_variant=stage2_variant, chunk=chunk)
        self._seed = int(seed)
        self._banks: Dict[Optional[str], Optional[EstimatorBank]] = {}
        self._mode_policies: Dict[str, Policy] = {}
        if self.controller is not None:
            # Prime (reset) the controller with this run's priors —
            # unless the plane has none to give and the caller already
            # primed it (e.g. AdaptiveController.prime({...}) passed to
            # a CNNSelectServer/ServingLoop, which carry no fleet
            # priors themselves): re-priming would wipe those.
            if (self.priors or self.default_prior is not None
                    or self.controller._priors is None):
                self.controller.prime(self.priors, self.default_prior)
            # One bank per estimator spec in the mode table, all fed
            # every observation, so a switch lands on a warm estimator.
            for m in self.controller.modes:
                self._bank_for(m.t_estimator)

    # -- shared helpers ----------------------------------------------------

    def _bank_for(self, spec: Optional[str]) -> Optional[EstimatorBank]:
        if spec not in self._banks:
            self._banks[spec] = None if spec is None else EstimatorBank(
                spec, priors=self.priors,
                default_prior=self.default_prior, lag=self.lag)
        return self._banks[spec]

    def _policy_for(self, mode: ControlMode) -> Policy:
        """The mode's policy override instance (base policy when the
        mode does not override), seeded per mode so runs are
        deterministic."""
        if mode.policy is None:
            return self.router.policy
        pol = self._mode_policies.get(mode.name)
        if pol is None:
            idx = self.controller.modes.index(mode)
            seed = int(np.random.SeedSequence(
                [self._seed, 3, idx]).generate_state(1)[0])
            pol = make_policy(mode.policy, seed=seed, **self._policy_kw)
            self._mode_policies[mode.name] = pol
        return pol

    def _static_prior(self, device_id) -> Optional[float]:
        """The degradation reference for the static outage detector:
        the device's estimator prior (its long-run mean)."""
        est = self.router.t_estimator
        if isinstance(est, EstimatorBank):
            return est.prior_for(device_id)
        if est is not None and est.prior is not None:
            return float(est.prior)
        return self.priors.get(device_id, self.default_prior)

    def _spike_prior(self, device_id) -> Optional[float]:
        """The reference for the per-request outage spike rule in
        adaptive modes: the controller's priors, the plane's/router's,
        or — when no offline prior exists at all (server/loop without
        fleet info) — the controller's current per-device reference
        level, which tracks the device's normal operating level."""
        if self.controller is not None:
            prior = (self.controller._priors or {}).get(
                device_id, self.controller._default_prior)
            if prior is not None:
                return float(prior)
        prior = self._static_prior(device_id)
        if prior is not None:
            return prior
        if self.controller is not None:
            st = self.controller._state.get(device_id)
            if st is not None:
                return float(st["ref"])
        return None

    def _fastest_mu(self) -> float:
        return min(p.mu for p in self.router.current_profiles())

    # -- scalar path (server / loop) ---------------------------------------

    def step(self, t_sla: float, t_input: float, *,
             device_id: Optional[str] = None,
             realized: Optional[np.ndarray] = None,
             on_device_ms: float = 0.0) -> ControlDecision:
        """One request's control step: estimate, (maybe) adapt, select,
        gate hedging/fallback. No zoo side effects — scalar drivers pay
        cold starts themselves, exactly as before the extraction."""
        if self.controller is None:
            est = self.router.observe_t_input(t_input, device_id)
            mode_name, degraded, fb_allowed = "static", False, \
                self.on_device_fallback
            hedge_mode = self.hedge
            if hedge_mode == "outage":
                prior = self._static_prior(device_id)
                degraded = (prior is not None
                            and est > self.outage_factor * prior)
            idx = self.router.select(t_sla, est, realized=realized)
        else:
            mode = self.controller.observe(device_id, t_input)
            mode_name = mode.name
            hedge_mode, fb_allowed = mode.hedge, mode.on_device_fallback
            bank = self._bank_for(mode.t_estimator)
            est = (float(t_input) if bank is None
                   else bank.estimate(device_id, observed=t_input))
            for b in self._banks.values():      # keep every bank warm
                if b is not None:
                    b.observe(device_id, float(t_input))
            # A mode with degraded=True treats the whole regime as
            # degraded (detection is the signal); a non-degraded mode
            # with the outage valve armed gates per request on the
            # outage_factor spike rule — exactly the static behaviour.
            degraded = mode.degraded
            if not degraded and hedge_mode == "outage":
                prior = self._spike_prior(device_id)
                degraded = (prior is not None
                            and est > self.outage_factor * prior)
            pol = self._policy_for(mode)
            idx = (self.router.select(t_sla, est, realized=realized)
                   if mode.policy is None else
                   pol.select(self.router.current_profiles(), t_sla,
                              est, realized=realized))
        fallback = bool(
            fb_allowed and degraded and hedge_mode == "outage"
            and on_device_ms > 0.0
            and on_device_fallback_decision(t_sla, est,
                                            self._fastest_mu(),
                                            on_device_ms))
        if fallback:
            return ControlDecision(index=-1, name="<on-device>",
                                   t_est=float(est), mode=mode_name,
                                   degraded=True, fallback=True)
        return ControlDecision(
            index=int(idx), name=self.router.order[int(idx)],
            t_est=float(est), mode=mode_name, degraded=bool(degraded),
            hedge=bool(hedge_mode == "outage" and degraded))

    def observe_outcome(self, name: str, latency_ms: float, *,
                        cold: bool = False, now: float = 0.0) -> None:
        """Feed one measured model latency back into the online
        profiles (the outcome half of the control step)."""
        self.router.record(name, latency_ms, cold=cold, now=now)

    # -- vectorized path (simulator) ---------------------------------------

    def plan_batch(self, rng: np.random.Generator, t_sla: float,
                   t_inputs: np.ndarray, *, device_keys=None,
                   realized: Optional[np.ndarray] = None,
                   prior_mean: Optional[np.ndarray] = None,
                   on_device=None,
                   estimator_scope: str = "device") -> BatchPlan:
        """The whole trace's control plan. `on_device` is the
        per-request ``(od_ms, od_sigma, od_accuracy)`` array triple of
        the issuing devices (None = no on-device capability anywhere);
        `prior_mean` is the per-request device long-run mean (the
        static outage detector's reference). Static path: identical
        operations in identical order to the pre-extraction simulator
        (RNG-flow compatible — golden-pinned)."""
        t_inputs = np.asarray(t_inputs, np.float64)
        n = len(t_inputs)
        est_keys = device_keys if estimator_scope == "device" else None
        if self.controller is None:
            return self._plan_static(rng, t_sla, t_inputs, est_keys,
                                     realized, prior_mean, on_device, n)
        return self._plan_adaptive(rng, t_sla, t_inputs, est_keys,
                                   realized, prior_mean, on_device, n)

    def _plan_static(self, rng, t_sla, t_inputs, est_keys, realized,
                     prior_mean, on_device, n) -> BatchPlan:
        t_est = self.router.estimate_series(t_inputs,
                                            device_ids=est_keys)
        return self.finish_static(rng, t_sla, t_est, realized,
                                  prior_mean, on_device, n)

    def finish_static(self, rng, t_sla, t_est, realized, prior_mean,
                      on_device, n) -> BatchPlan:
        """Phase 2 of the static plan — selection, outage masks, and
        the fallback-latency draws — over already-materialized budget
        estimates. Split from the estimation phase so the scan engine
        (serving/scan_engine.py) can compute `t_est` with its array
        program and then share this exact selection/masking code (and
        its RNG consumption order) with the python path."""
        sel = np.asarray(self.router.route_batch(
            np.full(n, t_sla), t_est, realized=realized,
            estimated=True), np.int64)
        degraded = fb_mask = od_latency = od_accuracy = None
        if self.hedge == "outage":
            degraded = t_est > self.outage_factor * prior_mean
            if on_device is not None and self.on_device_fallback:
                od_ms, od_sg, od_acc = on_device
                fb_mask = degraded & on_device_fallback_decision(
                    t_sla, t_est, self._fastest_mu(), od_ms)
                od_latency = np.maximum(
                    rng.normal(od_ms, od_sg + 1e-9),
                    0.1 * np.maximum(od_ms, 1e-9))
                od_accuracy = od_acc
        return BatchPlan(
            t_est=t_est, sel=sel,
            p95_gate=np.full(n, self.hedge == "p95"),
            outage_gate=(degraded if degraded is not None
                         else np.zeros(n, bool)),
            degraded=degraded, fb_mask=fb_mask, od_latency=od_latency,
            od_accuracy=od_accuracy)

    def _plan_adaptive(self, rng, t_sla, t_inputs, est_keys, realized,
                       prior_mean, on_device, n) -> BatchPlan:
        ctrl = self.controller
        modes_idx = ctrl.run_series(t_inputs, keys=est_keys)
        # Budget estimates: every estimator spec in the table runs over
        # the full trace (causal, per device), so a switched-to
        # estimator is already warm; each request reads the series of
        # its governing mode.
        series: Dict[Optional[str], np.ndarray] = {}
        for spec in {m.t_estimator for m in ctrl.modes}:
            bank = self._bank_for(spec)
            series[spec] = (t_inputs.copy() if bank is None else
                            bank.estimate_series(t_inputs, est_keys))
        t_est = self.compose_adaptive_estimates(series, modes_idx, n)
        return self.finish_adaptive(rng, t_sla, t_est, modes_idx,
                                    ctrl.events, realized, prior_mean,
                                    on_device, n)

    def compose_adaptive_estimates(self, series: Dict, modes_idx,
                                   n: int) -> np.ndarray:
        """Each request's budget estimate read from the series of its
        governing mode's estimator spec (shared by both engines)."""
        t_est = np.empty(n, np.float64)
        for k, m in enumerate(self.controller.modes):
            mask = modes_idx == k
            if mask.any():
                t_est[mask] = series[m.t_estimator][mask]
        return t_est

    def finish_adaptive(self, rng, t_sla, t_est, modes_idx, events,
                        realized, prior_mean, on_device,
                        n) -> BatchPlan:
        """Phase 2 of the adaptive plan — per-mode selection, hedging
        gates, fallback masks/draws — over already-materialized budget
        estimates and per-request mode indices. The scan engine feeds
        this with its array-program outputs; the RNG consumption order
        (the one `rng.normal` fallback-latency draw) is identical to
        the python path's."""
        ctrl = self.controller
        mode_list = ctrl.modes
        # Selection: requests grouped by governing policy (base policy
        # for modes that do not override it).
        sel = np.empty(n, np.int64)
        t_sla_vec = np.full(n, t_sla)
        base_mask = np.zeros(n, bool)
        for k, m in enumerate(mode_list):
            mask = modes_idx == k
            if not mask.any():
                continue
            if m.policy is None:
                base_mask |= mask
                continue
            pol = self._policy_for(m)
            sel[mask] = np.asarray(pol.select_batch(
                self.router.current_profiles(), t_sla_vec[mask],
                t_est[mask],
                realized=None if realized is None else realized[mask]),
                np.int64)
        if base_mask.any():
            sel[base_mask] = np.asarray(self.router.route_batch(
                t_sla_vec[base_mask], t_est[base_mask],
                realized=None if realized is None else
                realized[base_mask], estimated=True), np.int64)
        # Hedging gates / fallback. A degraded=True mode treats its
        # whole regime as degraded (detection is the signal); a
        # non-degraded mode with hedge="outage" keeps the per-request
        # outage_factor spike rule armed — the static safety valve for
        # individual hopeless uploads that are not a regime shift.
        hedge_kind = np.array([HEDGE_MODES.index(m.hedge)
                               for m in mode_list])[modes_idx]
        outage_armed = hedge_kind == HEDGE_MODES.index("outage")
        degraded = np.array([m.degraded for m in mode_list])[modes_idx]
        if prior_mean is not None:
            degraded = degraded | (
                outage_armed
                & (t_est > self.outage_factor * prior_mean))
        p95_gate = hedge_kind == HEDGE_MODES.index("p95")
        outage_gate = outage_armed & degraded
        fb_mask = od_latency = od_accuracy = None
        fb_allowed = np.array([m.on_device_fallback
                               for m in mode_list])[modes_idx]
        if on_device is not None and any(m.on_device_fallback
                                         for m in mode_list):
            od_ms, od_sg, od_acc = on_device
            fb_mask = (fb_allowed & outage_gate
                       & on_device_fallback_decision(
                           t_sla, t_est, self._fastest_mu(), od_ms))
            od_latency = np.maximum(
                rng.normal(od_ms, od_sg + 1e-9),
                0.1 * np.maximum(od_ms, 1e-9))
            od_accuracy = od_acc
        return BatchPlan(
            t_est=t_est, sel=sel, p95_gate=p95_gate,
            outage_gate=outage_gate, degraded=degraded,
            fb_mask=fb_mask, od_latency=od_latency,
            od_accuracy=od_accuracy, modes=np.asarray(modes_idx,
                                                      np.int64),
            mode_names=ctrl.mode_names(), events=list(events))
