"""The multi-tenant cluster control plane as a jit `lax.scan` program.

`Cluster.run(engine="scan")` lands here. The python `Cluster` is the
reference implementation; this engine reproduces it **bit-for-bit** —
every integer decision (selection, placement, eviction, scale, shed,
hedge winner) and every float in the metrics ledger — by splitting the
per-request loop into three phases:

1. **Controller columns (sharded).** The `AdaptiveController` is
   per-device state with no cross-device coupling, so it runs as the
   existing scan_engine (L, D) column program — `ctrl_desc_from_
   controller` + `_pack_columns` + `_run_program`, sharded across host
   devices via `repro.utils.shard_map` exactly like the single-stack
   engine. Output: each request's governing mode and the chronological
   switch-event list (the scale-up/down triggers).

2. **Selection / RNG precompute (numpy).** Policy decisions depend
   only on the request row, never on queue state, so cnnselect's
   3-stage probs collapse to one vectorized (N, K) mirror (same op
   order as `core.selection.cnnselect`) and each replica's gaussian /
   uniform / integer draws are pre-drawn from **deepcopies** of the
   live generators (`BlockNormals` blocks are bit-for-bit the scalar
   stream). After the scan, the live generators advance by exactly the
   consumed counts, so python and scan paths leave identical RNG
   state.

3. **The cluster scan (jit, request axis).** What remains coupled
   across requests is the small cluster state: per-replica free time
   (R,), flat hot/LRU state (R*K,), the global hot-byte count, and the
   active-prefix size. One `lax.scan` over the N arrival-ordered
   requests mirrors `Cluster.submit` op-for-op: switch-scale,
   least-delay placement over the active prefix (ties: capacity, then
   index — resolved by exact float equality, the same total order as
   python's tuple sort), load-scale, priority shedding, the placer's
   global-LRU evict loop (`lax.while_loop`, first-argmin = dict-order
   first-min), cold-start + exec sampling, and degraded-regime
   two-replica hedging with strict first-completion-wins. This axis is
   sequential by construction (every request sees the queues its
   predecessors left), so it is *not* sharded — the device-axis work
   in phase 1 is.

Equivalence discipline (DESIGN.md §17): events replay through
`replay_events` unchanged, `cluster.metrics.records` match the python
engine's floats bitwise, and replica zoos / rngs / free-times are
written back so a scan run is indistinguishable from a python run —
with one documented exception: per-replica `metrics` ledgers stay
empty (the cluster ledger is authoritative; the python engine's
replica rows are a byproduct of calling `SimReplicaStack.submit`).
"""

from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.selection import (CNNSelectPolicy, GreedyPolicy,
                                  RandomPolicy, StaticPolicy)
from repro.serving.cluster import Cluster, TenantColumns
from repro.serving.scan_engine import (_assemble_events, _pack_columns,
                                       _run_program, _unfused, _unpack,
                                       ctrl_desc_from_controller)
from repro.serving.stack import SimReplicaStack

__all__ = ["scan_cluster_run", "ClusterScanResult"]

_EPS = 1e-9


# --------------------------------------------------------------------------
# Validation: the python semantics this engine mirrors
# --------------------------------------------------------------------------

def _validate(cluster: Cluster) -> List[str]:
    """Reject configurations whose python path this engine does not
    mirror, *before* any state is touched. Returns the shared model
    name order."""
    if cluster._n or cluster.events:
        raise ValueError("engine='scan' needs a fresh cluster (no "
                         "requests submitted, no events)")
    names = None
    seen_pol, seen_rng = set(), set()
    for r in cluster.replicas:
        if type(r) is not SimReplicaStack:
            raise ValueError(
                f"engine='scan' supports SimReplicaStack replicas only "
                f"(got {type(r).__name__}); use engine='python'")
        if r.control.controller is not None:
            raise ValueError("engine='scan' cluster replicas must not "
                             "carry their own AdaptiveController (the "
                             "cluster controller is the one modeled)")
        if r.router.t_estimator is not None:
            raise ValueError("engine='scan' cluster replicas must use "
                             "the identity budget estimator "
                             "(t_estimator=None)")
        if r.control.hedge != "none":
            raise ValueError("engine='scan' cluster replicas must not "
                             "hedge internally (cluster-level hedging "
                             "is the modeled mechanism)")
        if r.router.zoo.memory_budget is not None:
            raise ValueError("engine='scan' cluster replicas must not "
                             "carry a per-zoo memory budget (the "
                             "ClusterPlacer owns the global budget)")
        if r._placer is not cluster.placer:
            raise ValueError("replica is not attached to this "
                             "cluster's placer")
        rn = tuple(r.router.order)
        if names is None:
            names = rn
        elif rn != names:
            raise ValueError("engine='scan' needs an identical model "
                             "registration order on every replica")
        pol = r.router.policy
        if type(pol) not in (CNNSelectPolicy, GreedyPolicy,
                             RandomPolicy, StaticPolicy):
            raise ValueError(
                f"engine='scan' cannot mirror policy "
                f"{type(pol).__name__}; use engine='python'")
        if id(pol) in seen_pol or id(r.rng) in seen_rng:
            raise ValueError("replicas must not share policy / rng "
                             "objects (per-replica draw streams)")
        seen_pol.add(id(pol))
        seen_rng.add(id(r.rng))
    ctrl = cluster.controller
    if ctrl is not None and (ctrl._n_seen or ctrl._events):
        raise ValueError("engine='scan' needs a pristine cluster "
                         "controller (no observations yet)")
    return list(names)


# --------------------------------------------------------------------------
# Workload columns: one layout for TenantColumns and Request lists
# --------------------------------------------------------------------------

@dataclass
class _Work:
    n: int
    arrival: np.ndarray       # (N,) f64
    t_input: np.ndarray       # (N,) f64
    dev_col: np.ndarray       # (N,) int64
    priors: np.ndarray        # (D,) f64 per-column controller prior
    device_names: object      # indexable column -> name (str() applied)
    t_sla_c: np.ndarray       # (N,) cluster-level deadline (shed/scale)
    t_sla_r: np.ndarray       # (N,) replica-level deadline (selection)
    has_sla: np.ndarray       # (N,) bool
    prio: np.ndarray          # (N,) f64 shed priority
    od: np.ndarray            # (N,) f64 on-device latency
    cols: Optional[TenantColumns] = None
    reqs: Optional[list] = None

    def dev_str(self, i: int) -> str:
        """The device string python events carry (str(key), "" for
        None) for request i."""
        name = self.device_names[self.dev_col[i]]
        return "" if name is None else str(name)

    def tenant_str(self, i: int) -> str:
        if self.cols is not None:
            return self.cols.tenants[self.cols.tenant_idx[i]].name
        return self.reqs[i].tenant or ""


def _work_from_columns(cluster: Cluster, cols: TenantColumns) -> _Work:
    n = len(cols)
    T = len(cols.tenants)
    tsc = np.empty(T)
    tsr = np.empty(T)
    has = np.empty(T, bool)
    pr = np.empty(T)
    for ti, t in enumerate(cols.tenants):
        ct = cluster.tenants.get(t.name or "")
        sla = t.t_sla          # == req.sla_ms for this tenant's rows
        c = sla or (ct.t_sla if ct is not None else 1e9)
        r = sla or 1e9
        if c is None or r is None:
            raise ValueError(f"tenant {t.name!r} has no SLA")
        tsc[ti], tsr[ti], has[ti] = c, r, bool(sla)
        pr[ti] = ct.shed_priority if ct is not None else 0
    tid = cols.tenant_idx
    return _Work(
        n=n, arrival=np.asarray(cols.arrival, np.float64),
        t_input=np.asarray(cols.t_input, np.float64),
        dev_col=np.asarray(cols.col, np.int64),
        priors=np.asarray(cols.col_prior, np.float64),
        device_names=cols, t_sla_c=tsc[tid], t_sla_r=tsr[tid],
        has_sla=has[tid], prio=pr[tid],
        od=np.asarray(cols.col_od_ms, np.float64)[cols.col],
        cols=cols)


def _work_from_requests(cluster: Cluster, requests) -> _Work:
    reqs = sorted(requests, key=lambda r: r.arrival)
    n = len(reqs)
    ctrl = cluster.controller
    col_of: Dict[object, int] = {}
    names: List[object] = []
    priors: List[float] = []
    arr = np.empty(n)
    ti_ = np.empty(n)
    dev = np.empty(n, np.int64)
    tsc = np.empty(n)
    tsr = np.empty(n)
    has = np.empty(n, bool)
    pr = np.empty(n)
    od = np.empty(n)
    for i, req in enumerate(reqs):
        key = req.device_id
        c = col_of.get(key)
        if c is None:
            c = col_of[key] = len(names)
            # Store the python event string form ("" for None), so
            # `_assemble_events` / `dev_str` emit what the python
            # controller would.
            names.append("" if key is None else str(key))
            if ctrl is not None:
                p = (ctrl._priors or {}).get(key, ctrl._default_prior)
                if p is None:
                    raise ValueError(
                        f"engine='scan' adaptive control needs a "
                        f"prior for every device (missing: {key!r})")
                priors.append(float(p))
            else:
                priors.append(np.nan)
        t = cluster.tenants.get(req.tenant or "")
        sla_c = req.sla_ms or (t.t_sla if t is not None else 1e9)
        if sla_c is None:
            raise ValueError(f"request {req.rid} has no SLA")
        arr[i], ti_[i], dev[i] = req.arrival, req.t_input_ms, c
        tsc[i], tsr[i] = sla_c, req.sla_ms or 1e9
        has[i] = bool(req.sla_ms)
        pr[i] = t.shed_priority if t is not None else 0
        od[i] = cluster.on_device_ms.get(req.device_id or "", 0.0)
    return _Work(n=n, arrival=arr, t_input=ti_, dev_col=dev,
                 priors=np.asarray(priors, np.float64),
                 device_names=names, t_sla_c=tsc, t_sla_r=tsr,
                 has_sla=has, prio=pr, od=od, reqs=reqs)


# --------------------------------------------------------------------------
# Phase 2: vectorized policy mirrors + pre-drawn RNG streams
# --------------------------------------------------------------------------

def _cnn_cdf(profiles, pol: CNNSelectPolicy, t_sla: np.ndarray,
             t_input: np.ndarray) -> np.ndarray:
    """`core.selection.cnnselect` stages 1-3 over N requests at once,
    op-for-op in f64 (same expression order, so the probabilities are
    bitwise the scalar path's), returning the normalized CDF rows that
    `rng.choice(K, p=probs)` searches with one uniform draw."""
    acc = np.array([p.accuracy for p in profiles], np.float64)
    mu = np.array([p.mu for p in profiles], np.float64)
    sg = np.array([p.sigma for p in profiles], np.float64)
    N = len(t_sla)
    t_up = t_sla - 2.0 * t_input                 # network_budget
    t_low = t_up - pol.t_threshold
    musg = mu + sg
    feas = ((musg[None, :] < t_up[:, None])
            & ((mu - sg)[None, :] < t_low[:, None]))
    any_f = feas.any(axis=1)
    masked = np.where(feas, acc[None, :], -np.inf)
    best = masked.max(axis=1)
    cand = masked >= (best - 1e-12)[:, None]
    base = np.where(
        any_f,
        np.argmin(np.where(cand, mu[None, :], np.inf), axis=1),
        int(np.argmin(mu)))
    mu_b, sg_b = mu[base], sg[base]
    if pol.stage2_variant == "figure":
        delta = np.abs(t_low - mu_b) + sg_b
        lo, hi = t_low - delta, t_low + delta
    else:                                        # "text"
        a = mu_b + sg_b
        b = 2.0 * t_low - mu_b + sg_b
        swap = t_low > mu_b
        lo, hi = np.where(swap, a, b), np.where(swap, b, a)
    elig = ((mu[None, :] >= lo[:, None]) & (mu[None, :] <= hi[:, None])
            & (musg[None, :] < t_up[:, None]))
    rows = np.arange(N)
    elig[rows, base] = True
    onehot = np.zeros_like(elig)
    onehot[rows, base] = True
    elig = np.where(any_f[:, None], elig, onehot)
    util = (acc[None, :] * (t_up[:, None] - musg[None, :])
            / np.maximum(np.abs(t_low[:, None] - mu[None, :]), _EPS))
    util = np.where(elig, np.maximum(util, _EPS), 0.0)
    total = util.sum(axis=1)
    pos = total > 0
    probs = np.where(
        pos[:, None],
        util / np.where(pos, total, 1.0)[:, None],
        elig / elig.sum(axis=1, keepdims=True))
    cdf = np.cumsum(probs, axis=1)
    cdf /= cdf[:, -1:]
    return cdf


def _greedy_sel(profiles, pol: GreedyPolicy, t_sla: np.ndarray,
                t_input: np.ndarray) -> np.ndarray:
    acc = np.array([p.accuracy for p in profiles])
    mu = np.array([p.mu for p in profiles])
    budget = (t_sla - 2.0 * t_input) if pol.use_network else t_sla
    ok = mu[None, :] <= budget[:, None]
    masked = np.where(ok, acc[None, :], -np.inf)
    return np.where(ok.any(axis=1), np.argmax(masked, axis=1),
                    int(np.argmin(mu)))


@dataclass
class _Draws:
    kind: np.ndarray          # (R,) 0=deterministic 1=cnn 2=random
    sel: np.ndarray           # (N, R) int32 precomputed det choices
    cdf: np.ndarray           # (N, R, K or 0) f64 cnnselect CDF rows
    u: np.ndarray             # (R, N or 1) f64 choice uniforms
    ri: np.ndarray            # (R, N or 1) int32 random-policy draws
    z: np.ndarray             # (R, 2N) f64 exec/cold standard normals


def _predraw(cluster: Cluster, work: _Work, K: int) -> _Draws:
    R = len(cluster.replicas)
    N = work.n
    kind = np.zeros(R, np.int32)
    sel = np.zeros((N, R), np.int32)
    cdf_rows: List[Optional[np.ndarray]] = [None] * R
    u_rows: List[Optional[np.ndarray]] = [None] * R
    ri_rows: List[Optional[np.ndarray]] = [None] * R
    z = np.empty((R, 2 * N))
    for r, rep in enumerate(cluster.replicas):
        pol = rep.router.policy
        profs = rep.router.current_profiles()
        if type(pol) is CNNSelectPolicy:
            kind[r] = 1
            cdf_rows[r] = _cnn_cdf(profs, pol, work.t_sla_r,
                                   work.t_input)
            u_rows[r] = copy.deepcopy(pol.rng).random(N)
        elif type(pol) is RandomPolicy:
            kind[r] = 2
            ri_rows[r] = copy.deepcopy(pol.rng).integers(
                K, size=N).astype(np.int32)
        elif type(pol) is GreedyPolicy:
            sel[:, r] = _greedy_sel(profs, pol, work.t_sla_r,
                                    work.t_input)
        else:                                    # StaticPolicy
            sel[:, r] = pol._index(profs)
        z[r] = copy.deepcopy(rep.rng).take(2 * N)
    any_cnn = bool((kind == 1).any())
    any_rnd = bool((kind == 2).any())
    cdf = np.zeros((N, R, K if any_cnn else 0))
    u = np.zeros((R, N if any_cnn else 1))
    ri = np.zeros((R, N if any_rnd else 1), np.int32)
    for r in range(R):
        if cdf_rows[r] is not None:
            cdf[:, r, :] = cdf_rows[r]
        if u_rows[r] is not None:
            u[r] = u_rows[r]
        if ri_rows[r] is not None:
            ri[r] = ri_rows[r]
    return _Draws(kind=kind, sel=sel, cdf=cdf, u=u, ri=ri, z=z)


# --------------------------------------------------------------------------
# Phase 3: the jitted request-axis scan
# --------------------------------------------------------------------------

_COMPILED: Dict[tuple, object] = {}


def _compile(R: int, K: int, has_budget: bool):
    key = (R, K, has_budget)
    fn = _COMPILED.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp
    from jax import lax

    E = R * K
    idx_e = jnp.arange(E)
    idx_r = jnp.arange(R)

    def leg(free, hot, last, hb, up, zp, on, j, x, const):
        """One replica submit (`SimReplicaStack.submit` through the
        `ClusterPlacer`), masked by `on`."""
        kindj = const["kind"][j]
        sel = jnp.where(
            kindj == 1,
            jnp.sum((x["cdf"][j] <= const["u"][j, up[j]])
                    .astype(jnp.int32)),          # searchsorted right
            jnp.where(kindj == 2, const["ri"][j, up[j]], x["sel"][j]))
        up = up.at[j].add(jnp.where(on & (kindj != 0), 1, 0))
        flat = j * K + sel
        was_hot = hot[flat]
        need = on & ~was_hot
        vict = jnp.full((E,), -1, jnp.int32)
        if has_budget:
            size = const["sizes"][flat]

            def cond(c):
                hot_c, _, _, hb_c = c
                cand = hot_c & (idx_e != flat)
                return (need & (hb_c + size > const["budget"])
                        & jnp.any(cand))

            def body(c):
                hot_c, vict_c, cnt, hb_c = c
                cand = hot_c & (idx_e != flat)
                v = jnp.argmin(jnp.where(cand, last, jnp.inf))
                return (hot_c.at[v].set(False),
                        vict_c.at[cnt].set(v.astype(jnp.int32)),
                        cnt + 1, hb_c - const["sizes"][v])

            hot, vict, _, hb = lax.while_loop(
                cond, body, (hot, vict, jnp.int32(0), hb))
        last = last.at[flat].set(jnp.where(on, x["arr"], last[flat]))
        hot = hot.at[flat].set(jnp.where(on, True, hot[flat]))
        hb = hb + jnp.where(need, const["sizes"][flat], 0)
        xmu = const["xmu"][flat]
        needs_z = (need & (xmu > 0.0)).astype(jnp.int32)
        zc = const["z"][j, zp[j]]
        # _unfused (scan_engine): every mul feeding an add is rounded
        # separately, or XLA:CPU contracts the pair into one fma —
        # numpy rounds twice, and bitwise parity with the python
        # engine is the contract here.
        startup = jnp.where(
            need,
            jnp.where(
                xmu > 0.0,
                jnp.maximum(
                    xmu + _unfused(const["xsgp"][flat] * zc, jnp), 0.0),
                xmu),
            0.0)
        ze = const["z"][j, zp[j] + needs_z]
        zp = zp.at[j].add(jnp.where(on, 1 + needs_z, 0))
        exc = (jnp.maximum(
                   const["mu"][flat]
                   + _unfused(const["sgp"][flat] * ze, jnp),
                   const["p1mu"][flat])
               / const["speed"][j] + startup)
        arrive = x["arr"] + x["ti"]
        start = jnp.maximum(arrive, free[j])
        queue = start - arrive
        free = free.at[j].set(jnp.where(on, start + exc, free[j]))
        e2e = _unfused(2.0 * x["ti"], jnp) + queue + exc
        return ((free, hot, last, hb, up, zp),
                (sel, flat, need, vict, queue, exc, e2e))

    def run(xs, init, const):
        def step(carry, x):
            free, hot, last, hb, n_act, up, zp = carry
            # 1. controller-alarm scale (request index self._n = i+1)
            al = x["al"].astype(jnp.int32)
            want = jnp.clip(n_act + jnp.sign(al),
                            const["min_active"], R)
            do1 = (al != 0) & (want != n_act)
            n1 = jnp.where(do1, want, n_act)
            # 2. active-prefix queue delays
            arrive = x["arr"] + x["ti"]
            delays = jnp.maximum(0.0, free - arrive)
            md1 = jnp.min(jnp.where(idx_r < n1, delays, jnp.inf))
            # 3. sustained-queueing scale-up
            grow = ((md1 > const["headroom"] * x["slac"]) & (n1 < R))
            n2 = n1 + grow.astype(n1.dtype)
            dmask = jnp.where(idx_r < n2, delays, jnp.inf)
            md2 = jnp.min(dmask)
            # 4. SLA-class-priority shed
            thresh = ((const["shed_factor"] * x["slac"])
                      * (1.0 + x["prio"]))
            shed = ((md2 > thresh) & (x["od"] > 0.0)
                    & ((x["od"] <= x["slac"]) | (md2 > 2.0 * thresh)))
            serve = ~shed
            # 5. placement order: (delay, -capacity, index) lexmin —
            # exact float equality reproduces python's tuple sort ties
            m1 = dmask == md2
            cm = jnp.where(m1, const["cap"], -jnp.inf)
            j1 = jnp.argmax(m1 & (cm == jnp.max(cm)))
            dmask2 = dmask.at[j1].set(jnp.inf)
            m1b = dmask2 == jnp.min(dmask2)
            cm2 = jnp.where(m1b, const["cap"], -jnp.inf)
            j2 = jnp.argmax(m1b & (cm2 == jnp.max(cm2)))
            do_hedge = (serve & x["degr"] & const["hedge"] & (n2 > 1))
            # 6/7. the two legs (leg 2 sees leg 1's queues). The hedge
            # leg rarely fires outside degraded regimes, so it runs
            # under a real branch (HLO conditional executes one side)
            # instead of where-masked every step. The taken branch is
            # leg(on=True) — identical arithmetic to the masked form,
            # so results stay bitwise.
            st = (free, hot, last, hb, up, zp)
            st, (sel1, flat1, place1, vict1, q1, x1, t1) = leg(
                *st, serve, j1, x, const)

            def _hedge(op):
                st_, j_, x_ = op
                return leg(*st_, jnp.bool_(True), j_, x_, const)

            out_sh = jax.eval_shape(_hedge, (st, j2, x))[1]

            def _skip(op):
                st_, _, _ = op
                return st_, tuple(
                    jnp.full(s.shape, -1 if i == 3 else 0, s.dtype)
                    for i, s in enumerate(out_sh))

            st, (sel2, flat2, place2, vict2, q2, x2, t2) = lax.cond(
                do_hedge, _hedge, _skip, (st, j2, x))
            free, hot, last, hb, up, zp = st
            # 8. strict first-completion-wins
            win2 = do_hedge & (t2 < t1)
            e2ew = jnp.where(win2, t2, t1)
            y = dict(
                scale1=jnp.where(do1, n1, -1).astype(jnp.int32),
                scale2=jnp.where(grow, n2, -1).astype(jnp.int32),
                shed=shed, hedged=do_hedge,
                j1=j1.astype(jnp.int32), sel1=sel1, place1=place1,
                j2=j2.astype(jnp.int32), sel2=sel2, place2=place2,
                jw=jnp.where(win2, j2, j1).astype(jnp.int32),
                flatw=jnp.where(win2, flat2, flat1),
                qw=jnp.where(win2, q2, q1),
                xw=jnp.where(win2, x2, x1),
                e2ew=e2ew,
                okw=jnp.where(x["has"], e2ew <= x["slar"], True))
            if has_budget:
                # Without a budget the vict buffers are the constant
                # full(-1); skip materializing N x E of them.
                y["vict1"], y["vict2"] = vict1, vict2
            return (free, hot, last, hb, n2, up, zp), y

        return lax.scan(step, init, xs)

    fn = jax.jit(run)
    _COMPILED[key] = fn
    return fn


# --------------------------------------------------------------------------
# The engine entry point
# --------------------------------------------------------------------------

@dataclass
class ClusterScanResult:
    """Columnar run summary (`cluster.metrics` / `cluster.events` carry
    the authoritative python-identical records)."""
    n: int
    events: List[dict]
    e2e: np.ndarray           # (N,) winner / on-device latency
    ok: np.ndarray            # (N,) bool
    shed: np.ndarray          # (N,) bool
    hedged: np.ndarray        # (N,) bool
    mode_idx: Optional[np.ndarray] = None
    rows: int = 0


def scan_cluster_run(cluster: Cluster, workload, *, shards: int = 1,
                     collect_rows: bool = True) -> ClusterScanResult:
    """Run a workload (a `TenantColumns` or a `Request` sequence)
    through the scan cluster engine, mutating `cluster` exactly as the
    python engine would (events, metrics rows, zoo/rng/queue state).
    ``collect_rows=False`` skips materializing the N metrics dicts —
    the fleet-scale benchmark path, where the columnar result is the
    product."""
    names = _validate(cluster)
    K = len(names)
    R = len(cluster.replicas)
    work = (_work_from_columns(cluster, workload)
            if isinstance(workload, TenantColumns)
            else _work_from_requests(cluster, workload))
    N = work.n
    ctrl = cluster.controller
    if N == 0:
        cluster.drain()
        return ClusterScanResult(0, [], np.empty(0), np.empty(0, bool),
                                 np.empty(0, bool), np.empty(0, bool))

    # -- phase 1: controller columns (sharded like scan_engine) -------
    alarm = np.zeros(N, np.int8)
    mode_idx = None
    ctrl_events: List[dict] = []
    degr = np.zeros(N, bool)
    if ctrl is not None:
        if np.isnan(work.priors).any():
            raise ValueError("engine='scan' adaptive control needs a "
                             "prior for every device")
        cdesc = ctrl_desc_from_controller(ctrl, table_specs=(None,))
        packed = _pack_columns(work.t_input, work.dev_col,
                               len(work.priors))
        out = _run_program(None, cdesc, packed, work.priors, shards)
        mode_idx = _unpack(packed, out["mode"], np.int64)
        ctrl_events = _assemble_events(out, packed, ctrl.mode_names(),
                                       work.device_names, work.dev_col)
        for e in ctrl_events:
            alarm[e["request"]] = np.int8(np.sign(e["alarm"]))
        degr = np.array([bool(m.degraded)
                         for m in ctrl.modes])[mode_idx]

    # -- phase 2: profiles, policies, pre-drawn streams ---------------
    draws = _predraw(cluster, work, K)
    mu = np.empty(R * K)
    sgp = np.empty(R * K)
    xmu = np.empty(R * K)
    xsgp = np.empty(R * K)
    sizes = np.empty(R * K, np.int64)
    acc_reg: List[float] = []
    hot0 = np.empty(R * K, bool)
    last0 = np.empty(R * K)
    free0 = np.empty(R)
    speed = np.empty(R)
    cap = np.empty(R)
    for r, rep in enumerate(cluster.replicas):
        free0[r] = rep._server_free
        speed[r] = rep.speed
        cap[r] = rep.capacity_score()
        for k, name in enumerate(names):
            e = rep.router.zoo.entries[name]
            p = e.profile
            f = r * K + k
            mu[f], sgp[f] = p.mu, p.sigma + 1e-9
            xmu[f] = max(p.cold_mu - p.mu, 0.0)
            xsgp[f] = max(p.cold_sigma - p.sigma, 0.0) + 1e-9
            sizes[f] = p.size_bytes
            hot0[f], last0[f] = e.hot, e.last_used
            acc_reg.append(p.accuracy)
    budget = cluster.placer.budget
    has_budget = budget is not None

    # -- phase 3: the cluster scan ------------------------------------
    from jax.experimental import enable_x64
    xs = dict(arr=work.arrival, ti=work.t_input, slac=work.t_sla_c,
              slar=work.t_sla_r, has=work.has_sla, prio=work.prio,
              od=work.od, degr=degr, al=alarm, sel=draws.sel,
              cdf=draws.cdf)
    const = dict(
        mu=mu, sgp=sgp, p1mu=0.1 * mu, xmu=xmu, xsgp=xsgp,
        sizes=sizes, cap=cap, speed=speed,
        kind=draws.kind, u=draws.u, ri=draws.ri, z=draws.z,
        budget=np.int64(budget if has_budget else 0),
        min_active=np.int32(cluster.min_active),
        hedge=np.bool_(cluster.hedge),
        shed_factor=np.float64(cluster.shed_factor),
        headroom=np.float64(cluster.scale_headroom))
    init = (free0, hot0, last0,
            np.int64(cluster.placer.hot_bytes()),
            np.int32(cluster.n_active),
            np.zeros(R, np.int32), np.zeros(R, np.int32))
    fn = _compile(R, K, has_budget)
    with enable_x64():
        carry, ys = fn(xs, init, const)
        free_end, hot_end, last_end, _, n_act_end, up_end, zp_end = (
            np.asarray(v) for v in carry)
        ys = {k: np.asarray(v) for k, v in ys.items()}

    # -- event assembly (chronological within each step) --------------
    events: List[dict] = []
    no_victs = np.empty((N, 0), np.int32)    # budget-free compile path
    have = ((ys["scale1"] >= 0) | (ys["scale2"] >= 0) | ys["shed"]
            | ys["place1"] | (ys["hedged"] & ys["place2"]))
    for i in np.flatnonzero(have):
        i = int(i)
        if ys["scale1"][i] >= 0:
            events.append({
                "kind": "scale_up" if alarm[i] > 0 else "scale_down",
                "request": i + 1, "n_active": int(ys["scale1"][i]),
                "reason": f"switch:{work.dev_str(i)}"})
        if ys["scale2"][i] >= 0:
            events.append({
                "kind": "scale_up", "request": i + 1,
                "n_active": int(ys["scale2"][i]), "reason": "load"})
        if ys["shed"][i]:
            events.append({
                "kind": "shed", "request": i,
                "tenant": work.tenant_str(i),
                "device": work.dev_str(i)})
            continue
        for leg_ in ("1", "2"):
            if leg_ == "2" and not ys["hedged"][i]:
                break
            for v in ys.get("vict" + leg_, no_victs)[i]:
                if v < 0:
                    break
                events.append({
                    "kind": "evict", "request": i,
                    "replica": int(v) // K, "model": names[int(v) % K]})
            if ys["place" + leg_][i]:
                events.append({
                    "kind": "place", "request": i,
                    "replica": int(ys["j" + leg_][i]),
                    "model": names[int(ys["sel" + leg_][i])]})
    cluster.events.extend(events)

    # -- metrics rows (schema-exact vs ServingMetrics.add) ------------
    shed = ys["shed"]
    hedged = ys["hedged"]
    e2e_all = np.where(shed, work.od, ys["e2ew"])
    ok_all = np.where(shed, work.od <= work.t_sla_c, ys["okw"])
    n_rows = 0
    if collect_rows:
        mode_names = (ctrl.mode_names() if ctrl is not None else None)
        if work.cols is not None:
            cols = work.cols
            tnames = [t.name for t in cols.tenants]
            rid = range(N)
            dev_of = [cols.device_name(c) for c in cols.col]
            ten_of = [tnames[t] for t in cols.tenant_idx]
        else:
            rid = [q.rid for q in work.reqs]
            dev_of = [q.device_id for q in work.reqs]
            ten_of = [q.tenant for q in work.reqs]
        recs = cluster.metrics.records
        flatw = ys["flatw"]
        jw = ys["jw"]
        qw, xw = ys["qw"], ys["xw"]
        okw = ys["okw"]
        for i in range(N):
            mode = (mode_names[mode_idx[i]] if mode_names is not None
                    else "static")
            if shed[i]:
                recs.append({
                    "rid": rid[i], "model": "<on-device>",
                    "queue_ms": 0.0, "exec_ms": 0.0,
                    "e2e_ms": float(work.od[i]),
                    "device": dev_of[i], "mode": mode,
                    "ok": bool(ok_all[i]), "tenant": ten_of[i],
                    "accuracy": None, "fallback": True,
                    "hedged": False, "replica": None})
            else:
                f = int(flatw[i])
                recs.append({
                    "rid": rid[i], "model": names[f % K],
                    "queue_ms": float(qw[i]),
                    "exec_ms": float(xw[i]),
                    "e2e_ms": float(ys["e2ew"][i]),
                    "device": dev_of[i], "mode": mode,
                    "ok": bool(okw[i]), "tenant": ten_of[i],
                    "accuracy": acc_reg[f], "fallback": False,
                    "hedged": bool(hedged[i]), "replica": int(jw[i])})
        n_rows = N

    # -- state writeback (scan run == python run afterwards) ----------
    flat1 = (ys["j1"].astype(np.int64) * K
             + ys["sel1"].astype(np.int64))
    flat2 = (ys["j2"].astype(np.int64) * K
             + ys["sel2"].astype(np.int64))
    heat_flat = np.concatenate([flat1[ys["place1"]],
                                flat2[ys["hedged"] & ys["place2"]]])
    load_counts = np.bincount(heat_flat, minlength=R * K)
    if "vict1" in ys:
        victs = np.concatenate([ys["vict1"].ravel(),
                                ys["vict2"].ravel()])
        evict_counts = np.bincount(victs[victs >= 0], minlength=R * K)
    else:
        evict_counts = np.zeros(R * K, np.int64)
    for r, rep in enumerate(cluster.replicas):
        zoo = rep.router.zoo
        for k, name in enumerate(names):
            e = zoo.entries[name]
            f = r * K + k
            e.hot = bool(hot_end[f])
            e.last_used = float(last_end[f])
            e.loads += int(load_counts[f])
            e.evictions += int(evict_counts[f])
        zoo.total_cold_starts += int(
            load_counts[r * K:(r + 1) * K].sum())
        rep._server_free = float(free_end[r])
        rep.rng.take(int(zp_end[r]))             # advance live stream
        pol = rep.router.policy
        nu = int(up_end[r])
        if nu:
            if draws.kind[r] == 1:
                pol.rng.random(nu)
            elif draws.kind[r] == 2:
                pol.rng.integers(K, size=nu)
    if ctrl is not None:
        # Post-run inspection state: the event log and counters match
        # the python run; the bank/detector internals are not replayed
        # (a fresh prime() is required before reusing the controller).
        ctrl._events = [dict(e) for e in ctrl_events]
        ctrl._n_seen = N
        cluster._seen_switches = len(ctrl_events)
    cluster.n_active = int(n_act_end)
    cluster._n = N
    cluster.placer.request = N - 1
    cluster._free_cache = [None] * R
    cluster._cap_cache = [None] * R
    return ClusterScanResult(
        n=N, events=events, e2e=e2e_all, ok=ok_all.astype(bool),
        shed=shed, hedged=hedged, mode_idx=mode_idx, rows=n_rows)
