"""Distributed flash-decode (sequence-sharded KV cache).

For architectures with n_kv_heads < tp (gemma2, yi, deepseek, chameleon,
qwen3, grok, recurrentgemma), the KV cache shards its SEQUENCE dim over
the model axis. GSPMD handles that layout correctly but conservatively —
the v1 roofline showed it all-gathering every layer's cache per decode
step (~1 GB/layer). This shard_map implements what the hardware should
do instead:

  - the new token's k/v is written by the one shard owning the slot
    (masked local dynamic-update-slice, no communication),
  - each shard computes attention over its local S/tp cache chunk for
    ALL heads (model-parallel over sequence, heads replicated — q is a
    single token, so replication is free),
  - partial softmax stats merge with a pmax + two psums of (B, H)-sized
    tensors — KBs instead of GBs per layer.

On real TPU the per-shard inner loop is the Pallas decode_attention
kernel (repro/kernels/decode_attention.py) applied to the local chunk;
the pure-jnp body below is its oracle-equivalent and what the dry-run
lowers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.utils import shard_map


def flash_decode_sharded(q, k_new, v_new, ck, cv, cpos, cache_pos,
                         cfg: ModelConfig, parallel, *, window: int,
                         valid_from=None):
    """q/k_new/v_new: (B,1,H|KV,hd); ck/cv: (B,S,KV,hd); cpos: (S,);
    cache_pos: scalar; valid_from: optional (B,) first attendable stored
    position per row (masked into each shard's local chunk before the
    partial-softmax merge; rows with no attendable slot produce zeros).
    Returns (out (B,1,H,hd), ck', cv', cpos')."""
    tp = parallel.tp_axis
    tp_size = parallel.mesh.shape[tp]
    B, S = ck.shape[0], ck.shape[1]
    data_ok = all(B % parallel.mesh.shape[a] == 0
                  for a in parallel.data_axes) and B >= _prod(
                      parallel.mesh.shape[a] for a in parallel.data_axes)
    baxes = parallel.data_axes if data_ok else None
    bspec4 = P(baxes, None, None, None)
    cspec = P(baxes, tp, None, None)
    scale = cfg.head_dim ** -0.5
    cap = cfg.attn_softcap

    def device_fn(qb, knb, vnb, ckb, cvb, posb, cpos_s, vfb):
        i = jax.lax.axis_index(tp)
        S_loc = ckb.shape[1]
        slot_g = cpos_s % S
        local = slot_g - i * S_loc
        in_range = (local >= 0) & (local < S_loc)
        idx = jnp.clip(local, 0, S_loc - 1)
        ck_up = jax.lax.dynamic_update_slice(
            ckb, knb.astype(ckb.dtype), (0, idx, 0, 0))
        cv_up = jax.lax.dynamic_update_slice(
            cvb, vnb.astype(cvb.dtype), (0, idx, 0, 0))
        pos_up = jax.lax.dynamic_update_slice(
            posb, cpos_s[None].astype(posb.dtype), (idx,))
        ckb = jnp.where(in_range, ck_up, ckb)
        cvb = jnp.where(in_range, cv_up, cvb)
        posb = jnp.where(in_range, pos_up, posb)

        KV = ckb.shape[2]
        H = qb.shape[2]
        rep = H // KV
        Bq = qb.shape[0]
        hd = qb.shape[3]
        # Grouped-GQA einsums: repeating KV to H heads would multiply the
        # cache read traffic by rep (measured 8x on chameleon decode).
        qg = (qb[:, 0] * scale).reshape(Bq, KV, rep, hd)
        s = jnp.einsum("bgrd,bkgd->bgrk", qg, ckb,
                       preferred_element_type=jnp.float32)  # (B,KV,rep,S)
        if cap:
            s = cap * jnp.tanh(s / cap)
        valid = (posb >= 0) & (posb <= cpos_s)
        if window:
            valid &= posb > cpos_s - window
        if valid_from is None:
            s = jnp.where(valid[None, None, None, :], s, -1e30)
        else:
            vmask = valid[None, :] & (posb[None, :] >= vfb[:, None])  # (B,S)
            s = jnp.where(vmask[:, None, None, :], s, -1e30)
        m_loc = s.max(axis=-1)                                  # (B,KV,rep)
        m = jax.lax.pmax(m_loc, tp)
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(p.sum(axis=-1), tp)                    # (B,KV,rep)
        acc = jnp.einsum("bgrk,bkgd->bgrd", p, cvb.astype(jnp.float32))
        acc = jax.lax.psum(acc, tp)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        if valid_from is not None:
            # Rows with no attendable slot anywhere (m still at the
            # -1e30 fill after the global pmax) produce zeros, matching
            # the shared masked-attention semantic (DESIGN.md §15).
            out = jnp.where((m > -5e29)[..., None], out, 0.0)
        out = out.astype(qb.dtype)
        return out.reshape(Bq, 1, H, hd), ckb, cvb, posb

    vf = (jnp.zeros((B,), jnp.int32) if valid_from is None
          else jnp.asarray(valid_from, jnp.int32))
    fn = shard_map(
        device_fn,
        mesh=parallel.mesh,
        in_specs=(bspec4, bspec4, bspec4, cspec, cspec, P(tp), P(),
                  P(baxes)),
        out_specs=(bspec4, cspec, cspec, P(tp)),
        check_vma=False,
    )
    return fn(q, k_new, v_new, ck, cv, cpos,
              jnp.asarray(cache_pos, jnp.int32), vf)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out
