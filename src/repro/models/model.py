"""Composable decoder LM covering all assigned architectures.

A model is `n_layers` blocks produced by cycling `cfg.pattern`. Layers
are grouped for `lax.scan` (one group = one pass through the pattern);
`n_layers % len(pattern)` tail layers are unrolled. KV/recurrent caches
thread through the scan as stacked xs/ys.

Entry points:
  forward(params, inputs, cfg)                      -> (logits, aux)
  forward(..., cache=init_cache(...), positions)    -> prefill: also fills cache
  decode_step(params, token, cache, cache_pos, cfg) -> (logits, new_cache)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as pmod
from repro.models.config import ModelConfig, ATTN_KINDS
from repro.models.layers import attn_block, rms_norm, softcap
from repro.models.moe import moe_block_ffn
from repro.models.rglru import rglru_block
from repro.models.ssd import ssd_block
from repro.utils import dtype_of

init_params = pmod.init_params
param_logical_axes = pmod.param_logical_axes
abstract_params = pmod.abstract_params


# --------------------------------------------------------------------------
# Cache construction (same mk-callback trick as params.py)
# --------------------------------------------------------------------------

def _block_cache_tree(cfg: ModelConfig, kind: str, B: int, max_seq: int, mk):
    if kind in ATTN_KINDS:
        S = min(cfg.window, max_seq) if kind == "local" and cfg.window else max_seq
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": mk((B, S, KV, hd), ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                    cfg.compute_dtype, "zeros"),
            "v": mk((B, S, KV, hd), ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                    cfg.compute_dtype, "zeros"),
            "pos": mk((S,), ("cache_seq",), "int32", "neg_ones"),
        }
    if kind == "rglru":
        W, K = cfg.lru_width, cfg.rglru.conv_width
        return {
            "h": mk((B, W), ("cache_batch", "rnn_width"), "float32", "zeros"),
            "conv": mk((B, K - 1, W), ("cache_batch", "conv_k", "rnn_width"),
                       cfg.compute_dtype, "zeros"),
        }
    if kind == "ssd":
        s = cfg.ssd
        nh, N, P = cfg.ssd_heads, s.d_state, s.head_dim
        di, gn, K = cfg.d_inner_ssd, s.n_groups * s.d_state, s.conv_width
        return {
            "S": mk((B, nh, N, P), ("cache_batch", "ssd_heads", "ssd_state", "ssd_hd"),
                    "float32", "zeros"),
            "conv": {
                "x": mk((B, K - 1, di), ("cache_batch", "conv_k", "ssd_inner"),
                        cfg.compute_dtype, "zeros"),
                "B": mk((B, K - 1, gn), ("cache_batch", "conv_k", "ssd_gn"),
                        cfg.compute_dtype, "zeros"),
                "C": mk((B, K - 1, gn), ("cache_batch", "conv_k", "ssd_gn"),
                        cfg.compute_dtype, "zeros"),
            },
        }
    raise ValueError(kind)


def _cache_tree(cfg: ModelConfig, B: int, max_seq: int, mk, mk_stacked):
    G = cfg.n_groups_scan
    blocks = []
    for kind in cfg.pattern:
        smk = lambda shape, axes, dt, init: mk_stacked(shape, axes, dt, init, G)
        blocks.append(_block_cache_tree(cfg, kind, B, max_seq, smk))
    tail = tuple(_block_cache_tree(cfg, kind, B, max_seq, mk)
                 for kind in cfg.tail_kinds)
    return {"blocks": tuple(blocks), "tail": tail}


def _mk_concrete(shape, axes, dt, init):
    dtype = jnp.int32 if dt == "int32" else dtype_of(dt)
    if init == "neg_ones":
        return -jnp.ones(shape, dtype)
    return jnp.zeros(shape, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    mk = _mk_concrete
    mk_stacked = lambda shape, axes, dt, init, n: _mk_concrete(
        (n,) + shape, axes, dt, init)
    return _cache_tree(cfg, batch, max_seq, mk, mk_stacked)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    mk = lambda shape, axes, dt, init: jax.ShapeDtypeStruct(
        shape, jnp.int32 if dt == "int32" else dtype_of(dt))
    mk_stacked = lambda shape, axes, dt, init, n: jax.ShapeDtypeStruct(
        (n,) + shape, jnp.int32 if dt == "int32" else dtype_of(dt))
    return _cache_tree(cfg, batch, max_seq, mk, mk_stacked)


def cache_logical_axes(cfg: ModelConfig, batch: int = 1, max_seq: int = 8):
    mk = lambda shape, axes, dt, init: axes
    mk_stacked = lambda shape, axes, dt, init, n: ("layers",) + axes
    return _cache_tree(cfg, batch, max_seq, mk, mk_stacked)


# --------------------------------------------------------------------------
# Block dispatch
# --------------------------------------------------------------------------

def _apply_block(kind: str, p, x, cfg: ModelConfig, positions, cache,
                 cache_pos, parallel, constrain=None, valid_from=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if valid_from is not None and kind in ("rglru", "ssd"):
        # Recurrent state integrates every input step sequentially — a
        # left-padded prompt contaminates h/S/conv in a way no attention
        # mask can undo. Callers must feed unpadded sequences instead.
        raise NotImplementedError(
            f"valid_from masking cannot be applied to recurrent blocks "
            f"({kind}); feed unpadded sequences")
    if kind in ("attn", "global", "local"):
        x, nc = attn_block(p, x, cfg, kind, positions, cache, cache_pos,
                           constrain, parallel, valid_from)
        return x, nc, aux
    if kind == "moe":
        x, nc = attn_block(p, x, cfg, kind, positions, cache, cache_pos,
                           constrain, parallel, valid_from)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        out, aux = moe_block_ffn(p, h, cfg, parallel)
        if cfg.sandwich_norm:
            out = rms_norm(out, p["post_ffn_norm"], cfg.norm_eps)
        if constrain is not None:
            # T-shard BEFORE naming so the saved residual is 1/tp-sized.
            out = constrain(out)
        if cfg.remat == "moe_save":
            from jax.ad_checkpoint import checkpoint_name
            out = checkpoint_name(out, "moe_out")
        x = x + out
        if constrain is not None:
            x = constrain(x)
        return x, nc, aux
    if kind == "rglru":
        x, nc = rglru_block(p, x, cfg, cache)
        if constrain is not None:
            x = constrain(x)
        return x, nc, aux
    if kind == "ssd":
        x, nc = ssd_block(p, x, cfg, cache, parallel)
        if constrain is not None:
            x = constrain(x)
        return x, nc, aux
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Forward / decode
# --------------------------------------------------------------------------

def forward(params, inputs, cfg: ModelConfig, *, parallel=None,
            cache=None, cache_pos=None, positions=None,
            logits_last_only: bool = False, valid_from=None):
    """inputs: (B,T) int tokens or (B,T,d) embeddings (frontend stubs).

    cache=None: plain forward. cache given & T>1: prefill (fills cache).
    logits_last_only: unembed only the final position (serving prefill —
    avoids materializing the (B,S,V) logits tensor).
    valid_from: optional (B,) int32 per-row first attendable position —
    masks left-padded prompt slots (and stale cache rows after a slot
    backfill) out of attention. Attention-only patterns; recurrent
    blocks raise.
    Returns (logits, {"aux_loss", "cache"}).
    """
    compute_dtype = dtype_of(cfg.compute_dtype)
    if cfg.input_mode == "embeddings":
        x = inputs.astype(compute_dtype)
    else:
        x = jnp.take(params["embed"], inputs, axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), compute_dtype)
    B, T = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)
    if cache_pos is None:
        cache_pos = jnp.zeros((), jnp.int32)

    # Megatron-style sequence parallelism: the residual stream stays
    # sharded over (batch x seq); applied after EVERY residual add so
    # GSPMD converts each row-parallel all-reduce into a reduce-scatter
    # and the scanned carry stays 1/tp-sized (§Perf iteration 3).
    constrain = None
    entry_constrain = None
    if parallel is not None and parallel.seq_shard and T > 1:
        from jax.sharding import PartitionSpec as P
        res_spec = P(parallel.data_axes, parallel.tp_axis, None)
        rep_spec = P(parallel.data_axes, None, None)

        def _tshard(h):
            return jax.lax.with_sharding_constraint(h, res_spec)

        if getattr(parallel, "seq_mode", "full") == "carry":
            # Only the scan carry stays T-sharded; inside a group x is
            # explicitly gathered to model-replicated so qkv runs
            # head-sharded (otherwise GSPMD gathers the small weights and
            # replicates attention over the model axis — §Perf).
            def entry_constrain(h):
                return jax.lax.with_sharding_constraint(h, rep_spec)
            exit_constrain = _tshard
        else:
            constrain = _tshard
            exit_constrain = None

    def apply_group(x, aux, bps, bcs):
        if entry_constrain is not None:
            x = entry_constrain(x)
        elif constrain is not None:
            x = constrain(x)
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            c = None if bcs is None else bcs[i]
            x, nc, a = _apply_block(kind, bps[i], x, cfg, positions, c,
                                    cache_pos, parallel, constrain,
                                    valid_from)
            new_caches.append(nc)
            aux = aux + a
        if entry_constrain is not None:
            x = exit_constrain(x)  # reduce-scatter back into the carry
        return x, aux, tuple(new_caches)

    aux0 = jnp.zeros((), jnp.float32)
    bcaches = cache["blocks"] if cache is not None else None
    if cfg.n_groups_scan > 0:
        if bcaches is None:
            # No cache: scan over stacked params only.
            def body_nc(carry, bps):
                x, aux = carry
                x, aux, _ = apply_group(x, aux, bps, None)
                return (x, aux), None

            if cfg.remat == "block":
                body_nc = jax.checkpoint(body_nc)
            elif cfg.remat == "moe_save":
                # Like "block" but the (T-sharded) MoE outputs are saved:
                # the backward recompute then skips the expert FFN and its
                # weight-gather + combine collectives (§Perf, qwen3 train).
                body_nc = jax.checkpoint(
                    body_nc,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "moe_out"))
            (x, aux), _ = jax.lax.scan(body_nc, (x, aux0), params["blocks"])
            new_bcache = None
        else:
            # Cache is CARRIED (not scanned xs/ys): the stacked cache
            # buffers live in the loop carry and are updated in place via
            # dynamic_update_index_in_dim — scanned ys would force XLA to
            # double-buffer the (layers, B, S, KV, hd) arrays.
            def body_c(carry, bps):
                x, aux, caches, i = carry
                bcs = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                           keepdims=False),
                    caches)
                x, aux, ncs = apply_group(x, aux, bps, bcs)
                caches = jax.tree.map(
                    lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                        c, nc.astype(c.dtype), i, 0), caches, ncs)
                return (x, aux, caches, i + 1), None

            if cfg.remat == "block":
                body_c = jax.checkpoint(body_c)
            (x, aux, new_bcache, _), _ = jax.lax.scan(
                body_c, (x, aux0, bcaches, jnp.zeros((), jnp.int32)),
                params["blocks"])
    else:
        aux = aux0
        new_bcache = bcaches

    new_tail = []
    tcaches = cache["tail"] if cache is not None else None
    for i, kind in enumerate(cfg.tail_kinds):
        c = None if tcaches is None else tcaches[i]
        x, nc, a = _apply_block(kind, params["tail"][i], x, cfg, positions, c,
                                cache_pos, parallel, valid_from=valid_from)
        new_tail.append(nc)
        aux = aux + a

    if logits_last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)

    new_cache = None
    if cache is not None:
        new_cache = {"blocks": new_bcache, "tail": tuple(new_tail)}
    return logits, {"aux_loss": aux, "cache": new_cache}


def decode_step(params, token, cache, cache_pos, cfg: ModelConfig, *,
                parallel=None, valid_from=None):
    """One decode step. token: (B,1) int32 (or (B,1,d) embeddings);
    cache_pos: scalar int32 = number of tokens already in context.
    valid_from: optional (B,) per-row first attendable cache position.
    Returns (logits (B,1,V), new_cache)."""
    positions = cache_pos[None].astype(jnp.int32)
    logits, extras = forward(params, token, cfg, parallel=parallel,
                             cache=cache, cache_pos=cache_pos,
                             positions=positions, valid_from=valid_from)
    return logits, extras["cache"]


def prefill(params, inputs, cfg: ModelConfig, max_seq: int, *, parallel=None,
            logits_last_only: bool = False, valid_from=None):
    """Full-sequence prefill: returns (logits, cache ready for decoding).

    valid_from: optional (B,) int32 — with left-padded prompts, row b's
    real tokens start at position valid_from[b]; padding slots are masked
    out of every attention so they cannot contaminate logits or the KV
    cache reads of later decode steps."""
    B, T = inputs.shape[0], inputs.shape[1]
    cache = init_cache(cfg, B, max_seq)
    logits, extras = forward(params, inputs, cfg, parallel=parallel,
                             cache=cache,
                             positions=jnp.arange(T, dtype=jnp.int32),
                             logits_last_only=logits_last_only,
                             valid_from=valid_from)
    return logits, extras["cache"]
