"""Composable model substrate: configs, layers, parameter trees, forward passes."""

from repro.models.config import ModelConfig, MoEConfig, SSDConfig, RGLRUConfig
from repro.models.model import (
    init_params,
    forward,
    init_cache,
    decode_step,
    param_logical_axes,
    cache_logical_axes,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSDConfig",
    "RGLRUConfig",
    "init_params",
    "forward",
    "init_cache",
    "decode_step",
    "param_logical_axes",
    "cache_logical_axes",
]
