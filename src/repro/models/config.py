"""Model configuration dataclasses.

One `ModelConfig` describes any of the assigned architectures through a
cycled per-layer *block pattern* (e.g. ``("attn",)``,
``("local", "global")``, ``("rglru", "rglru", "local")``, ``("ssd",)``,
``("moe",)``). Layers are grouped for `lax.scan`: `n_layers // len(pattern)`
full groups are scanned; the remainder ("tail") layers are unrolled.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Block kinds implying a full-attention mixer (=> quadratic in context;
# archs containing any of these skip the long_500k shape).
FULL_ATTN_KINDS = ("attn", "global", "moe")
# Block kinds with an attention mixer at all (need a KV cache).
ATTN_KINDS = ("attn", "global", "local", "moe")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSDConfig:
    """Mamba2 state-space-duality mixer."""
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    expand: int = 2
    chunk: int = 256  # SSD chunk length (training/prefill)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent mixer."""
    lru_width: int = 0  # defaults to d_model
    conv_width: int = 4
    c: float = 8.0  # recurrence sharpness constant


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # sliding window for "local" blocks
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    sandwich_norm: bool = False  # gemma2-style post-block norms
    rotary_pct: float = 1.0
    rope_theta: float = 10000.0
    mlp_gated: bool = True
    mlp_act: str = "silu"  # silu | gelu
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d_model) input scaling
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm frontend stubs)
    norm_eps: float = 1e-6

    moe: Optional[MoEConfig] = None
    ssd: Optional[SSDConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # TP head padding: smallest multiple of the model-axis size >= n_heads.
    # 0 means "no padding needed". Only deepseek-coder-33b (56 heads) uses it.
    tp_pad_heads: int = 0
    # TP vocab padding (embedding rows added so vocab shards over the model
    # axis). Only mamba2 (50280) needs it. 0 = no padding.
    tp_pad_vocab: int = 0

    # Runtime knobs (not architecture):
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_impl: str = "auto"  # auto | naive | jax_chunked | pallas
    attn_chunk: int = 512
    remat: str = "none"  # none | block | moe_save (checkpoint around each group)

    def __post_init__(self):
        if self.pattern and any(k in ATTN_KINDS for k in self.pattern):
            assert self.n_heads % self.n_kv_heads == 0, \
                f"{self.name}: n_heads {self.n_heads} must be a multiple " \
                f"of n_kv_heads {self.n_kv_heads}"
            if self.tp_pad_heads:
                assert self.tp_pad_heads >= self.n_heads

    # ---- derived helpers -------------------------------------------------
    @property
    def q_heads_padded(self) -> int:
        return self.tp_pad_heads if self.tp_pad_heads else self.n_heads

    @property
    def padded_vocab(self) -> int:
        return self.tp_pad_vocab if self.tp_pad_vocab else self.vocab

    @property
    def n_groups_scan(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        tail = self.n_layers % len(self.pattern)
        return self.pattern[:tail]

    @property
    def is_subquadratic(self) -> bool:
        """True iff no block kind uses full (unwindowed) attention."""
        return not any(k in FULL_ATTN_KINDS for k in self.pattern)

    @property
    def uses_attention(self) -> bool:
        return any(k in ATTN_KINDS for k in self.pattern)

    @property
    def d_inner_ssd(self) -> int:
        assert self.ssd is not None
        return self.ssd.expand * self.d_model

    @property
    def ssd_heads(self) -> int:
        assert self.ssd is not None
        return self.d_inner_ssd // self.ssd.head_dim

    @property
    def lru_width(self) -> int:
        assert self.rglru is not None
        return self.rglru.lru_width or self.d_model

    def with_runtime(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (analytic; cross-checked against the actual
    # tree in tests) --------------------------------------------------------
    def _block_params(self, kind: str) -> int:
        d = self.d_model
        n = 0
        if kind in ATTN_KINDS:
            hq = self.q_heads_padded * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            n += d * hq + 2 * d * hkv + hq * d  # q, k, v, o
            n += 2 * d  # ln1 + ln2
            if self.qk_norm:
                n += 2 * self.head_dim
            if self.sandwich_norm:
                n += 2 * d
        if kind == "moe":
            m = self.moe
            n += d * m.n_experts  # router
            gate = 1 if self.mlp_gated else 0
            n += m.n_experts * ((2 + gate - 1) * d * m.d_ff_expert + m.d_ff_expert * d)
        elif kind in ("attn", "global", "local"):
            gate = 1 if self.mlp_gated else 0
            n += (1 + gate) * d * self.d_ff + self.d_ff * d
        elif kind == "rglru":
            w = self.lru_width
            cw = self.rglru.conv_width
            n += 2 * d * w  # x branch + gate branch in-proj
            n += w * cw  # temporal conv
            n += 3 * w  # a-gate, i-gate (diagonal params) + Lambda
            n += 2 * w * w  # recurrent input/recurrence gates (dense per RG-LRU)
            n += w * d  # out proj
            n += 2 * d  # ln1 + ln2 (mixer norm + mlp norm)
            gate = 1 if self.mlp_gated else 0
            n += (1 + gate) * d * self.d_ff + self.d_ff * d
        elif kind == "ssd":
            s = self.ssd
            di = self.d_inner_ssd
            nh = self.ssd_heads
            conv_ch = di + 2 * s.n_groups * s.d_state
            n += d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            n += conv_ch * s.conv_width  # conv
            n += 2 * nh  # A_log, dt_bias
            n += nh  # D skip
            n += di  # gated norm
            n += di * d  # out_proj
            n += d  # ln1
        return n

    def param_count(self) -> int:
        n = self.padded_vocab * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model
        n += self.d_model  # final norm
        for i in range(self.n_layers):
            n += self._block_params(self.pattern[i % len(self.pattern)])
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        n = self.param_count()
        if self.moe is not None:
            m = self.moe
            gate = 1 if self.mlp_gated else 0
            per_expert = (1 + gate) * self.d_model * m.d_ff_expert + m.d_ff_expert * self.d_model
            n_moe_layers = sum(
                1 for i in range(self.n_layers)
                if self.pattern[i % len(self.pattern)] == "moe"
            )
            n -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return n
