"""Mamba2 SSD (state-space duality) mixer, chunked.

Recurrence per head h with state S in R^{N x P}:
    S_t = a_t * S_{t-1} + B_t (x_t dt_t)^T        a_t = exp(dt_t * A_h)
    y_t = C_t^T S_t + D_h * x_t

Sequence mode uses the chunked SSD algorithm (arXiv:2405.21060): a scan
over chunks of length Q carrying the running state; within a chunk the
quadratic (Q x Q) form runs on the MXU. Decode mode is the O(1) update.

Shapes: x (B,T,H,P); B,C (B,T,G,N) with H % G == 0; dt (B,T,H).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.rglru import causal_conv1d


def _expand_groups(t, H):
    """(B,...,G,N) -> (B,...,H,N) by repeating each group H//G times."""
    G = t.shape[-2]
    return jnp.repeat(t, H // G, axis=-2)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, S0=None):
    """Chunked SSD scan. Returns (y, S_last).

    x: (B,T,H,P); dt: (B,T,H) (already softplus'd); A: (H,) negative;
    Bm, Cm: (B,T,G,N). S0: optional (B,H,N,P) initial state.
    """
    B_, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q

    xdt = (x * dt[..., None]).astype(jnp.float32)
    log_a = dt.astype(jnp.float32) * A.astype(jnp.float32)  # (B,T',H), <= 0

    def resh(t):
        return t.reshape((t.shape[0], nc, Q) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    single_group = (G == 1)
    if single_group:
        # Fast path: keep B/C per-group — expanding them to all H heads
        # materialized (B,T,H,N) fp32 tensors (~5.4 GB/layer on the
        # mamba2 train cell, §Perf iteration: memory-bound hillclimb).
        xs = (resh(xdt), resh(log_a), resh(Bm[:, :, 0]), resh(Cm[:, :, 0]))
    else:
        xs = (resh(xdt), resh(log_a), resh(_expand_groups(Bm, H)),
              resh(_expand_groups(Cm, H)))

    if S0 is None:
        S0 = jnp.zeros((B_, H, N, P), jnp.float32)

    def body(S, inp):
        xc, lac, Bc, Cc = inp  # xc (B,Q,H,P); lac (B,Q,H); Bc/Cc see above
        l = jnp.cumsum(lac, axis=1)  # inclusive within-chunk cumulative log-decay
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        decay_out = jnp.exp(l[:, -1, :][:, None] - l)  # (B,Q,H)
        if single_group:
            # Bc/Cc: (B,Q,N) shared across heads.
            y_inter = jnp.einsum("bqn,bhnp->bqhp", Cc, S) * jnp.exp(l)[..., None]
            scores = jnp.einsum("bqn,bkn->bqk", Cc, Bc)
            dec = l[:, :, None, :] - l[:, None, :, :]  # (B,Q,K,H)
            M = jnp.exp(jnp.where(causal[None, :, :, None], dec, -1e30))
            y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, M, xc)
            S_new = (jnp.exp(l[:, -1])[..., None, None] * S +
                     jnp.einsum("bkn,bkhp->bhnp", Bc,
                                xc * decay_out[..., None]))
        else:
            # Bc/Cc: (B,Q,H,N) per-head.
            y_inter = jnp.einsum("bqhn,bhnp->bqhp", Cc, S) * jnp.exp(l)[..., None]
            scores = jnp.einsum("bqhn,bkhn->bhqk", Cc, Bc)
            dec = (l[:, :, None, :].transpose(0, 3, 1, 2)
                   - l[:, None, :, :].transpose(0, 3, 1, 2))
            # Mask inside the exp: exp of masked (positive) entries would
            # be inf and poison gradients through the 0*inf=nan backward.
            M = jnp.exp(jnp.where(causal[None, None], dec, -1e30))
            y_intra = jnp.einsum("bhqk,bkhp->bqhp", scores * M, xc)
            S_new = (jnp.exp(l[:, -1])[..., None, None] * S +
                     jnp.einsum("bkhn,bkhp->bhnp", Bc * decay_out[..., None],
                                xc))
        return S_new, (y_inter + y_intra)

    S_last, ys = jax.lax.scan(body, S0, xs)  # ys: (nc,B,Q,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, nc * Q, H, P)[:, :T]
    return y.astype(x.dtype), S_last


def ssd_step(x, dt, A, Bm, Cm, S):
    """Single-token decode. x: (B,1,H,P); Bm/Cm: (B,1,G,N); S: (B,H,N,P)."""
    H = x.shape[2]
    a = jnp.exp(dt[:, 0].astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    Bh = _expand_groups(Bm[:, 0], H).astype(jnp.float32)  # (B,H,N)
    Ch = _expand_groups(Cm[:, 0], H).astype(jnp.float32)
    xdt = (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)  # (B,H,P)
    S_new = a[..., None, None] * S + jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S_new)
    return y[:, None].astype(x.dtype), S_new


def ssd_block(p, x, cfg: ModelConfig, cache=None, parallel=None):
    """Full mamba2 residual block. cache: None or
    {"S": (B,H,N,P) fp32, "conv": (B,K-1,conv_ch)}. Returns (x_out, cache)."""
    s = cfg.ssd
    eps = cfg.norm_eps
    di = cfg.d_inner_ssd
    H = cfg.ssd_heads
    P = s.head_dim
    G, N = s.n_groups, s.d_state

    h = rms_norm(x, p["ln1"], eps)
    # Separate projections (vs. one fused matmul) keep TP sharding clean:
    # z/x/dt shard with heads over the model axis, B/C stay replicated
    # (they are per-group, G=1, and feed every head's state update).
    z = jnp.einsum("btd,de->bte", h, p["w_z"])
    xb = jnp.einsum("btd,de->bte", h, p["w_x"])
    Bc = jnp.einsum("btd,de->bte", h, p["w_B"])
    Cc = jnp.einsum("btd,de->bte", h, p["w_C"])
    dt = jnp.einsum("btd,dh->bth", h, p["w_dt"])
    cs = cache["conv"] if cache is not None else None
    xb, st_x = causal_conv1d(p["conv_x"], xb, None if cs is None else cs["x"])
    Bc, st_b = causal_conv1d(p["conv_B"], Bc, None if cs is None else cs["B"])
    Cc, st_c = causal_conv1d(p["conv_C"], Cc, None if cs is None else cs["C"])
    conv_state = {"x": st_x, "B": st_b, "C": st_c}
    xb, Bc, Cc = jax.nn.silu(xb), jax.nn.silu(Bc), jax.nn.silu(Cc)

    Bt = x.shape[0]
    T = x.shape[1]
    xh = xb.reshape(Bt, T, H, P)
    Bm = Bc.reshape(Bt, T, G, N)
    Cm = Cc.reshape(Bt, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    if parallel is not None and T > 1:
        # Pin the head dim to the model axis: GSPMD's propagation loses
        # the sharding through the chunked-scan einsum chain and runs the
        # whole SSD replicated on every model rank (measured 16x traffic
        # on the mamba2 train cell — §Perf hillclimb 1).
        from jax.sharding import PartitionSpec as P_
        hspec = P_(parallel.data_axes, None, parallel.tp_axis, None)
        xh = jax.lax.with_sharding_constraint(xh, hspec)
        dt = jax.lax.with_sharding_constraint(
            dt, P_(parallel.data_axes, None, parallel.tp_axis))

    if cache is None:
        y, S_last = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
        new_cache = None
    elif T == 1:  # decode
        y, S_last = ssd_step(xh, dt, A, Bm, Cm, cache["S"])
        new_cache = {"S": S_last, "conv": conv_state}
    else:  # prefill: chunked scan from zero state, emit the final state
        y, S_last = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
        new_cache = {"S": S_last, "conv": conv_state}

    y = y + p["D"][None, None, :, None] * xh  # skip connection
    y = y.reshape(Bt, T, di)
    # Gated RMSNorm (mamba2): norm(y * silu(z)).
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], eps, zero_centered=False)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return x + out, new_cache
