"""Parameter tree construction with logical sharding axes.

Every builder receives a ``mk(shape, axes, init)`` callback so the same
structural code yields (a) real initialized arrays, (b) the parallel tree
of logical-axis tuples, and (c) ShapeDtypeStruct stand-ins for the
dry-run — guaranteeing the three can never drift apart.

Logical axis vocabulary (mapped to mesh axes by `repro.sharding` rules):
  vocab, embed        embedding table dims
  hidden_in           d_model as a matmul input dim
  heads, kv_heads, head_dim
  ff                  dense FFN hidden
  experts, expert_ff  MoE dims
  rnn_width           RG-LRU width
  ssd_inner, ssd_heads, ssd_gn
  norm, conv_k, layers(stacked scan dim)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ATTN_KINDS
from repro.utils import dtype_of


def block_tree(cfg: ModelConfig, kind: str, mk):
    """One block's parameter tree via the mk callback."""
    d = cfg.d_model
    p = {}
    if kind in ATTN_KINDS:
        Hq, KV, hd = cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim
        p["ln1"] = mk((d,), ("norm",), "zeros")
        p["wq"] = mk((d, Hq, hd), ("hidden_in", "heads", "head_dim"), "fan_in")
        p["wk"] = mk((d, KV, hd), ("hidden_in", "kv_heads", "head_dim"), "fan_in")
        p["wv"] = mk((d, KV, hd), ("hidden_in", "kv_heads", "head_dim"), "fan_in")
        p["wo"] = mk((Hq, hd, d), ("heads", "head_dim", "hidden_in"), "fan_io")
        if cfg.qk_norm:
            p["q_norm"] = mk((hd,), ("norm",), "zeros")
            p["k_norm"] = mk((hd,), ("norm",), "zeros")
        if cfg.sandwich_norm:
            p["post_attn_norm"] = mk((d,), ("norm",), "zeros")
            p["post_ffn_norm"] = mk((d,), ("norm",), "zeros")
        p["ln2"] = mk((d,), ("norm",), "zeros")
    if kind == "moe":
        m = cfg.moe
        p["router"] = mk((d, m.n_experts), ("hidden_in", "router"), "fan_in")
        p["w_up"] = mk((m.n_experts, d, m.d_ff_expert),
                       ("experts", "expert_in", "expert_ff"), "fan_in3")
        if cfg.mlp_gated:
            p["w_gate"] = mk((m.n_experts, d, m.d_ff_expert),
                             ("experts", "expert_in", "expert_ff"), "fan_in3")
        p["w_down"] = mk((m.n_experts, m.d_ff_expert, d),
                         ("experts", "expert_ff", "expert_in"), "fan_in3")
    elif kind in ("attn", "global", "local"):
        p["mlp"] = _mlp_tree(cfg, mk)
    elif kind == "rglru":
        w = cfg.lru_width
        K = cfg.rglru.conv_width
        p["ln1"] = mk((d,), ("norm",), "zeros")
        p["w_gate_branch"] = mk((d, w), ("hidden_in", "rnn_width"), "fan_in")
        p["w_in"] = mk((d, w), ("hidden_in", "rnn_width"), "fan_in")
        p["conv_w"] = mk((w, K), ("rnn_width", "conv_k"), "conv")
        p["w_a"] = mk((w, w), ("rnn_in", "rnn_width"), "fan_in")
        p["w_x"] = mk((w, w), ("rnn_in", "rnn_width"), "fan_in")
        p["b_a"] = mk((w,), ("rnn_width",), "zeros")
        p["b_x"] = mk((w,), ("rnn_width",), "zeros")
        p["lam"] = mk((w,), ("rnn_width",), "lambda")
        p["w_out"] = mk((w, d), ("rnn_width", "hidden_in"), "fan_in")
        p["ln2"] = mk((d,), ("norm",), "zeros")
        p["mlp"] = _mlp_tree(cfg, mk)
    elif kind == "ssd":
        s = cfg.ssd
        di, nh = cfg.d_inner_ssd, cfg.ssd_heads
        gn = s.n_groups * s.d_state
        K = s.conv_width
        p["ln1"] = mk((d,), ("norm",), "zeros")
        p["w_z"] = mk((d, di), ("hidden_in", "ssd_inner"), "fan_in")
        p["w_x"] = mk((d, di), ("hidden_in", "ssd_inner"), "fan_in")
        p["w_B"] = mk((d, gn), ("hidden_in", "ssd_gn"), "fan_in")
        p["w_C"] = mk((d, gn), ("hidden_in", "ssd_gn"), "fan_in")
        p["w_dt"] = mk((d, nh), ("hidden_in", "ssd_heads"), "fan_in")
        p["conv_x"] = mk((di, K), ("ssd_inner", "conv_k"), "conv")
        p["conv_B"] = mk((gn, K), ("ssd_gn", "conv_k"), "conv")
        p["conv_C"] = mk((gn, K), ("ssd_gn", "conv_k"), "conv")
        p["A_log"] = mk((nh,), ("ssd_heads",), "a_log")
        p["dt_bias"] = mk((nh,), ("ssd_heads",), "dt_bias")
        p["D"] = mk((nh,), ("ssd_heads",), "ones")
        p["norm_w"] = mk((di,), ("ssd_inner",), "ones")
        p["w_out"] = mk((di, d), ("ssd_inner", "hidden_in"), "fan_in")
    return p


def _mlp_tree(cfg: ModelConfig, mk):
    d, f = cfg.d_model, cfg.d_ff
    p = {"w_up": mk((d, f), ("hidden_in", "ff"), "fan_in"),
         "w_down": mk((f, d), ("ff", "hidden_in"), "fan_in")}
    if cfg.mlp_gated:
        p["w_gate"] = mk((d, f), ("hidden_in", "ff"), "fan_in")
    return p


def model_tree(cfg: ModelConfig, mk, mk_stacked):
    """Full model parameter tree.

    mk_stacked(shape, axes, init, n) creates a leaf with a leading
    ("layers", n) dim for the scanned groups.
    """
    d = cfg.d_model
    params = {
        "embed": mk((cfg.padded_vocab, d), ("vocab", "embed"), "embed"),
        "final_norm": mk((d,), ("norm",), "zeros"),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = mk((d, cfg.padded_vocab), ("hidden_in", "vocab"),
                               "fan_in")
    G = cfg.n_groups_scan
    blocks = []
    for kind in cfg.pattern:
        stacked_mk = lambda shape, axes, init: mk_stacked(shape, axes, init, G)
        blocks.append(block_tree(cfg, kind, stacked_mk))
    params["blocks"] = tuple(blocks)
    params["tail"] = tuple(block_tree(cfg, kind, mk) for kind in cfg.tail_kinds)
    return params


# --------------------------------------------------------------------------
# The three concrete instantiations of mk
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    counter = [0]

    def draw(shape, init):
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "embed":
            return (jax.random.normal(k, shape) * 1.0).astype(dtype)
        if init == "lambda":
            # RG-LRU Lambda init: a in [0.9, 0.999] => Lambda = logit-ish.
            u = jax.random.uniform(k, shape, minval=0.9, maxval=0.999)
            # a = exp(-c*softplus(lam)) at r=1 -> softplus(lam) = -log(a)/c
            sp = -jnp.log(u) / 8.0
            return jnp.log(jnp.expm1(jnp.maximum(sp, 1e-8))).astype(dtype)
        if init == "a_log":
            # mamba2: A in [1, 16) -> A_log = log(A).
            u = jax.random.uniform(k, shape, minval=1.0, maxval=16.0)
            return jnp.log(u).astype(dtype)
        if init == "dt_bias":
            # dt in [1e-3, 1e-1] through softplus.
            u = jax.random.uniform(k, shape, minval=1e-3, maxval=1e-1)
            return jnp.log(jnp.expm1(u)).astype(dtype)
        if init == "conv":
            fan = shape[-1]
            return (jax.random.normal(k, shape) / np.sqrt(fan)).astype(dtype)
        # fan_in variants: scale by 1/sqrt(prod of input dims).
        if init == "fan_in3":
            fan = shape[1]
        elif init == "fan_io":
            fan = shape[0] * shape[1]
        else:
            fan = shape[0]
        return (jax.random.normal(k, shape) / np.sqrt(fan)).astype(dtype)

    def mk(shape, axes, init):
        return draw(shape, init)

    def mk_stacked(shape, axes, init, n):
        return draw((n,) + shape, init)

    return model_tree(cfg, mk, mk_stacked)


def param_logical_axes(cfg: ModelConfig) -> dict:
    mk = lambda shape, axes, init: axes
    mk_stacked = lambda shape, axes, init, n: ("layers",) + axes
    return model_tree(cfg, mk, mk_stacked)


def abstract_params(cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    mk = lambda shape, axes, init: jax.ShapeDtypeStruct(shape, dtype)
    mk_stacked = lambda shape, axes, init, n: jax.ShapeDtypeStruct(
        (n,) + shape, dtype)
    return model_tree(cfg, mk, mk_stacked)
