"""Core layer math shared by every architecture.

Everything is a pure function over parameter pytrees. Attention comes in
three implementations selected by `cfg.attn_impl`:

- ``naive``: materializes the (T, S) logit matrix; fine for short context.
- ``jax_chunked``: pure-JAX flash attention (double scan over query/key
  chunks with running max/denominator) — O(chunk^2) live memory; this is
  the path used by the multi-pod dry-run (the Pallas kernel targets TPU
  and is validated separately in interpret mode).
- ``pallas``: the TPU kernel from `repro.kernels` (real hardware only).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.utils import dtype_of


# --------------------------------------------------------------------------
# Norms & activations
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6, *, zero_centered: bool = True):
    """RMSNorm with fp32 accumulation. `zero_centered`: gemma-style (1+w)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    scale = (1.0 + w) if zero_centered else w
    return (xf * scale).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


# --------------------------------------------------------------------------
# Rotary position embeddings (partial-rotary supported)
# --------------------------------------------------------------------------

def rope(x, positions, *, theta: float, rotary_pct: float = 1.0):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def _qk_norm(q, k, p, eps):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    return q, k


def _attn_mask(pos_q, pos_k, window: int):
    """(Tq, Tk) bool mask: causal + optional sliding window + validity.

    Invalid (unwritten) cache slots carry position -1 and are masked by
    the causality test (pos_k <= pos_q fails only if pos_q < 0, never true).
    """
    m = pos_k[None, :] <= pos_q[:, None]
    m &= pos_k[None, :] >= 0
    if window:
        m &= pos_k[None, :] > pos_q[:, None] - window
    return m


def _row_mask(pos_k, valid_from):
    """(B, Tk) bool: per-row first-valid key position.

    Rows in a batched cache can start at different positions (left-padded
    prompts, or a backfilled slot whose previous occupant left stale k/v
    behind): key position p is attendable for row b only if
    p >= valid_from[b]. The shared cache `pos` array stays (S,)."""
    return pos_k[None, :] >= valid_from[:, None]


def _repeat_kv(k, rep: int):
    """(B,S,KV,hd) -> (B,S,KV*rep,hd).

    GQA via explicit head repetition rather than a (KV, rep) reshape of
    the q-head dim: the flat head dim keeps its TP sharding (a 2D split
    would force GSPMD to shard the often-indivisible KV dim — v0
    roofline showed it replicating attention instead, §Perf iter 1).
    Each rank materializes only its local heads' copies."""
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def attention_naive(q, k, v, pos_q, pos_k, *, window: int, cap: float,
                    scale: float, valid_from=None):
    """q: (B,Tq,Hq,hd); k,v: (B,Tk,KV,hd). Returns (B,Tq,Hq,hd)."""
    B, Tq, Hq, hd = q.shape
    KV = k.shape[2]
    k = _repeat_kv(k, Hq // KV)
    v = _repeat_kv(v, Hq // KV)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cap)
    mask = _attn_mask(pos_q, pos_k, window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    if valid_from is not None:
        rm = _row_mask(pos_k, valid_from)  # (B, Tk)
        logits = jnp.where(rm[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    if valid_from is not None:
        # Shared masked-attention semantic (DESIGN.md §15): a query row
        # with no attendable key produces zeros, not the uniform-softmax
        # average the -1e30 fill would otherwise renormalize to.
        any_valid = (mask[None] & rm[:, None, :]).any(-1)  # (B, Tq)
        out = jnp.where(any_valid[:, :, None, None], out, 0.0)
    return out


def attention_chunked(q, k, v, pos_q, pos_k, *, window: int, cap: float,
                      scale: float, chunk_q: int, chunk_k: int,
                      valid_from=None):
    """Pure-JAX flash attention: scan over query chunks, inner scan over
    key chunks, maintaining running (max, denom, acc)."""
    B, Tq, Hq, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    k = _repeat_kv(k, Hq // KV)
    v = _repeat_kv(v, Hq // KV)
    cq = min(chunk_q, Tq)
    ck = min(chunk_k, Tk)
    # Pad to chunk multiples; padded q rows are discarded, padded k columns
    # are masked via position -1.
    pad_q = (-Tq) % cq
    pad_k = (-Tk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, (0, pad_q), constant_values=-(10 ** 9))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, (0, pad_k), constant_values=-1)
    nq, nk = q.shape[1] // cq, k.shape[1] // ck

    qs = q.reshape(B, nq, cq, Hq, hd).transpose(1, 0, 2, 3, 4)
    pqs = pos_q.reshape(nq, cq)
    ks = k.reshape(B, nk, ck, Hq, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, Hq, hd).transpose(1, 0, 2, 3, 4)
    pks = pos_k.reshape(nk, ck)

    def q_body(_, q_in):
        qc, pq = q_in  # (B,cq,H,hd), (cq,)
        m0 = jnp.full((B, Hq, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, cq), jnp.float32)
        a0 = jnp.zeros((B, Hq, cq, hd), jnp.float32)

        def k_step(carry, kc, vc, pk):
            m, l, acc = carry
            logits = jnp.einsum("bqhd,bkhd->bhqk", qc * scale, kc,
                                preferred_element_type=jnp.float32)
            logits = softcap(logits, cap)
            mask = _attn_mask(pq, pk, window)
            logits = jnp.where(mask[None, None], logits, -1e30)
            if valid_from is not None:
                rm = _row_mask(pk, valid_from)  # (B, ck)
                logits = jnp.where(rm[:, None, None, :], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc, preferred_element_type=jnp.float32)
            return m_new, l, acc

        def k_body(carry, k_in):
            kc, vc, pk = k_in
            if valid_from is None:
                return k_step(carry, kc, vc, pk), None
            # Early-skip invariant (shared with the pallas kernels,
            # DESIGN.md §15): a key chunk entirely below every row's
            # valid_from is fully masked for the whole batch and
            # contributes nothing — skip its compute outright.
            run = pk.max() >= jnp.min(valid_from)
            return jax.lax.cond(
                run, lambda c: k_step(c, kc, vc, pk), lambda c: c,
                carry), None

        (m, l, acc), _ = jax.lax.scan(k_body, (m0, l0, a0), (ks, vs, pks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        if valid_from is not None:
            # Fully-masked rows (m never rose above the -1e30 fill; the
            # -inf init marks rows whose every chunk was skipped): zeros.
            out = jnp.where((m > -5e29)[..., None], out, 0.0)
        out = out.transpose(0, 2, 1, 3)  # (B,cq,H,hd)
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, pqs))  # (nq,B,cq,H,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, Hq, hd)
    return out[:, :Tq]


def _impl_naive(q, k, v, pos_q, pos_k, cfg, *, window, cap, scale,
                valid_from):
    return attention_naive(q, k, v, pos_q, pos_k, window=window, cap=cap,
                           scale=scale, valid_from=valid_from)


def _impl_chunked(q, k, v, pos_q, pos_k, cfg, *, window, cap, scale,
                  valid_from):
    if q.shape[1] == 1:  # single-token: chunking buys nothing
        return attention_naive(q, k, v, pos_q, pos_k, window=window, cap=cap,
                               scale=scale, valid_from=valid_from)
    return attention_chunked(q, k, v, pos_q, pos_k, window=window, cap=cap,
                             scale=scale, chunk_q=cfg.attn_chunk,
                             chunk_k=cfg.attn_chunk, valid_from=valid_from)


def _impl_pallas(q, k, v, pos_q, pos_k, cfg, *, window, cap, scale,
                 valid_from):
    """The kernel fast path (interpret mode on CPU, Mosaic on TPU).

    Tq == 1 against a longer key set is a cache decode: the
    content-masked flash-decode kernel reads the stored-position array
    (correct for ring caches) and, on linear caches (window == 0 means
    every attention cache spans max_seq, so slot == position),
    block-skips slots outside [valid_from, cache_pos]. Anything else is
    a prefill over freshly computed contiguous k/v: the flash kernel's
    implicit positions match pos_q == pos_k, with valid_from shifted to
    kernel coordinates by the ops wrapper."""
    from repro.kernels import ops as kops  # deferred import
    if q.shape[1] == 1 and k.shape[1] > 1:
        return kops.decode_attention(q, k, v, pos_k, pos_q[0], valid_from,
                                     window=window, softcap=cap, scale=scale,
                                     linear=(window == 0))
    return kops.flash_attention(q, k, v, pos_q, pos_k, valid_from,
                                window=window, softcap=cap, scale=scale)


# Kernel dispatch registry (DESIGN.md §15). Every impl accepts the same
# signature — including per-row valid_from — so the serving engine keeps
# a single jit trace regardless of cfg.attn_impl.
ATTN_IMPLS = {
    "naive": _impl_naive,
    "jax_chunked": _impl_chunked,
    "pallas": _impl_pallas,
}


def attention(q, k, v, pos_q, pos_k, cfg: ModelConfig, *, window: int,
              valid_from=None):
    scale = cfg.head_dim ** -0.5
    cap = cfg.attn_softcap
    impl = cfg.attn_impl
    Tq, Tk = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "naive" if Tq * Tk <= 4096 * 4096 and Tq > 1 else (
            "naive" if Tq == 1 else "jax_chunked")
    try:
        fn = ATTN_IMPLS[impl]
    except KeyError:
        raise ValueError(
            f"unknown attn_impl {impl!r}; valid impls: "
            f"{', '.join(sorted(ATTN_IMPLS))} (or 'auto')") from None
    return fn(q, k, v, pos_q, pos_k, cfg, window=window, cap=cap,
              scale=scale, valid_from=valid_from)


def _proj(x, w, spec: str):
    """Projection dispatch (DESIGN.md §15): fp32/bf16 weight leaves run
    the given einsum; int8 execution leaves ({"q","scale"} dicts from
    `quant.int8.quantize_exec_tree`) dispatch to the int8 matmul kernel,
    so quantized zoo candidates get real int8 compute instead of a
    dequantized-fp32 round-trip. x's leading two axes are (batch, seq);
    every trailing x axis contracts against w's leading axes, so the
    flattened (B*T, K) @ (K, N) kernel call covers qkv (d -> (H, hd)),
    the output projection ((H, hd) -> d) and both MLP matmuls."""
    if isinstance(w, dict):
        from repro.kernels import ops as kops  # deferred import
        B, T = x.shape[0], x.shape[1]
        nc = x.ndim - 2                         # contracted x axes
        out_shape = w["q"].shape[nc:]
        x2 = x.reshape(B * T, -1)
        w2 = w["q"].reshape(x2.shape[1], -1)
        s2 = w["scale"].reshape(-1)
        out = kops.int8_matmul(x2, w2, s2).astype(x.dtype)
        return out.reshape((B, T) + out_shape)
    return jnp.einsum(spec, x, w)


def attn_block(p, x, cfg: ModelConfig, kind: str, positions,
               cache: Optional[dict] = None, cache_pos=None,
               constrain=None, parallel=None, valid_from=None):
    """Pre-norm attention block. Returns (x_out, new_cache).

    Train/prefill: cache is None, positions = (T,) absolute positions.
    Decode: cache = {"k","v"} ring/linear buffers, cache_pos = scalar of
    tokens already in context (the new token's position).
    constrain: optional residual sharding constraint (sequence
    parallelism) applied after every residual add, so GSPMD turns the
    row-parallel all-reduces into reduce-scatters.
    valid_from: optional (B,) int32 — per row, the first key position this
    row may attend to (masks left-padding and, on backfilled slots, the
    previous occupant's stale cache entries).
    """
    window = cfg.window if kind == "local" else 0
    eps = cfg.norm_eps
    h = rms_norm(x, p["ln1"], eps)
    B, T, _ = h.shape
    Hq, KV, hd = cfg.q_heads_padded, cfg.n_kv_heads, cfg.head_dim
    q = _proj(h, p["wq"], "btd,dhk->bthk")
    k = _proj(h, p["wk"], "btd,dhk->bthk")
    v = _proj(h, p["wv"], "btd,dhk->bthk")
    # Per-arch lever (§Perf): pinning q/k/v head-sharded stops GSPMD from
    # replicating attention over the model axis. On dense archs (whose
    # MLP anchors the propagation) it HURT (~2x gather/RS ping-pong); on
    # MoE archs (shard_map FFN gives no anchor) attention otherwise runs
    # fully replicated with fp32 dq/dk all-reduces. Off by default;
    # enabled per measured cell via ParallelConfig.attn_pin.
    if parallel is not None and getattr(parallel, "attn_pin", False) and T > 1:
        from jax.sharding import PartitionSpec as P_
        tpn = parallel.mesh.shape[parallel.tp_axis]
        qspec = P_(parallel.data_axes, None, parallel.tp_axis, None)
        kvspec = qspec if KV % tpn == 0 else P_(parallel.data_axes, None,
                                                None, None)
        q = jax.lax.with_sharding_constraint(q, qspec)
        k = jax.lax.with_sharding_constraint(k, kvspec)
        v = jax.lax.with_sharding_constraint(v, kvspec)
    q, k = _qk_norm(q, k, p, eps)
    q = rope(q, positions, theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct)
    k = rope(k, positions, theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct)

    new_cache = None
    out = None
    if cache is not None and T == 1 and parallel is not None and \
            cfg.n_kv_heads % parallel.mesh.shape[parallel.tp_axis] != 0:
        # Sequence-sharded cache (kv < tp): explicit distributed
        # flash-decode — masked local cache write + partial-softmax merge
        # (GSPMD's generic handling all-gathered the cache per layer).
        # valid_from folds into the per-shard content mask before the
        # partial-softmax stats merge.
        from repro.models.flash_decode import flash_decode_sharded
        out, ckn, cvn, cpn = flash_decode_sharded(
            q, k, v, cache["k"], cache["v"], cache["pos"], cache_pos,
            cfg, parallel, window=window, valid_from=valid_from)
        new_cache = {"k": ckn, "v": cvn, "pos": cpn}
    elif cache is not None and T == 1:
        # Decode: ring-buffer write. Windowed layers allocate S == window so
        # the modulo wraps; full layers allocate S == max_seq (identity).
        S = cache["k"].shape[1]
        slot = cache_pos % S
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        # Stored positions make masking correct for both ring & linear cases
        # (unwritten slots stay -1 and are masked out).
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], positions.astype(cache["pos"].dtype), (slot,))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        k, v, pos_k = ck, cv, cpos
        pos_q = positions
    elif cache is not None:
        # Prefill from position 0: attend over the freshly computed k/v and
        # write them into the cache preserving the ring invariant
        # (position p lives at slot p % S).
        S = cache["k"].shape[1]
        kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        pd = positions.astype(cache["pos"].dtype)
        if T >= S:
            slots = np.arange(T - S, T) % S
            ck = cache["k"].at[:, slots].set(kd[:, T - S:])
            cv = cache["v"].at[:, slots].set(vd[:, T - S:])
            cpos = cache["pos"].at[slots].set(pd[T - S:])
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, 0, 0, 0))
            cpos = jax.lax.dynamic_update_slice(cache["pos"], pd, (0,))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        pos_q = pos_k = positions
    else:
        pos_q = pos_k = positions

    if out is None:
        out = attention(q, k, v, pos_q, pos_k, cfg, window=window,
                        valid_from=valid_from)
    out = _proj(out, p["wo"], "bthk,hkd->btd")
    if cfg.sandwich_norm:
        out = rms_norm(out, p["post_attn_norm"], eps)
    x = x + out
    if constrain is not None:
        x = constrain(x)

    # FFN half (dense; MoE blocks override this in model.py).
    if "mlp" in p:
        h = rms_norm(x, p["ln2"], eps)
        out = mlp(p["mlp"], h, cfg)
        if cfg.sandwich_norm:
            out = rms_norm(out, p["post_ffn_norm"], eps)
        x = x + out
        if constrain is not None:
            x = constrain(x)
    return x, new_cache


def mlp(p, x, cfg: ModelConfig):
    act = act_fn(cfg.mlp_act)
    if cfg.mlp_gated:
        u = _proj(x, p["w_up"], "btd,df->btf")
        g = _proj(x, p["w_gate"], "btd,df->btf")
        h = act(g) * u
    else:
        h = act(_proj(x, p["w_up"], "btd,df->btf"))
    return _proj(h, p["w_down"], "btf,fd->btd")
