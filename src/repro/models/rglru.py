"""RecurrentGemma block: temporal conv + RG-LRU linear recurrence.

Recurrence (Griffin, arXiv:2402.19427):
    r_t = sigmoid(x_t W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (per-channel decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mode uses `lax.associative_scan` over (a, b) pairs — a linear
recurrence composes associatively: (a2, b2) o (a1, b1) = (a1*a2, a2*b1+b2).
Decode mode is the O(1) single-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, mlp, act_fn


def _gates(p, x, cfg: ModelConfig):
    c = cfg.rglru.c
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", x, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", x, p["w_x"]) + p["b_x"])
    log_a = -c * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = i * x
    # sqrt(1 - a^2) normalizer, computed stably in fp32.
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = (beta * gated_x.astype(jnp.float32))
    return a, b


def rglru_scan(p, x, cfg: ModelConfig, h0=None):
    """x: (B,T,W). Returns (y, h_last). Associative-scan linear recurrence."""
    a, b = _gates(p, x, cfg)
    if h0 is not None:
        # Fold the incoming state into the first step: b_0 += a_0 * h0.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x, cfg: ModelConfig, h):
    """x: (B,1,W); h: (B,W) fp32 state. Returns (y, h_new)."""
    a, b = _gates(p, x, cfg)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None].astype(x.dtype), h_new


def causal_conv1d(w, x, state=None):
    """Depthwise causal conv. x: (B,T,C); w: (C,K). state: (B,K-1,C) prior
    inputs for decode. Returns (y, new_state)."""
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[:, i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return y, new_state


def rglru_block(p, x, cfg: ModelConfig, cache=None):
    """Full recurrentgemma residual block (mixer + MLP).

    cache: None (sequence mode) or {"h": (B,W) fp32, "conv": (B,K-1,W)}.
    Returns (x_out, new_cache).
    """
    eps = cfg.norm_eps
    h = rms_norm(x, p["ln1"], eps)
    gate = act_fn("gelu")(jnp.einsum("btd,dw->btw", h, p["w_gate_branch"]))
    u = jnp.einsum("btd,dw->btw", h, p["w_in"])
    if cache is None:
        u, _ = causal_conv1d(p["conv_w"], u)
        y, _ = rglru_scan(p, u, cfg)
        new_cache = None
    elif x.shape[1] == 1:  # decode
        u, conv_state = causal_conv1d(p["conv_w"], u, cache["conv"])
        y, h_last = rglru_step(p, u, cfg, cache["h"])
        new_cache = {"h": h_last, "conv": conv_state}
    else:  # prefill: run the sequence scan, emit the final state
        u, conv_state = causal_conv1d(p["conv_w"], u)
        y, h_last = rglru_scan(p, u, cfg)
        new_cache = {"h": h_last, "conv": conv_state}
    out = jnp.einsum("btw,wd->btd", y * gate, p["w_out"])
    x = x + out

    h = rms_norm(x, p["ln2"], eps)
    x = x + mlp(p["mlp"], h, cfg)
    return x, new_cache
