"""Mixture-of-Experts FFN.

Two execution paths:

- **dense reference** (no mesh): every expert computed for every token,
  combined with renormalized top-k router probs. O(T*E*ff) — used for
  small smoke/property tests and as the oracle for the sharded path.

- **sharded** (`shard_map`): expert parallelism without any all_to_all.
  Activations are TP-replicated over the `model` axis when they reach
  the FFN, so every model-rank already holds all of its data-shard's
  tokens. Two weight layouts:

    * ``ep``  (E % tp == 0, e.g. qwen3 128e): experts sharded over the
      model axis; each rank dispatches its local tokens to its local
      experts via a capacity-bounded scatter (Mesh-TF position-in-expert
      cumsum), runs a grouped FFN, scatter-adds, and the closing
      ``psum(model)`` combines expert contributions across ranks.
    * ``tp``  (E < tp, e.g. grok 8e): every rank holds all experts but
      only an ff-slice; the same closing psum combines ff partial sums.

  Weights are additionally FSDP-sharded over `fsdp_axes` and
  all-gathered per layer inside the scan (ZeRO-3).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import act_fn
from repro.utils import shard_map


def router_topk(p, x2d, cfg: ModelConfig):
    """x2d: (T, d). Returns (vals (T,k), idx (T,k), probs (T,E) fp32)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, m.top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)  # renorm
    return vals, idx, probs


def moe_ffn_dense(p, x, cfg: ModelConfig):
    """Reference path: (B,T,d) -> ((B,T,d), aux_loss)."""
    m = cfg.moe
    B, T, d = x.shape
    x2 = x.reshape(B * T, d)
    vals, idx, probs = router_topk(p, x2, cfg)
    act = act_fn(cfg.mlp_act)
    # (T, E) combine weights.
    comb = jnp.zeros((B * T, m.n_experts), jnp.float32)
    comb = comb.at[jnp.arange(B * T)[:, None], idx].add(vals)
    g = jnp.einsum("td,edf->tef", x2, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2, p["w_up"])
    h = act(g) * u
    y = jnp.einsum("tef,efd->ted", h, p["w_down"])
    out = jnp.einsum("ted,te->td", y, comb.astype(y.dtype))
    aux = _load_balance_loss(comb, probs, m.n_experts)
    return out.reshape(B, T, d).astype(x.dtype), aux


def _load_balance_loss(comb, probs, E):
    """Switch-transformer load-balance loss: E * sum_e f_e * P_e."""
    f = (comb > 0).astype(jnp.float32).mean(0)  # fraction routed per expert
    pbar = probs.mean(0)
    return E * jnp.sum(f * pbar)


def _dispatch_indices(idx, vals, E_loc, off, C):
    """Capacity-bounded dispatch bookkeeping (per device).

    idx/vals: (T,k) global expert ids / gate weights. Experts
    [off, off+E_loc) are local. Returns (idx_buf (E_loc*C,) token ids,
    gate_buf (E_loc*C,) weights, comb_local for aux loss).
    """
    T, k = idx.shape
    flat_e = idx.reshape(-1) - off  # (T*k,) local expert or out of range
    flat_v = vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    valid = (flat_e >= 0) & (flat_e < E_loc)
    one_hot = jax.nn.one_hot(jnp.where(valid, flat_e, E_loc), E_loc + 1,
                             dtype=jnp.int32)[:, :E_loc]  # (T*k, E_loc)
    pos = jnp.cumsum(one_hot, axis=0) - one_hot  # exclusive count before me
    my_pos = jnp.sum(pos * one_hot, axis=1)  # (T*k,)
    keep = valid & (my_pos < C)
    slot = jnp.where(keep, jnp.where(valid, flat_e, 0) * C + my_pos,
                     E_loc * C)  # dropped -> out-of-range slot
    size = E_loc * C
    idx_buf = jnp.zeros((size,), jnp.int32).at[slot].set(
        flat_t.astype(jnp.int32), mode="drop")
    gate_buf = jnp.zeros((size,), jnp.float32).at[slot].set(
        flat_v, mode="drop")
    return idx_buf, gate_buf


def moe_weight_specs(mode: str, tp, fsdp):
    """Per-mode expert weight layouts (shard_map in_specs; the same
    mapping drives the stored-parameter shardings via repro.sharding).

    - ep:   experts/tp, d/fsdp    + per-layer FSDP gather of the weights
    - tp:   ff/tp, d/fsdp         + per-layer FSDP gather (E < tp_size)
    - ep2d: experts/tp, ff/fsdp   NO weight movement; activations are
            gathered over data instead (decode: x is tiny, weights huge)
    - tp2d: ff/(fsdp x tp)        NO weight movement (decode, E < tp)
    """
    if mode == "ep":
        return P(tp, fsdp, None), P(tp, None, fsdp)
    if mode == "tp":
        return P(None, fsdp, tp), P(None, tp, fsdp)
    if mode == "ep2d":
        return P(tp, None, fsdp), P(tp, fsdp, None)
    if mode == "tp2d":
        both = tuple(fsdp) + (tp,)
        return P(None, None, both), P(None, both, None)
    raise ValueError(mode)


def moe_ffn_sharded(p, x, cfg: ModelConfig, parallel):
    """shard_map path: (B,T,d) -> ((B,T,d), aux_loss)."""
    from repro.sharding import moe_mode_for

    m = cfg.moe
    tp = parallel.tp_axis
    tp_size = parallel.mesh.shape[tp]
    mode = moe_mode_for(cfg, parallel)
    fsdp = parallel.fsdp_axes
    data_axes = parallel.data_axes
    bspec = P(data_axes, None, None)
    wspec_in, wspec_out = moe_weight_specs(mode, tp, fsdp)
    rspec = P(None, None)
    twod = mode.endswith("2d")

    def device_fn(router_w, wg, wu, wd, xb):
        if twod:
            # Decode layout: move the (tiny) activations, not the weights.
            for ax in reversed(data_axes):
                xb = jax.lax.all_gather(xb, ax, axis=0, tiled=True)
        else:
            # Gather the FSDP shards of this layer's expert weights
            # (ZeRO-3). Innermost axis first so tiled concatenation
            # reconstructs the outer-major layout.
            for ax in reversed(fsdp):
                wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
        B_loc, T, d = xb.shape
        x2 = xb.reshape(B_loc * T, d)
        vals, idx, probs = router_topk({"router": router_w}, x2, cfg)
        T_tok = B_loc * T
        if mode.startswith("ep"):
            E_loc = m.n_experts // tp_size
            off = jax.lax.axis_index(tp) * E_loc
        else:
            E_loc = m.n_experts
            off = 0
        C = max(1, math.ceil(T_tok * m.top_k / m.n_experts * m.capacity_factor))
        C = min(C, T_tok)
        idx_buf, gate_buf = _dispatch_indices(idx, vals, E_loc, off, C)
        buf = x2[idx_buf]  # (E_loc*C, d)
        act = act_fn(cfg.mlp_act)
        bufe = buf.reshape(E_loc, C, d)
        g = jnp.einsum("ecd,edf->ecf", bufe, wg)
        u = jnp.einsum("ecd,edf->ecf", bufe, wu)
        y = jnp.einsum("ecf,efd->ecd", act(g) * u, wd).reshape(E_loc * C, d)
        y = y * gate_buf[:, None].astype(y.dtype)
        out = jnp.zeros((T_tok, d), y.dtype).at[idx_buf].add(y)
        out = jax.lax.psum(out, tp)
        if twod:
            # Combine the ff partial sums across data AND re-shard the
            # batch in one collective.
            out = out.reshape(B_loc, T, d)
            for ax in data_axes:
                out = jax.lax.psum_scatter(out, ax, scatter_dimension=0,
                                           tiled=True)
            B_out = out.shape[0]
            out = out.reshape(B_out, T, d)
        else:
            out = out.reshape(B_loc, T, d)
        # Aux loss: identical across tp ranks (same tokens & router);
        # pmean over the data axes makes it fully replicated.
        comb = jnp.zeros((T_tok, m.n_experts), jnp.float32).at[
            jnp.arange(T_tok)[:, None], idx].add(vals)
        aux = _load_balance_loss(comb, probs, m.n_experts)
        aux = jax.lax.pmean(aux, data_axes)
        return out, aux

    fn = shard_map(
        device_fn,
        mesh=parallel.mesh,
        in_specs=(rspec, wspec_in, wspec_in, wspec_out, bspec),
        out_specs=(bspec, P()),
        check_vma=False,
    )
    out, aux = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out.astype(x.dtype), aux


def moe_block_ffn(p, x, cfg: ModelConfig, parallel=None):
    if parallel is None:
        return moe_ffn_dense(p, x, cfg)
    return moe_ffn_sharded(p, x, cfg, parallel)
