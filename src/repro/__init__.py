"""repro: SLA-aware multi-model inference serving framework in JAX.

Reproduction of Ogden & Guo, "Characterizing the Deep Neural Networks
Inference Performance of Mobile Applications" (2019), adapted to TPU
pods: a zoo of large LMs with per-(arch, shape, mesh) latency profiles
and the CNNSelect SLA-aware model-selection algorithm in front of a
distributed batched inference engine.
"""

__version__ = "0.1.0"
