"""int8-weight matmul Pallas TPU kernel (paper Fig 6: 8-bit post-training
quantization, adapted to TPU serving).

C[M,N] = X[M,K] @ (Wq[K,N] * scale[N])   with Wq int8, per-output-channel
fp32 scales. Grid (nM, nN, nK), K innermost: the fp32 accumulator tile
stays in VMEM across the K sweep; scales are applied ONCE per output tile
at flush (not per K block), so the MXU consumes the int8 weights
directly after an on-chip convert. Tiles default to (256, 256, 512) —
multiples of the 128x128 MXU and int8-friendly (the Wq block is
512*256 = 128 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)   # int8 -> f32 on-chip
    acc[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(t == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc[...] * s_ref[0]).astype(o_ref.dtype)


def int8_matmul(x, w_q, w_scale, *, block_m: int = 256, block_n: int = 256,
                block_k: int = 512, interpret: bool = False):
    """x: (M, K) float; w_q: (K, N) int8; w_scale: (N,) f32 -> (M, N)."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        "pad operands to block multiples"
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, w_scale.reshape(1, N))
