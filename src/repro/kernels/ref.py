"""Pure-jnp oracles for every kernel (the ground truth in kernel tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


def flash_attention_ref(q, k, v, *, window: int = 0, cap: float = 0.0,
                        scale: float | None = None, causal: bool = True,
                        valid_from=None):
    """q: (B, Hq, T, hd); k, v: (B, KV, S, hd). Positions are implicit
    (q position i == kv position i). valid_from: optional (B,) first
    attendable key index per batch row; query rows with no attendable
    key at all produce zeros (the shared masked-attention semantic —
    DESIGN.md §15). Returns (B, Hq, T, hd) in q.dtype."""
    B, Hq, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    rep = Hq // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, rep, T, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bgrth,bgsh->bgrts", qg, k.astype(jnp.float32))
    logits = softcap(logits, cap)
    pos_q = jnp.arange(T)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window:
        mask &= pos_k > pos_q - window
    mask = mask[None] if valid_from is None else (
        mask[None] & (pos_k[None] >= valid_from[:, None, None]))  # (B,T,S)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrts,bgsh->bgrth", p, v.astype(jnp.float32))
    if valid_from is not None:
        any_valid = mask.any(axis=-1)                             # (B,T)
        out = jnp.where(any_valid[:, None, None, :, None], out, 0.0)
    return out.reshape(B, Hq, T, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, cache_pos, *, cap: float = 0.0,
                         scale: float | None = None, window: int = 0,
                         valid_from=None):
    """q: (B, Hq, hd); k, v: (B, KV, S, hd); pos: (S,) stored positions
    (-1 = unwritten); cache_pos: scalar current position. valid_from:
    optional (B,) first attendable stored position per row (rows with no
    attendable slot produce zeros). (B, Hq, hd)."""
    B, Hq, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    rep = Hq // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, rep, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bgrh,bgsh->bgrs", qg, k.astype(jnp.float32))
    logits = softcap(logits, cap)
    valid = (pos >= 0) & (pos <= cache_pos)
    if window:
        valid &= pos > cache_pos - window
    valid = valid[None] if valid_from is None else (
        valid[None] & (pos[None] >= valid_from[:, None]))          # (B,S)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsh->bgrh", p, v.astype(jnp.float32))
    if valid_from is not None:
        out = jnp.where(valid.any(axis=-1)[:, None, None, None], out, 0.0)
    return out.reshape(B, Hq, hd).astype(q.dtype)


def int8_matmul_ref(x, w_q, w_scale):
    """x: (M, K) float; w_q: (K, N) int8; w_scale: (1, N) or (N,) f32."""
    w = w_q.astype(jnp.float32) * w_scale.reshape(1, -1)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
