"""jit'd wrappers: shape checking, padding to block multiples, and the
model-facing entry point used when `cfg.attn_impl == "pallas"`.

On this CPU container the kernels run in interpret mode
(`REPRO_PALLAS_INTERPRET=1`, set by tests); on real TPU the same calls
compile to Mosaic."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.int8_matmul import int8_matmul as _int8mm


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1" or \
        jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_q", "block_k"))
def flash_attention_btHd(q, k, v, valid_from=None, *, window=0, softcap=0.0,
                         scale=None, block_q=512, block_k=512):
    """Model-layout wrapper: q (B,T,H,hd), k/v (B,S,KV,hd) — transposes to
    the kernel's (B,H,T,hd) layout and pads T/S to block multiples.
    valid_from: optional (B,) first attendable key index (0-based, same
    axis as the kernel's implicit positions)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    bq = min(block_q, max(T, 1))
    bk = min(block_k, max(S, 1))
    pad_q = (-T) % bq
    pad_k = (-S) % bk
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = _flash(qt, kt, vt, valid_from, window=window, softcap=softcap,
                 scale=scale, block_q=bq, block_k=bk, interpret=_interpret())
    out = out[:, :, :T]
    return jnp.moveaxis(out, 1, 2)


def flash_attention(q, k, v, pos_q, pos_k, valid_from=None, *, window=0,
                    softcap=0.0, scale=None):
    """Entry point matching repro.models.layers.attention's signature
    (prefill path: pos_q == pos_k, contiguous). The kernel's positions
    are implicit 0-based indices; `valid_from` is absolute (engine
    coordinates), so shift it by the window start — prefill_row runs at
    offset..offset+T-1 and causal/window masking is shift-invariant,
    but valid_from is not."""
    if valid_from is not None:
        valid_from = valid_from - pos_k[0]
    return flash_attention_btHd(q, k, v, valid_from, window=window,
                                softcap=softcap, scale=scale)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_s", "linear"))
def decode_attention(q, k, v, pos, cache_pos, valid_from=None, *, window=0,
                     softcap=0.0, scale=None, block_s=512, linear=False):
    """q: (B,1,H,hd) or (B,H,hd); k/v: (B,S,KV,hd) model layout.
    valid_from: optional (B,) first attendable stored position; linear
    declares slot == position (full-seq caches), enabling block skip."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    S = kt.shape[2]
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, (0, pad), constant_values=-1)
    out = _decode(q, kt, vt, pos, cache_pos, valid_from, window=window,
                  softcap=softcap, scale=scale, block_s=bs, linear=linear,
                  interpret=_interpret())
    return out[:, None] if squeeze else out


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def int8_matmul(x, w_q, w_scale, *, block_m=256, block_n=256, block_k=512):
    M, K = x.shape
    N = w_q.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w_q, ((0, pk), (0, pn))) if (pk or pn) else w_q
    sp = jnp.pad(w_scale, (0, pn)) if pn else w_scale
    out = _int8mm(xp, wp, sp, block_m=bm, block_n=bn, block_k=bk,
                  interpret=_interpret())
    return out[:M, :N]
