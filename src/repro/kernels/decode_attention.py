"""Flash-decode Pallas TPU kernel: one query token vs. a (ring-buffer)
KV cache.

Grid (B, KV, nS) with the cache-block index innermost; the per-(b, kv)
accumulator covers all `rep = Hq/KV` query heads of the group at once —
(rep, hd) tiles keep the MXU busy even at rep=1 because hd>=128.
Validity masking uses the stored position array (slot -> position,
-1 = unwritten), which makes the same kernel correct for linear and
ring-buffer (sliding-window) caches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(cpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, acc, m_i, l_i, *,
            scale: float, cap: float, window: int, rep: int, bs: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q = q_ref[0, 0].astype(jnp.float32) * scale   # (rep, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[0]                              # (bs,) stored positions
    cache_pos = cpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rep, bs)
    if cap:
        s = cap * jnp.tanh(s / cap)
    valid = (pos >= 0) & (pos <= cache_pos)
    if window:
        valid &= pos > cache_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_i[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_i[...] = l_i[...] * corr + p.sum(axis=1)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_i[...] = m_new

    @pl.when(t == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, cache_pos, *, window: int = 0,
                     softcap: float = 0.0, scale: float | None = None,
                     block_s: int = 512, interpret: bool = False):
    """q: (B, Hq, hd); k, v: (B, KV, S, hd); pos: (S,) int32;
    cache_pos: scalar int32. Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    assert Hq % KV == 0
    rep = Hq // KV
    bs = min(block_s, S)
    assert S % bs == 0
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, rep, hd)
    cpos = jnp.asarray(cache_pos, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, scale=scale, cap=softcap,
                             window=window, rep=rep, bs=bs)
    out = pl.pallas_call(
        kern,
        grid=(B, KV, S // bs),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # cache_pos scalar
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, t: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, t: (b, g, t, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, t: (b, g, t, 0)),
            pl.BlockSpec((1, bs), lambda b, g, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, t: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
        ],
        interpret=interpret,
    )(cpos, qg, k, v, pos.reshape(1, S))
    return out.reshape(B, Hq, hd)
