"""Flash-decode Pallas TPU kernel: one query token vs. a (ring-buffer)
KV cache.

Grid (B, KV, nS) with the cache-block index innermost; the per-(b, kv)
accumulator covers all `rep = Hq/KV` query heads of the group at once —
(rep, hd) tiles keep the MXU busy even at rep=1 because hd>=128.
Validity masking uses the stored position array (slot -> position,
-1 = unwritten), which makes the same kernel correct for linear and
ring-buffer (sliding-window) caches; per-row `valid_from` folds into
the same content mask (pos >= valid_from[b]), masking left-padding and
a backfilled slot's stale previous-occupant entries.

`linear=True` declares slot index == stored position (full-seq caches,
the serving engine's layout), unlocking a block-level early-skip: cache
blocks entirely below this row's valid_from, or entirely past
cache_pos, are gated off without reading k/v. Ring caches (slot !=
position) keep the always-correct content mask only. The online
rescale self-heals any all-masked block (corr -> 0 once a valid slot
appears); rows with no attendable slot at all flush zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(cpos_ref, vf_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            acc, m_i, l_i, *, scale: float, cap: float, window: int,
            rep: int, bs: int, linear: bool):
    b = pl.program_id(0)
    t = pl.program_id(2)
    cache_pos = cpos_ref[0]
    vf = vf_ref[b]

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    if linear:
        # Slot s holds position s (or -1): a block wholly below
        # valid_from or wholly past cache_pos cannot contribute.
        run = jnp.logical_and(t * bs + bs - 1 >= vf, t * bs <= cache_pos)
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (rep, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        pos = pos_ref[0]                              # (bs,) stored positions

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (rep, bs)
        if cap:
            s = cap * jnp.tanh(s / cap)
        valid = (pos >= vf) & (pos <= cache_pos)
        if window:
            valid &= pos > cache_pos - window
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_i[...] = l_i[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_i[...] = m_new

    @pl.when(t == pl.num_programs(2) - 1)
    def _flush():
        seen = m_i[...] > NEG_INF * 0.5
        out = acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
        o_ref[0, 0] = jnp.where(seen[:, None], out, 0.0).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, cache_pos, valid_from=None, *,
                     window: int = 0, softcap: float = 0.0,
                     scale: float | None = None, block_s: int = 512,
                     linear: bool = False, interpret: bool = False):
    """q: (B, Hq, hd); k, v: (B, KV, S, hd); pos: (S,) int32;
    cache_pos: scalar int32. valid_from: optional (B,) int32 first
    attendable stored position per row (None == zeros == unmasked).
    linear: slot index == stored position (enables block early-skip).
    Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    assert Hq % KV == 0
    rep = Hq // KV
    bs = min(block_s, S)
    assert S % bs == 0
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, rep, hd)
    cpos = jnp.asarray(cache_pos, jnp.int32).reshape(1)
    if valid_from is None:
        valid_from = jnp.zeros((B,), jnp.int32)
    vf = jnp.asarray(valid_from, jnp.int32).reshape(B)

    kern = functools.partial(_kernel, scale=scale, cap=softcap,
                             window=window, rep=rep, bs=bs, linear=linear)
    out = pl.pallas_call(
        kern,
        grid=(B, KV, S // bs),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # cache_pos scalar
            pl.BlockSpec(memory_space=pltpu.SMEM),   # valid_from (B,)
            pl.BlockSpec((1, 1, rep, hd), lambda b, g, t: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, t: (b, g, t, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, t: (b, g, t, 0)),
            pl.BlockSpec((1, bs), lambda b, g, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, t: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
        ],
        interpret=interpret,
    )(cpos, vf, qg, k, v, pos.reshape(1, S))
    return out.reshape(B, Hq, hd)
