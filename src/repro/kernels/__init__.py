"""Pallas TPU kernels for the serving hot spots.

- flash_attention: prefill attention (GQA + sliding window + logit
  softcap + causal), online-softmax over KV blocks in VMEM.
- decode_attention: flash-decode over a (possibly ring-buffer) KV cache.
- int8_matmul: per-channel-scaled int8 x bf16 matmul (the TPU adaptation
  of the paper's 8-bit post-training quantization study — MXU-aligned
  128x128 tiles, scales applied once per tile column at flush).

Each kernel ships with `ops.py` (jit'd wrappers used by the model when
`attn_impl="pallas"`) and `ref.py` (pure-jnp oracles); tests sweep
shapes/dtypes in interpret mode against the oracles.
"""
