"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax over KV blocks: grid (B, Hq, nQ, nK) with the KV-block
index innermost, so the (bq, hd) accumulator, running max and denominator
live in VMEM scratch across the inner sweep and the output block is
flushed once on the last KV step. GQA is folded into the K/V BlockSpec
index maps (q head h reads kv head h // rep). Causal + sliding-window +
per-row `valid_from` masking is block-skipped: fully-masked KV blocks
contribute nothing and their compute is gated behind pl.when — a
left-padded (or backfilled) row whose first attendable key is
valid_from[b] never pays FLOPs for KV blocks entirely below it.

Rows with no attendable key at all (valid_from past the last key) flush
zeros: with a finite NEG_INF the softmax of an all-masked row would
otherwise renormalize garbage (exp(0) per masked entry). The online
rescale already self-heals any all-masked *block* (corr -> 0 once a
valid key appears); the flush guard covers the only case it cannot.

VMEM budget per step (defaults bq=bk=512, hd<=256, fp32 scratch):
q (512*256*4) + k/v (2*512*256*4) + acc (512*256*4) ~= 2 MiB << 16 MiB
v5e VMEM; block dims are multiples of (8,128) MXU/VREG tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(vf_ref, q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
            scale: float, cap: float, window: int, causal: bool,
            bq: int, bk: int):
    b = pl.program_id(0)  # batch row (selects this row's valid_from)
    j = pl.program_id(2)  # q block
    t = pl.program_id(3)  # kv block (innermost)
    vf = vf_ref[b]

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_start = j * bq
    k_start = t * bk
    # Block-level skip: fully-masked KV blocks are gated off entirely —
    # causal (block above the diagonal), window (block before the
    # window) and valid_from (block entirely below this row's first
    # attendable key).
    run = k_start + bk - 1 >= vf
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = pos_k >= vf
        if causal:
            mask &= pos_k <= pos_q
        if window:
            mask &= pos_k > pos_q - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_i[...] = l_i[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_i[...] = m_new

    @pl.when(t == pl.num_programs(3) - 1)
    def _flush():
        # m_i still at NEG_INF <=> the row never saw an attendable key.
        seen = m_i[...] > NEG_INF * 0.5
        out = acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
        o_ref[0, 0] = jnp.where(seen[:, None], out, 0.0).astype(o_ref.dtype)


def flash_attention(q, k, v, valid_from=None, *, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: (B, Hq, T, hd); k, v: (B, KV, S, hd) -> (B, Hq, T, hd).

    valid_from: optional (B,) int32 — per row, the first key index that
    may be attended (kernel-relative, i.e. on the same 0-based axis as
    the implicit positions). None == zeros == unmasked (bit-identical:
    the masking terms are value-level no-ops on causal rows)."""
    B, Hq, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    assert Hq % KV == 0, (Hq, KV)
    rep = Hq // KV
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, "pad sequences to block multiples"
    scale = hd ** -0.5 if scale is None else scale
    grid = (B, Hq, T // bq, S // bk)
    if valid_from is None:
        valid_from = jnp.zeros((B,), jnp.int32)
    vf = jnp.asarray(valid_from, jnp.int32).reshape(B)

    kern = functools.partial(
        _kernel, scale=scale, cap=softcap, window=window, causal=causal,
        bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # valid_from (B,)
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, j, t: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, t, rep=rep: (b, h // rep, t, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, t, rep=rep: (b, h // rep, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, j, t: (b, h, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(vf, q, k, v)
