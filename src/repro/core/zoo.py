"""Model zoo with cold/hot lifecycle management.

Paper §4.1 ("Impact of model startup latency"): cold-start inference is
one to two orders of magnitude slower than hot-start, so "it is critical
to keep important and often used CNN models in the memory". The zoo
models exactly that: an accelerator-memory budget, LRU eviction, and a
cold-start penalty charged when a request lands on a cold model."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.selection import ModelProfile


@dataclass
class ZooEntry:
    profile: ModelProfile
    hot: bool = False
    last_used: float = 0.0
    loads: int = 0
    evictions: int = 0
    runner: object = None  # optional real engine (repro.serving.engine)


class ModelZoo:
    def __init__(self, memory_budget_bytes: Optional[int] = None):
        self.entries: Dict[str, ZooEntry] = {}
        self.memory_budget = memory_budget_bytes
        self.total_cold_starts = 0

    def register(self, profile: ModelProfile, *, hot: bool = False,
                 runner=None):
        self.entries[profile.name] = ZooEntry(profile, hot=hot,
                                              runner=runner)

    @property
    def names(self) -> List[str]:
        return list(self.entries)

    def profiles(self) -> List[ModelProfile]:
        return [e.profile for e in self.entries.values()]

    def hot_bytes(self) -> int:
        return sum(e.profile.size_bytes for e in self.entries.values()
                   if e.hot)

    def ensure_hot(self, name: str, now: float,
                   rng: Optional[np.random.Generator] = None) -> float:
        """Returns the startup delay paid by this request (0 if hot).
        Evicts LRU entries if the memory budget would be exceeded."""
        e = self.entries[name]
        e.last_used = now
        if e.hot:
            return 0.0
        # Evict until it fits.
        if self.memory_budget is not None:
            while (self.hot_bytes() + e.profile.size_bytes
                   > self.memory_budget):
                victims = [x for x in self.entries.values()
                           if x.hot and x.profile.name != name]
                if not victims:
                    break
                v = min(victims, key=lambda x: x.last_used)
                v.hot = False
                v.evictions += 1
        e.hot = True
        e.loads += 1
        self.total_cold_starts += 1
        p = e.profile
        # Cold start adds (cold - hot) extra latency on top of execution.
        extra_mu = max(p.cold_mu - p.mu, 0.0)
        extra_sg = max(p.cold_sigma - p.sigma, 0.0)
        if rng is None or extra_mu == 0.0:
            return extra_mu
        return float(max(rng.normal(extra_mu, extra_sg + 1e-9), 0.0))

    def evict(self, name: str) -> None:
        """Force-evict one entry (cluster-wide placement,
        serving/cluster.py: the placer's global budget decides victims
        across zoos, then evicts here)."""
        e = self.entries[name]
        if e.hot:
            e.hot = False
            e.evictions += 1

    def lru_hot(self, exclude=()) -> Optional[ZooEntry]:
        """The least-recently-used hot entry (eviction candidate),
        skipping `exclude` names; None when nothing is evictable."""
        victims = [e for e in self.entries.values()
                   if e.hot and e.profile.name not in exclude]
        return min(victims, key=lambda e: e.last_used) if victims else None

    def sample_exec(self, name: str, rng: np.random.Generator) -> float:
        p = self.entries[name].profile
        return float(max(rng.normal(p.mu, p.sigma + 1e-9), 0.1 * p.mu))

    def prewarm(self, names, now: float = 0.0):
        for n in names:
            self.ensure_hot(n, now)
        self.total_cold_starts = 0
