"""CNNSelect (paper §5) and baseline selection policies.

Per request: budget ``T_budget = T_sla - 2*T_input`` and limits
``T_U = T_budget``, ``T_L = T_U - T_threshold``.

Stage 1 (greedy base): maximize A(m) s.t. mu+sigma < T_U and
mu-sigma < T_L; infeasible -> fastest model (best-effort fallback).

Stage 2 (exploration set): T_E = T_L +- (|T_L - mu*| + sigma*)
(the symmetric interval from Fig 11; ``stage2_variant="text"`` gives the
paper's printed-equation variant — see DESIGN.md §8 fidelity notes);
M_E = {m : mu(m) in T_E and mu(m)+sigma(m) < T_U} plus the base model.

Stage 3 (probabilistic pick): U(m) = A(m) * (T_U - (mu+sigma)) / |T_L - mu|,
Pr(m) proportional to U(m) over M_E (clamped to eps > 0; the guards are
exercised by the hypothesis property tests).

Two implementations, tested for agreement:
  - `cnnselect`: numpy reference, one request.
  - `cnnselect_batch`: vectorized jnp over N requests (the 10k-request
    simulations of §5.2 run through this under jit/vmap).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

_EPS = 1e-9


@dataclass(frozen=True)
class ModelProfile:
    name: str
    accuracy: float            # A(m), in [0, 1]
    mu: float                  # mean inference time (ms)
    sigma: float               # std of inference time (ms)
    cold_mu: float = 0.0       # cold-start mean (ms), Table 5
    cold_sigma: float = 0.0
    size_bytes: int = 0


@dataclass
class SelectionResult:
    index: int                 # selected model
    base_index: int            # stage-1 base model
    eligible: np.ndarray       # bool (K,), the exploration set M_E
    probs: np.ndarray          # (K,), zero outside M_E
    fallback: bool             # stage-1 infeasible -> fastest model
    t_budget: float
    t_low: float
    t_up: float


def _limits(t_sla: float, t_input: float, t_threshold: float):
    t_budget = t_sla - 2.0 * t_input
    t_up = t_budget
    t_low = t_up - t_threshold
    return t_budget, t_low, t_up


def cnnselect(profiles: Sequence[ModelProfile], t_sla: float, t_input: float,
              t_threshold: float, rng: np.random.Generator,
              stage2_variant: str = "figure") -> SelectionResult:
    acc = np.array([p.accuracy for p in profiles], dtype=np.float64)
    mu = np.array([p.mu for p in profiles], dtype=np.float64)
    sg = np.array([p.sigma for p in profiles], dtype=np.float64)
    K = len(profiles)
    t_budget, t_low, t_up = _limits(t_sla, t_input, t_threshold)

    # Stage 1: greedy base model.
    feasible = (mu + sg < t_up) & (mu - sg < t_low)
    fallback = not feasible.any()
    if fallback:
        base = int(np.argmin(mu))
    else:
        # max accuracy; ties -> smaller mu.
        masked = np.where(feasible, acc, -np.inf)
        best_acc = masked.max()
        cands = np.where(masked >= best_acc - 1e-12)[0]
        base = int(cands[np.argmin(mu[cands])])

    # Stage 2: exploration set.
    if fallback:
        eligible = np.zeros(K, dtype=bool)
        eligible[base] = True
    else:
        if stage2_variant == "figure":
            delta = abs(t_low - mu[base]) + sg[base]
            lo, hi = t_low - delta, t_low + delta
        else:  # "text": the paper's printed equation
            if t_low > mu[base]:
                lo, hi = mu[base] + sg[base], 2 * t_low - mu[base] + sg[base]
            else:
                lo, hi = 2 * t_low - mu[base] + sg[base], mu[base] + sg[base]
        eligible = (mu >= lo) & (mu <= hi) & (mu + sg < t_up)
        eligible[base] = True

    # Stage 3: probabilistic pick by utility.
    util = acc * (t_up - (mu + sg)) / np.maximum(np.abs(t_low - mu), _EPS)
    util = np.where(eligible, np.maximum(util, _EPS), 0.0)
    total = util.sum()
    probs = util / total if total > 0 else eligible / eligible.sum()
    idx = int(rng.choice(K, p=probs))
    return SelectionResult(idx, base, eligible, probs, fallback,
                           t_budget, t_low, t_up)


# --------------------------------------------------------------------------
# Vectorized jnp implementation (N requests at once)
# --------------------------------------------------------------------------

def cnnselect_batch(mu, sigma, acc, t_sla, t_input, t_threshold, key,
                    stage2_variant: str = "figure"):
    """mu/sigma/acc: (K,); t_sla/t_input: (N,); key: PRNGKey.
    Returns (selected (N,) int32, probs (N,K), base (N,) int32)."""
    import jax
    import jax.numpy as jnp

    mu = jnp.asarray(mu, jnp.float32)
    sg = jnp.asarray(sigma, jnp.float32)
    acc = jnp.asarray(acc, jnp.float32)
    t_sla = jnp.asarray(t_sla, jnp.float32)
    t_input = jnp.asarray(t_input, jnp.float32)
    K = mu.shape[0]

    t_up = (t_sla - 2.0 * t_input)[:, None]          # (N,1)
    t_low = t_up - t_threshold

    feasible = (mu + sg < t_up) & (mu - sg < t_low)  # (N,K)
    any_feas = feasible.any(axis=1)
    masked_acc = jnp.where(feasible, acc, -jnp.inf)
    # max accuracy, ties -> smaller mu: lexicographic score.
    score = masked_acc - 1e-9 * mu
    base_feas = jnp.argmax(score, axis=1)
    base_fall = jnp.argmin(mu)
    base = jnp.where(any_feas, base_feas, base_fall).astype(jnp.int32)

    mu_b = mu[base][:, None]
    sg_b = sg[base][:, None]
    if stage2_variant == "figure":
        delta = jnp.abs(t_low - mu_b) + sg_b
        lo, hi = t_low - delta, t_low + delta
    else:
        hi0 = 2 * t_low - mu_b + sg_b
        lo0 = mu_b + sg_b
        swap = t_low <= mu_b
        lo = jnp.where(swap, hi0, lo0)
        hi = jnp.where(swap, lo0, hi0)
    eligible = (mu >= lo) & (mu <= hi) & (mu + sg < t_up)
    eligible = eligible | jax.nn.one_hot(base, K, dtype=bool)
    eligible = jnp.where(any_feas[:, None], eligible,
                         jax.nn.one_hot(base, K, dtype=bool))

    util = acc * (t_up - (mu + sg)) / jnp.maximum(jnp.abs(t_low - mu), _EPS)
    util = jnp.where(eligible, jnp.maximum(util, _EPS), 0.0)
    probs = util / jnp.maximum(util.sum(axis=1, keepdims=True), _EPS)
    # Gumbel-max categorical sampling.
    g = jax.random.gumbel(key, probs.shape)
    logp = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-30)), -jnp.inf)
    selected = jnp.argmax(logp + g, axis=1).astype(jnp.int32)
    return selected, probs, base


# --------------------------------------------------------------------------
# Baselines (paper §5.2.2 and standard references)
# --------------------------------------------------------------------------

def greedy_select(profiles: Sequence[ModelProfile], t_sla: float,
                  *, t_input: float = 0.0, use_network: bool = False) -> int:
    """Paper's greedy: the most accurate model whose mean time fits the
    SLA. It ignores network-time variability (use_network=False) — the
    key weakness CNNSelect addresses."""
    budget = t_sla - (2.0 * t_input if use_network else 0.0)
    acc = np.array([p.accuracy for p in profiles])
    mu = np.array([p.mu for p in profiles])
    ok = mu <= budget
    if not ok.any():
        return int(np.argmin(mu))
    masked = np.where(ok, acc, -np.inf)
    return int(np.argmax(masked))


def static_select(profiles: Sequence[ModelProfile], index: int) -> int:
    return index


def random_select(profiles: Sequence[ModelProfile],
                  rng: np.random.Generator) -> int:
    return int(rng.integers(len(profiles)))


def oracle_select(profiles: Sequence[ModelProfile], t_sla: float,
                  t_input: float, realized_times: np.ndarray) -> int:
    """Upper bound: knows each model's realized execution time for this
    request; picks the most accurate that meets the SLA end-to-end."""
    acc = np.array([p.accuracy for p in profiles])
    ok = realized_times + 2.0 * t_input <= t_sla
    if not ok.any():
        return int(np.argmin(realized_times))
    masked = np.where(ok, acc, -np.inf)
    return int(np.argmax(masked))
