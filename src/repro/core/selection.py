"""CNNSelect (paper §5) and baseline selection policies.

Per request: budget ``T_budget = T_sla - 2*T_input`` and limits
``T_U = T_budget``, ``T_L = T_U - T_threshold``.

Stage 1 (greedy base): maximize A(m) s.t. mu+sigma < T_U and
mu-sigma < T_L; infeasible -> fastest model (best-effort fallback).

Stage 2 (exploration set): T_E = T_L +- (|T_L - mu*| + sigma*)
(the symmetric interval from Fig 11; ``stage2_variant="text"`` gives the
paper's printed-equation variant — see DESIGN.md §8 fidelity notes);
M_E = {m : mu(m) in T_E and mu(m)+sigma(m) < T_U} plus the base model.

Stage 3 (probabilistic pick): U(m) = A(m) * (T_U - (mu+sigma)) / |T_L - mu|,
Pr(m) proportional to U(m) over M_E (clamped to eps > 0; the guards are
exercised by the hypothesis property tests).

Two implementations, tested for agreement:
  - `cnnselect`: numpy reference, one request.
  - `cnnselect_batch`: vectorized jnp over N requests (the 10k-request
    simulations of §5.2 run through this under jit/vmap).

Both are wrapped by the `Policy` layer (DESIGN.md §2): every selection
strategy — cnnselect, greedy, greedy_nw, random, static:<name>, oracle —
is a `Policy` object with scalar `select` and vectorized `select_batch`
entry points, resolved by name through `make_policy`. The serving stacks
(server, loop, simulator) all dispatch through this one registry.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.registry import parse_spec

_EPS = 1e-9

# Paper's conservative network-time estimate: responses are small text
# labels, so T_nw = 2 * T_input (upload + equal-cost download).
T_NW_FACTOR = 2.0


def network_budget(t_sla, t_input, factor: float = T_NW_FACTOR):
    """Execution-time budget left after network time:
    ``T_budget = T_sla - factor * T_input``. `t_input` is whatever the
    serving stack budgets with — the observed upload time (paper) or an
    online `TInputEstimator` output (time-varying networks, DESIGN.md
    §9). Works on scalars, numpy, and jnp arrays."""
    return t_sla - factor * t_input


def on_device_fallback_decision(t_sla, t_input_est, fastest_mu,
                                on_device_ms, factor: float = T_NW_FACTOR):
    """MDInference's (arXiv:2002.06603) on-device-vs-cloud duality,
    evaluated with the *device's* estimated budget: serve locally iff
    the device can meet the SLA on its own while the estimated cloud
    path cannot even with the fastest model in the zoo —

        ``on_device_ms <= T_sla < factor * t_input_est + fastest_mu``.

    ``on_device_ms == 0`` means the device has no on-device capability
    and never falls back (paper §4: a Nexus 5 at ~9 s is never viable).
    Vectorized over per-request arrays of estimates / device profiles."""
    od = np.asarray(on_device_ms, np.float64)
    cloud_est = factor * np.asarray(t_input_est, np.float64) + fastest_mu
    return (od > 0.0) & (od <= t_sla) & (cloud_est > t_sla)


@dataclass(frozen=True)
class ModelProfile:
    name: str
    accuracy: float            # A(m), in [0, 1]
    mu: float                  # mean inference time (ms)
    sigma: float               # std of inference time (ms)
    cold_mu: float = 0.0       # cold-start mean (ms), Table 5
    cold_sigma: float = 0.0
    size_bytes: int = 0


@dataclass
class SelectionResult:
    index: int                 # selected model
    base_index: int            # stage-1 base model
    eligible: np.ndarray       # bool (K,), the exploration set M_E
    probs: np.ndarray          # (K,), zero outside M_E
    fallback: bool             # stage-1 infeasible -> fastest model
    t_budget: float
    t_low: float
    t_up: float


def _limits(t_sla: float, t_input: float, t_threshold: float):
    t_budget = network_budget(t_sla, t_input)
    t_up = t_budget
    t_low = t_up - t_threshold
    return t_budget, t_low, t_up


def cnnselect(profiles: Sequence[ModelProfile], t_sla: float, t_input: float,
              t_threshold: float, rng: np.random.Generator,
              stage2_variant: str = "figure") -> SelectionResult:
    acc = np.array([p.accuracy for p in profiles], dtype=np.float64)
    mu = np.array([p.mu for p in profiles], dtype=np.float64)
    sg = np.array([p.sigma for p in profiles], dtype=np.float64)
    K = len(profiles)
    t_budget, t_low, t_up = _limits(t_sla, t_input, t_threshold)

    # Stage 1: greedy base model.
    feasible = (mu + sg < t_up) & (mu - sg < t_low)
    fallback = not feasible.any()
    if fallback:
        base = int(np.argmin(mu))
    else:
        # max accuracy; ties -> smaller mu.
        masked = np.where(feasible, acc, -np.inf)
        best_acc = masked.max()
        cands = np.where(masked >= best_acc - 1e-12)[0]
        base = int(cands[np.argmin(mu[cands])])

    # Stage 2: exploration set.
    if fallback:
        eligible = np.zeros(K, dtype=bool)
        eligible[base] = True
    else:
        if stage2_variant == "figure":
            delta = abs(t_low - mu[base]) + sg[base]
            lo, hi = t_low - delta, t_low + delta
        else:  # "text": the paper's printed equation
            if t_low > mu[base]:
                lo, hi = mu[base] + sg[base], 2 * t_low - mu[base] + sg[base]
            else:
                lo, hi = 2 * t_low - mu[base] + sg[base], mu[base] + sg[base]
        eligible = (mu >= lo) & (mu <= hi) & (mu + sg < t_up)
        eligible[base] = True

    # Stage 3: probabilistic pick by utility.
    util = acc * (t_up - (mu + sg)) / np.maximum(np.abs(t_low - mu), _EPS)
    util = np.where(eligible, np.maximum(util, _EPS), 0.0)
    total = util.sum()
    probs = util / total if total > 0 else eligible / eligible.sum()
    idx = int(rng.choice(K, p=probs))
    return SelectionResult(idx, base, eligible, probs, fallback,
                           t_budget, t_low, t_up)


# --------------------------------------------------------------------------
# Vectorized jnp implementation (N requests at once)
# --------------------------------------------------------------------------

def cnnselect_batch(mu, sigma, acc, t_sla, t_input, t_threshold, key,
                    stage2_variant: str = "figure"):
    """mu/sigma/acc: (K,); t_sla/t_input: (N,); key: PRNGKey.
    Returns (selected (N,) int32, probs (N,K), base (N,) int32)."""
    import jax
    import jax.numpy as jnp

    mu = jnp.asarray(mu, jnp.float32)
    sg = jnp.asarray(sigma, jnp.float32)
    acc = jnp.asarray(acc, jnp.float32)
    t_sla = jnp.asarray(t_sla, jnp.float32)
    t_input = jnp.asarray(t_input, jnp.float32)
    K = mu.shape[0]

    t_up = network_budget(t_sla, t_input)[:, None]   # (N,1)
    t_low = t_up - t_threshold

    feasible = (mu + sg < t_up) & (mu - sg < t_low)  # (N,K)
    any_feas = feasible.any(axis=1)
    masked_acc = jnp.where(feasible, acc, -jnp.inf)
    # max accuracy, ties -> smaller mu: lexicographic score.
    score = masked_acc - 1e-9 * mu
    base_feas = jnp.argmax(score, axis=1)
    base_fall = jnp.argmin(mu)
    base = jnp.where(any_feas, base_feas, base_fall).astype(jnp.int32)

    mu_b = mu[base][:, None]
    sg_b = sg[base][:, None]
    if stage2_variant == "figure":
        delta = jnp.abs(t_low - mu_b) + sg_b
        lo, hi = t_low - delta, t_low + delta
    else:
        hi0 = 2 * t_low - mu_b + sg_b
        lo0 = mu_b + sg_b
        swap = t_low <= mu_b
        lo = jnp.where(swap, hi0, lo0)
        hi = jnp.where(swap, lo0, hi0)
    eligible = (mu >= lo) & (mu <= hi) & (mu + sg < t_up)
    eligible = eligible | jax.nn.one_hot(base, K, dtype=bool)
    eligible = jnp.where(any_feas[:, None], eligible,
                         jax.nn.one_hot(base, K, dtype=bool))

    util = acc * (t_up - (mu + sg)) / jnp.maximum(jnp.abs(t_low - mu), _EPS)
    util = jnp.where(eligible, jnp.maximum(util, _EPS), 0.0)
    probs = util / jnp.maximum(util.sum(axis=1, keepdims=True), _EPS)
    # Gumbel-max categorical sampling.
    g = jax.random.gumbel(key, probs.shape)
    logp = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-30)), -jnp.inf)
    selected = jnp.argmax(logp + g, axis=1).astype(jnp.int32)
    return selected, probs, base


_BATCH_JIT = None


def _jit_cnnselect_batch():
    """Process-wide jit of `cnnselect_batch` (stage2_variant is static);
    compiled once per (chunk, K) shape and shared by every policy
    instance."""
    global _BATCH_JIT
    if _BATCH_JIT is None:
        import jax
        _BATCH_JIT = jax.jit(cnnselect_batch,
                             static_argnames=("stage2_variant",))
    return _BATCH_JIT


# --------------------------------------------------------------------------
# Baselines (paper §5.2.2 and standard references)
# --------------------------------------------------------------------------

def greedy_select(profiles: Sequence[ModelProfile], t_sla: float,
                  *, t_input: float = 0.0, use_network: bool = False) -> int:
    """Paper's greedy: the most accurate model whose mean time fits the
    SLA. It ignores network-time variability (use_network=False) — the
    key weakness CNNSelect addresses."""
    budget = network_budget(t_sla, t_input) if use_network else t_sla
    acc = np.array([p.accuracy for p in profiles])
    mu = np.array([p.mu for p in profiles])
    ok = mu <= budget
    if not ok.any():
        return int(np.argmin(mu))
    masked = np.where(ok, acc, -np.inf)
    return int(np.argmax(masked))


def static_select(profiles: Sequence[ModelProfile], index: int) -> int:
    return index


def random_select(profiles: Sequence[ModelProfile],
                  rng: np.random.Generator) -> int:
    return int(rng.integers(len(profiles)))


def oracle_select(profiles: Sequence[ModelProfile], t_sla: float,
                  t_input: float, realized_times: np.ndarray) -> int:
    """Upper bound: knows each model's realized execution time for this
    request; picks the most accurate that meets the SLA end-to-end."""
    acc = np.array([p.accuracy for p in profiles])
    ok = realized_times <= network_budget(t_sla, t_input)
    if not ok.any():
        return int(np.argmin(realized_times))
    masked = np.where(ok, acc, -np.inf)
    return int(np.argmax(masked))


# --------------------------------------------------------------------------
# Policy layer: one object per strategy, one registry for all stacks
# --------------------------------------------------------------------------

@dataclass
class BatchSelection:
    """Vectorized selection over N requests (DESIGN.md §3).

    `probs`/`base`/`eligible` are populated only by probabilistic
    policies (cnnselect); deterministic baselines fill `indices` alone.
    """
    indices: np.ndarray                  # (N,) int
    probs: Optional[np.ndarray] = None   # (N, K)
    base: Optional[np.ndarray] = None    # (N,) stage-1 base models

    @property
    def eligible(self) -> Optional[np.ndarray]:
        """Exploration sets M_E as a bool (N, K) mask. Utilities are
        clamped to eps > 0 inside M_E, so the support of probs IS the
        exploration set — in both the numpy and the jnp implementation."""
        return None if self.probs is None else self.probs > 0.0


class Policy:
    """A model-selection strategy over a profile zoo.

    `select` answers one request; `select_batch` answers N at once (the
    simulator's hot path). The default `select_batch` is a python loop
    over `select`; policies with a vectorized form override it.
    """

    name: str = "policy"

    def select(self, profiles: Sequence[ModelProfile], t_sla: float,
               t_input: float, *, realized: Optional[np.ndarray] = None
               ) -> int:
        raise NotImplementedError

    def select_batch(self, profiles: Sequence[ModelProfile],
                     t_sla: np.ndarray, t_input: np.ndarray, *,
                     realized: Optional[np.ndarray] = None,
                     detail: bool = False
                     ) -> Union[np.ndarray, BatchSelection]:
        t_sla = np.broadcast_to(np.asarray(t_sla, np.float64),
                                np.shape(t_input))
        idx = np.array([
            self.select(profiles, float(t_sla[i]), float(t_input[i]),
                        realized=None if realized is None else realized[i])
            for i in range(len(t_input))], dtype=np.int64)
        return BatchSelection(idx) if detail else idx


class CNNSelectPolicy(Policy):
    """The paper's policy. Scalar path: numpy `cnnselect`. Batch path:
    the jit'd `cnnselect_batch` Gumbel-max kernel, called on fixed-size
    chunks so XLA compiles exactly one (chunk, K) program per zoo."""

    name = "cnnselect"

    def __init__(self, *, t_threshold: float = 50.0,
                 stage2_variant: str = "figure", seed: int = 0,
                 chunk: int = 2048):
        self.t_threshold = t_threshold
        self.stage2_variant = stage2_variant
        self.seed = seed
        self.chunk = chunk
        self.rng = np.random.default_rng(seed)
        self._key = None                     # lazy jax PRNGKey

    def select(self, profiles, t_sla, t_input, *, realized=None) -> int:
        r = cnnselect(profiles, t_sla, t_input, self.t_threshold, self.rng,
                      self.stage2_variant)
        return r.index

    def select_batch(self, profiles, t_sla, t_input, *, realized=None,
                     detail: bool = False):
        import jax

        if self._key is None:
            self._key = jax.random.PRNGKey(self.seed)
        mu = np.array([p.mu for p in profiles], np.float32)
        sg = np.array([p.sigma for p in profiles], np.float32)
        acc = np.array([p.accuracy for p in profiles], np.float32)
        t_input = np.asarray(t_input, np.float32)
        t_sla = np.broadcast_to(np.asarray(t_sla, np.float32),
                                t_input.shape)
        N, K = t_input.shape[0], mu.shape[0]
        fn = _jit_cnnselect_batch()
        idx = np.empty(N, np.int64)
        probs = np.empty((N, K), np.float64) if detail else None
        base = np.empty(N, np.int64) if detail else None
        for lo in range(0, N, self.chunk):
            hi = min(lo + self.chunk, N)
            n = hi - lo
            # Pad the tail chunk so every call shares one compiled shape.
            sla_c = np.resize(t_sla[lo:hi], self.chunk)
            tin_c = np.resize(t_input[lo:hi], self.chunk)
            self._key, sub = jax.random.split(self._key)
            sel_c, probs_c, base_c = fn(
                mu, sg, acc, sla_c, tin_c, self.t_threshold, sub,
                stage2_variant=self.stage2_variant)
            idx[lo:hi] = np.asarray(sel_c)[:n]
            if detail:
                probs[lo:hi] = np.asarray(probs_c)[:n]
                base[lo:hi] = np.asarray(base_c)[:n]
        return BatchSelection(idx, probs, base) if detail else idx


class GreedyPolicy(Policy):
    """Paper baseline; `use_network=True` is the greedy_nw variant that
    subtracts the observed 2*T_input from the budget."""

    def __init__(self, *, use_network: bool = False):
        self.use_network = use_network
        self.name = "greedy_nw" if use_network else "greedy"

    def select(self, profiles, t_sla, t_input, *, realized=None) -> int:
        return greedy_select(profiles, t_sla, t_input=t_input,
                             use_network=self.use_network)

    def select_batch(self, profiles, t_sla, t_input, *, realized=None,
                     detail: bool = False):
        acc = np.array([p.accuracy for p in profiles])
        mu = np.array([p.mu for p in profiles])
        t_input = np.asarray(t_input, np.float64)
        t_sla = np.broadcast_to(np.asarray(t_sla, np.float64),
                                t_input.shape)
        budget = network_budget(t_sla, t_input) if self.use_network \
            else t_sla
        ok = mu[None, :] <= budget[:, None]
        masked = np.where(ok, acc[None, :], -np.inf)
        idx = np.where(ok.any(axis=1), np.argmax(masked, axis=1),
                       np.argmin(mu))
        return BatchSelection(idx) if detail else idx


class RandomPolicy(Policy):
    name = "random"

    def __init__(self, *, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, profiles, t_sla, t_input, *, realized=None) -> int:
        return random_select(profiles, self.rng)

    def select_batch(self, profiles, t_sla, t_input, *, realized=None,
                     detail: bool = False):
        idx = self.rng.integers(len(profiles), size=len(t_input))
        return BatchSelection(idx) if detail else idx


class StaticPolicy(Policy):
    """Always the named model (the paper's per-model static baselines)."""

    def __init__(self, model_name: str):
        self.model_name = model_name
        self.name = f"static:{model_name}"

    def _index(self, profiles) -> int:
        names = [p.name for p in profiles]
        if self.model_name not in names:
            raise ValueError(f"static policy: model {self.model_name!r} "
                             f"not in zoo {names}")
        return names.index(self.model_name)

    def select(self, profiles, t_sla, t_input, *, realized=None) -> int:
        return self._index(profiles)

    def select_batch(self, profiles, t_sla, t_input, *, realized=None,
                     detail: bool = False):
        idx = np.full(len(t_input), self._index(profiles), np.int64)
        return BatchSelection(idx) if detail else idx


class OraclePolicy(Policy):
    """Upper bound: sees each request's realized execution times."""

    name = "oracle"

    def select(self, profiles, t_sla, t_input, *, realized=None) -> int:
        if realized is None:
            raise ValueError("oracle policy needs realized times")
        return oracle_select(profiles, t_sla, t_input, realized)

    def select_batch(self, profiles, t_sla, t_input, *, realized=None,
                     detail: bool = False):
        if realized is None:
            raise ValueError("oracle policy needs realized times")
        acc = np.array([p.accuracy for p in profiles])
        realized = np.asarray(realized, np.float64)           # (N, K)
        t_input = np.asarray(t_input, np.float64)
        t_sla = np.broadcast_to(np.asarray(t_sla, np.float64),
                                t_input.shape)
        ok = realized <= network_budget(t_sla, t_input)[:, None]
        masked = np.where(ok, acc[None, :], -np.inf)
        idx = np.where(ok.any(axis=1), np.argmax(masked, axis=1),
                       np.argmin(realized, axis=1))
        return BatchSelection(idx) if detail else idx


# --------------------------------------------------------------------------
# Control modes: the (policy, hedge, estimator) operating points the
# online control plane switches between (serving/control.py)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ControlMode:
    """One operating point of the serving control plane: which policy
    budgets, which estimator feeds the budget, and how aggressively the
    stack hedges/falls back. The `AdaptiveController`
    (serving/control.py) escalates through an *ordered* list of modes
    on detected network degradation and de-escalates on recovery.

    `policy=None` keeps the stack's base policy (only the budgeting
    side changes); `degraded=True` marks the mode as a degraded-regime
    operating point — requests served under it count as degraded for
    hedging/fallback purposes (the detector, not the crude
    `outage_factor` threshold, is the degradation signal)."""

    name: str
    policy: Optional[str] = None       # None = keep the base policy
    t_estimator: Optional[str] = None  # None = budget from observations
    hedge: str = "none"                # "none" | "p95" | "outage"
    degraded: bool = False
    on_device_fallback: bool = False


# Named modes the adaptive controller's tables reference. Ordered
# tables (configs/paper_zoo.CONTROLLER_SCENARIOS) list them least ->
# most conservative; the controller walks the list on alarms.
CONTROL_MODES: Dict[str, ControlMode] = {
    # Stationary operation: the paper's behaviour — budget from each
    # request's observed upload time — with the per-request outage
    # safety valve armed (spike-gated hedging/fallback for the
    # individual uploads whose estimated cloud path cannot meet the
    # SLA; `degraded=False`, so the gate is the outage_factor rule, not
    # the whole regime).
    "stationary": ControlMode(name="stationary", t_estimator=None,
                              hedge="outage", on_device_fallback=True),
    # Detected degradation: budget from a conservative rolling
    # percentile, hedge degraded requests, allow on-device fallback.
    "degraded": ControlMode(name="degraded", t_estimator="pctl:90",
                            hedge="outage", degraded=True,
                            on_device_fallback=True),
    # Conservative stationary variant (slow-reacting estimator).
    "cautious": ControlMode(name="cautious", t_estimator="pctl:75",
                            degraded=False),
}


def mode_names() -> List[str]:
    return sorted(CONTROL_MODES)


def make_mode(spec: Union[str, ControlMode]) -> ControlMode:
    """Resolve a control-mode spec (a `CONTROL_MODES` name or an
    already-built `ControlMode`)."""
    if isinstance(spec, ControlMode):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"unknown control mode {spec!r}; known: "
                         f"{', '.join(mode_names())}")
    head, _ = parse_spec(spec, kind="control mode", heads=CONTROL_MODES,
                         known=mode_names())
    return CONTROL_MODES[head]


# Name -> factory(arg, **options). `arg` is the text after ":" in specs
# like "static:<model>"; options are the shared policy knobs.
POLICY_REGISTRY: Dict[str, Callable[..., Policy]] = {
    "cnnselect": lambda arg, **kw: CNNSelectPolicy(
        t_threshold=kw["t_threshold"], stage2_variant=kw["stage2_variant"],
        seed=kw["seed"], chunk=kw["chunk"]),
    "greedy": lambda arg, **kw: GreedyPolicy(use_network=False),
    "greedy_nw": lambda arg, **kw: GreedyPolicy(use_network=True),
    "random": lambda arg, **kw: RandomPolicy(seed=kw["seed"]),
    "static": lambda arg, **kw: StaticPolicy(arg),
    "oracle": lambda arg, **kw: OraclePolicy(),
}


def policy_names() -> List[str]:
    return list(POLICY_REGISTRY)


def make_policy(spec: Union[str, Policy], *, t_threshold: float = 50.0,
                stage2_variant: str = "figure", seed: int = 0,
                chunk: int = 2048) -> Policy:
    """Resolve a policy spec ("cnnselect", "greedy", "static:<name>", or
    an already-built Policy) to a Policy instance."""
    if isinstance(spec, Policy):
        return spec
    head, arg = parse_spec(spec, kind="policy", heads=POLICY_REGISTRY,
                           known=policy_names(),
                           arg_heads=("static",),
                           required_arg_heads=("static",),
                           arg_desc={"static": ("model name", "name")})
    return POLICY_REGISTRY[head](arg, t_threshold=t_threshold,
                                 stage2_variant=stage2_variant, seed=seed,
                                 chunk=chunk)
