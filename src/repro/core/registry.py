"""Shared ``kind:arg`` spec parsing for every name-resolved factory.

The repo resolves pluggable components by short string specs — policies
("cnnselect", "static:<name>"), T_input estimators ("ewma:0.3"),
networks ("lte", "trace:diurnal"), control modes, change-point
detectors. Pre-refactor each factory re-implemented the same partition
/ validate / raise sequence with its own error phrasing, so a typo'd
spec surfaced differently depending on which subsystem it reached.
`parse_spec` is the one copy: every factory raises the same
registry-style `ValueError` naming the kind, the offending spec, and
every valid form.

Error contract (pinned by the factory test suites):

- unknown head   -> ``unknown <kind> <spec>; known: <names>``
- stray argument -> ``<kind> <head> takes no ':<arg>' argument; known: …``
- missing argument (heads in `required_arg_heads`)
                 -> ``<kind> <head> needs a <desc>: '<head>:<ph>'``
- non-numeric argument (heads in `numeric_arg_heads`)
                 -> ``<kind> <head> takes a numeric argument, got
                    <spec>; known: …``
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

__all__ = ["parse_spec"]


def parse_spec(spec: str, *, kind: str, heads: Iterable[str],
               known: Optional[Sequence[str]] = None,
               arg_heads: Sequence[str] = (),
               required_arg_heads: Sequence[str] = (),
               numeric_arg_heads: Sequence[str] = (),
               arg_desc: Optional[Dict[str, Tuple[str, str]]] = None
               ) -> Tuple[str, str]:
    """Parse and validate a ``head[:arg]`` spec against a registry.

    `heads` is the set of resolvable heads; `known` the human-facing
    list for error text (defaults to `heads` in iteration order, so a
    dict registry lists its declaration order). `arg_heads` may carry a
    ``:<arg>``, `required_arg_heads` must, `numeric_arg_heads` must
    parse as float. `arg_desc` maps a required head to its
    ``(description, placeholder)`` for the missing-argument message,
    e.g. ``{"static": ("model name", "name")}``. Returns ``(head,
    arg)`` with ``arg == ""`` when absent.
    """
    head, _, arg = spec.partition(":")
    head_set = set(heads)
    names = ", ".join(known if known is not None else heads)
    if head not in head_set:
        raise ValueError(f"unknown {kind} {spec!r}; known: {names}")
    if arg and head not in arg_heads:
        raise ValueError(f"{kind} {head!r} takes no ':{arg}' argument; "
                         f"known: {names}")
    if not arg and head in required_arg_heads:
        desc, ph = (arg_desc or {}).get(head, ("argument", "arg"))
        raise ValueError(f"{kind} {head!r} needs a {desc}: "
                         f"'{head}:<{ph}>'")
    if arg and head in numeric_arg_heads:
        try:
            float(arg)
        except ValueError:
            raise ValueError(
                f"{kind} {head!r} takes a numeric argument, got "
                f"{spec!r}; known: {names}") from None
    return head, arg
