"""The paper's primary contribution: CNNSelect — SLA-aware probabilistic
model selection over a zoo of models with (accuracy, mu, sigma) profiles
— plus the greedy/static/random/oracle baselines it is evaluated against,
online performance profiling, and cold/hot model lifecycle management.
"""

from repro.core.selection import (
    BatchSelection,
    CNNSelectPolicy,
    GreedyPolicy,
    ModelProfile,
    OraclePolicy,
    Policy,
    RandomPolicy,
    SelectionResult,
    StaticPolicy,
    cnnselect,
    cnnselect_batch,
    greedy_select,
    make_policy,
    policy_names,
    static_select,
    random_select,
    oracle_select,
)
from repro.core.profiles import OnlineProfile, ProfileStore
from repro.core.zoo import ModelZoo, ZooEntry

__all__ = [
    "ModelProfile", "SelectionResult", "cnnselect", "cnnselect_batch",
    "greedy_select", "static_select", "random_select", "oracle_select",
    "Policy", "BatchSelection", "CNNSelectPolicy", "GreedyPolicy",
    "RandomPolicy", "StaticPolicy", "OraclePolicy", "make_policy",
    "policy_names",
    "OnlineProfile", "ProfileStore", "ModelZoo", "ZooEntry",
]
