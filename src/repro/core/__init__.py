"""The paper's primary contribution: CNNSelect — SLA-aware probabilistic
model selection over a zoo of models with (accuracy, mu, sigma) profiles
— plus the greedy/static/random/oracle baselines it is evaluated against,
online performance profiling, and cold/hot model lifecycle management.
"""

from repro.core.selection import (
    ModelProfile,
    SelectionResult,
    cnnselect,
    cnnselect_batch,
    greedy_select,
    static_select,
    random_select,
    oracle_select,
)
from repro.core.profiles import OnlineProfile, ProfileStore
from repro.core.zoo import ModelZoo, ZooEntry

__all__ = [
    "ModelProfile", "SelectionResult", "cnnselect", "cnnselect_batch",
    "greedy_select", "static_select", "random_select", "oracle_select",
    "OnlineProfile", "ProfileStore", "ModelZoo", "ZooEntry",
]
