"""Online model performance profiles (paper: "CNN model performance
profiles are measured and managed by individual inference servers").
One `ProfileStore` per admission `Router` (serving/router.py) — the
stacks feed measured latencies back through `Router.record` and every
policy decision reads the blended view via `Router.current_profiles`.

Welford's algorithm for numerically stable streaming mean/std, plus a
staleness clock: `T_threshold` grows with profile staleness when the
optional `threshold_mode="staleness"` extension is enabled (the paper
defers dynamic adjustment to future work — flagged in DESIGN.md §8)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass
class OnlineProfile:
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    last_update: float = 0.0   # wall-ish clock supplied by caller

    def update(self, x: float, now: float = 0.0):
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self.m2 += delta * (x - self.mean)
        self.last_update = now

    @property
    def var(self) -> float:
        return self.m2 / (self.n - 1) if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return float(np.sqrt(self.var))

    def staleness(self, now: float) -> float:
        return max(0.0, now - self.last_update)


class ProfileStore:
    """Per-model hot/cold latency profiles with priors.

    Priors let the store answer before any measurement (seeded from the
    dry-run roofline estimates for the LM zoo, or from paper Table 5 for
    the CNN zoo)."""

    def __init__(self):
        self._hot: Dict[str, OnlineProfile] = {}
        self._cold: Dict[str, OnlineProfile] = {}
        self._prior: Dict[str, tuple] = {}

    def set_prior(self, name: str, mu: float, sigma: float,
                  cold_mu: float = 0.0, cold_sigma: float = 0.0):
        self._prior[name] = (mu, sigma, cold_mu, cold_sigma)

    def record(self, name: str, latency: float, *, cold: bool = False,
               now: float = 0.0):
        store = self._cold if cold else self._hot
        store.setdefault(name, OnlineProfile()).update(latency, now)

    def mu_sigma(self, name: str, *, cold: bool = False,
                 min_obs: int = 5) -> tuple:
        """Blend prior with observations until min_obs measurements."""
        store = self._cold if cold else self._hot
        prior = self._prior.get(name)
        obs = store.get(name)
        if obs is None or obs.n == 0:
            if prior is None:
                raise KeyError(f"no profile or prior for {name!r}")
            return (prior[2], prior[3]) if cold else (prior[0], prior[1])
        if obs.n >= min_obs or prior is None:
            return obs.mean, obs.std
        w = obs.n / min_obs
        pm, ps = (prior[2], prior[3]) if cold else (prior[0], prior[1])
        return (w * obs.mean + (1 - w) * pm, w * obs.std + (1 - w) * ps)

    def staleness(self, name: str, now: float) -> float:
        obs = self._hot.get(name)
        return obs.staleness(now) if obs else float("inf")

    def dynamic_threshold(self, names, now: float, *, base: float,
                          t_device: float, rate: float = 0.01) -> float:
        """Optional extension: grow T_threshold with the max staleness of
        the managed profiles, bounded by [0, T_D] per the paper."""
        stale = max((min(self.staleness(n, now), 1e6) for n in names),
                    default=0.0)
        return float(np.clip(base + rate * stale, 0.0, t_device))
