"""One-call process configuration for jax: platform, x64, host devices.

jax reads ``XLA_FLAGS`` and most of ``jax.config`` exactly once — when
the backend is first initialised (the first ``jax.devices()`` /
``jnp.asarray`` / jit trace).  Setting them later silently does nothing
(or raises deep inside XLA), which is how "works on my machine, single
device in CI" bugs are born.  :func:`configure` centralises the dance:
call it once at process start, *before anything touches jax*, and it
either applies the settings or fails loudly explaining why it cannot.

Typical entry-point usage::

    from repro.utils.config import configure
    configure(platform="cpu", x64=False, host_devices=8)
    import jax  # safe either way; jax must not be *initialised* yet

Tests opt in via the ``REPRO_HOST_DEVICES`` env var (see
``tests/conftest.py``): CI runs the engine suite once with
``REPRO_HOST_DEVICES=4`` so the shard_map path is exercised on plain
CPU runners.
"""

from __future__ import annotations

import os
import sys
from typing import Optional

__all__ = ["configure", "jax_is_initialized", "host_device_count"]

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def jax_is_initialized() -> bool:
    """True if jax has already created a backend (config is frozen)."""
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return xla_bridge.backends_are_initialized()
    except Exception:  # pragma: no cover - very old/new jax layouts
        jax = sys.modules["jax"]
        try:
            return bool(getattr(jax.lib.xla_bridge, "_backends", None))
        except Exception:
            return False


def host_device_count() -> Optional[int]:
    """The ``--xla_force_host_platform_device_count`` currently in
    ``XLA_FLAGS``, or None if the flag is absent."""
    flags = os.environ.get("XLA_FLAGS", "")
    for tok in flags.split():
        if tok.startswith(_DEVICE_FLAG + "="):
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return None
    return None


def _set_device_flag(n: int) -> None:
    flags = [tok for tok in os.environ.get("XLA_FLAGS", "").split()
             if not tok.startswith(_DEVICE_FLAG + "=")]
    flags.append(f"{_DEVICE_FLAG}={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def configure(platform: Optional[str] = None,
              x64: Optional[bool] = None,
              host_devices: Optional[int] = None) -> None:
    """Configure the jax runtime for this process, before first use.

    Parameters
    ----------
    platform:
        "cpu", "gpu", or "tpu" — pins ``jax_platform_name`` so the
        process cannot silently fall back to a different backend.
    x64:
        Flip the *global* default float width.  Prefer the scoped
        ``jax.experimental.enable_x64()`` context inside library code
        (the scan engine does exactly that); the global switch is for
        benchmark / CLI entry points that own the whole process.
    host_devices:
        Present ``N`` fake host devices on CPU via
        ``--xla_force_host_platform_device_count=N`` so shard_map /
        mesh code paths run multi-device on machines without
        accelerators.

    Raises
    ------
    RuntimeError
        If jax has already initialised its backends — at that point
        ``host_devices`` / ``platform`` cannot take effect, and
        failing loudly beats a simulator that silently runs on one
        device.
    """
    if platform is None and x64 is None and host_devices is None:
        return
    if jax_is_initialized():
        if host_devices is not None and host_device_count() == host_devices:
            # Idempotent re-call with the same topology: harmless.
            host_devices = None
        if host_devices is not None or platform is not None:
            raise RuntimeError(
                "repro.utils.config.configure() called after jax was "
                "initialised — XLA_FLAGS/platform changes can no longer "
                "take effect. Call configure() at process start, before "
                "importing modules that build jax arrays.")

    if host_devices is not None:
        if host_devices < 1:
            raise ValueError(f"host_devices must be >= 1, got {host_devices}")
        _set_device_flag(host_devices)

    import jax  # deferred: XLA_FLAGS must be in the env first

    if platform is not None:
        jax.config.update("jax_platform_name", platform)
    if x64 is not None:
        jax.config.update("jax_enable_x64", bool(x64))
