"""Small shared utilities: dtypes, pytree helpers, counting."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.config import configure, host_device_count

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int8": jnp.int8,
    "int32": jnp.int32,
}


def dtype_of(name: str):
    return DTYPES[name]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` across jax versions: older releases expose it as
    `jax.experimental.shard_map.shard_map` with the replication check
    named `check_rep` instead of `check_vma` (same meaning)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStructs too)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_allfinite(tree) -> bool:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return True
    return bool(jnp.all(jnp.stack(leaves)))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"
