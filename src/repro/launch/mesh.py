"""Mesh construction. A FUNCTION (not module-level constant) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Assignment mesh: 16x16 single pod (256 chips) or 2x16x16 (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh from the first prod(shape) available devices
    (used by reduced-device tests, e.g. 8 host devices -> (2,2,2))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
