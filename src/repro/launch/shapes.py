"""Assigned input shapes and per-(arch x shape) abstract input specs.

Shapes (assignment):
  train_4k     seq=4096   global_batch=256   (training step)
  prefill_32k  seq=32768  global_batch=32    (inference prefill)
  decode_32k   seq=32768  global_batch=128   (one decode token, 32k KV)
  long_500k    seq=524288 global_batch=1     (long-context decode; only
               sub-quadratic archs — SSM/hybrid — run it)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.utils import dtype_of

SHAPE_DEFS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

SHAPE_NAMES = tuple(SHAPE_DEFS)


def cell_runnable(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k requires a sub-quadratic arch (no full-attention blocks)."""
    if shape_name == "long_500k":
        return cfg.is_subquadratic
    return True


def skip_reason(cfg: ModelConfig, shape_name: str) -> str:
    return (f"{cfg.name} contains full (unwindowed) attention layers; "
            f"long_500k requires sub-quadratic context handling "
            f"(DESIGN.md long_500k skips)")


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends ([audio]/[vlm]) are stubs: inputs are precomputed
    frame/patch embeddings (B, T, d_model) instead of int tokens.
    """
    d = SHAPE_DEFS[shape_name]
    B, S = d["batch"], d["seq"]
    cdt = dtype_of(cfg.compute_dtype)
    tok = jnp.int32
    if d["kind"] == "train":
        if cfg.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), tok)
        return {"kind": "train",
                "batch": {"inputs": inputs,
                          "labels": jax.ShapeDtypeStruct((B, S), tok)}}
    if d["kind"] == "prefill":
        if cfg.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), tok)
        return {"kind": "prefill", "inputs": inputs, "max_seq": S}
    # decode: one new token with a KV cache of S.
    if cfg.input_mode == "embeddings":
        token = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cdt)
    else:
        token = jax.ShapeDtypeStruct((B, 1), tok)
    return {"kind": "decode", "token": token, "batch": B, "max_seq": S,
            "cache_pos": jax.ShapeDtypeStruct((), jnp.int32)}
