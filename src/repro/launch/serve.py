"""Serving launcher: a CNNSelect-fronted multi-model server over real
engines, driven by a synthetic request stream.

    PYTHONPATH=src python -m repro.launch.serve --requests 40 --sla 200 \
        --network campus_wifi --policy cnnselect
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.selection import make_policy, policy_names
from repro.models import init_params
from repro.serving.batching import Request
from repro.serving.engine import InferenceEngine
from repro.serving.network import make_network
from repro.serving.server import CNNSelectServer, ServedModel


def build_default_zoo():
    """Three reduced engines spanning a latency/accuracy frontier."""
    base = reduced_config("stablelm_1_6b")
    tiers = [
        ("xs", dict(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                    head_dim=16, d_ff=64), 0.50),
        ("s", dict(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                   head_dim=16, d_ff=128), 0.72),
        ("m", dict(n_layers=6, d_model=160, n_heads=8, n_kv_heads=8,
                   head_dim=20, d_ff=320), 0.90),
    ]
    models = []
    for name, kw, acc in tiers:
        cfg = dataclasses.replace(base, **kw)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
        models.append(ServedModel(name=name, engine=eng, accuracy=acc))
    return models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--sla", type=float, default=250.0)
    ap.add_argument("--network", default="campus_wifi")
    ap.add_argument("--policy", default="cnnselect",
                    help="registry spec: one of %s, or static:<name>"
                    % ", ".join(policy_names()))
    ap.add_argument("--t-threshold", type=float, default=30.0)
    ap.add_argument("--n-tokens", type=int, default=6)
    args = ap.parse_args()

    # Resolve the policy before paying engine-compile time so a bad
    # spec fails immediately.
    policy = make_policy(args.policy, t_threshold=args.t_threshold)
    srv = CNNSelectServer(build_default_zoo(), t_threshold=args.t_threshold,
                          policy=policy, n_tokens=args.n_tokens)
    print("profiling zoo...", flush=True)
    srv.profile_models(prompt_len=8, reps=5)
    for p in srv.current_profiles():
        print(f"  {p.name}: mu={p.mu:.1f}ms sigma={p.sigma:.1f} "
              f"acc={p.accuracy:.2f}")

    net = make_network(args.network)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        req = Request(arrival=0.0, rid=i,
                      prompt=rng.integers(0, 50, 8).astype(np.int32),
                      t_input_ms=float(net.sample_t_input(rng, 1)[0]))
        rec = srv.handle(req, t_sla=args.sla)
        if i < 5 or (i + 1) % 10 == 0:
            print(f"req {i:3d}: model={rec['model']:3s} "
                  f"e2e={rec['e2e_ms']:7.1f}ms ok={rec['ok']}")
    print("\nsummary:", json.dumps(srv.metrics.summary(), indent=1))


if __name__ == "__main__":
    main()
