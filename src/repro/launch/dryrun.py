import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Test hook: reduced device count must be set BEFORE jax initializes.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
derive the three roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod multipod

Results land in benchmarks/results/<arch>_<shape>_<mesh>_<tag>.json and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ALIASES, get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh, make_mesh
from repro.launch.shapes import SHAPE_DEFS, SHAPE_NAMES, cell_runnable, \
    input_specs, skip_reason
from repro.models import decode_step, param_logical_axes, cache_logical_axes
from repro.models.model import prefill, abstract_cache
from repro.models.params import abstract_params
from repro.sharding import (ParallelConfig, make_parallel, moe_mode_for,
                            tree_specs, tree_shardings)
from repro.training.optim import adamw, adafactor, cosine_schedule, \
    mixed_precision
from repro.training.step import (make_train_step, abstract_train_state,
                                 train_state_logical_axes)

# TPU v5e hardware model (assignment constants).
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

BIG_PARAM_THRESHOLD = 5e10  # adafactor above this (Adam state won't fit)


def runtime_config(cfg, kind: str, overrides: dict):
    kw = dict(compute_dtype="bfloat16", attn_chunk=512)
    if kind == "train":
        # bf16 live params (fp32 master in opt state: gradients are born
        # bf16 so DP reductions move half the bytes). naive attention +
        # remat: the (T,S) logits are transient and recomputed in backward
        # — differentiating the double-scan flash path would store
        # per-chunk carries instead (measured blow-up).
        kw.update(param_dtype="bfloat16", remat="block", attn_impl="naive")
    else:
        # q-chunk 2048: flash K-streaming traffic scales with S^2/chunk_q
        # (-17% on the prefill memory term; 4096 gave <5% more — §Perf).
        kw.update(param_dtype="bfloat16", remat="none",
                  attn_impl="jax_chunked", attn_chunk=2048)
    import dataclasses as _dc
    fields = {f.name for f in _dc.fields(cfg)}
    kw.update({k: v for k, v in overrides.items()
               if v is not None and k in fields})
    return cfg.with_runtime(**kw)


def act_batch_axes(parallel, batch: int):
    sizes = 1
    for a in parallel.data_axes:
        sizes *= parallel.mesh.shape[a]
    return parallel.data_axes if batch % sizes == 0 else None


def build_cell(cfg, shape_name: str, mesh, overrides: dict):
    """Returns (jit_fn, abstract_args, info)."""
    spec = input_specs(runtime_config(cfg, "probe", {}), shape_name)
    kind = spec["kind"]
    cfg = runtime_config(cfg, kind, overrides)
    spec = input_specs(cfg, shape_name)
    profile = "train" if kind == "train" else "serve"
    # Decode defaults to the weight-resident 2d MoE layouts: moving the
    # per-step activations (KBs) beats re-gathering expert weights (GBs)
    # every token (§Perf iteration 2).
    default_moe = "auto2d" if kind == "decode" else "auto"
    parallel = make_parallel(mesh, profile,
                             seq_shard=overrides.get("seq_shard"),
                             moe_mode=overrides.get("moe_mode") or default_moe,
                             attn_pin=bool(overrides.get("attn_pin")),
                             # carry-mode SP: -11% collective on the SSM
                             # family but +42 GB peak (replicated x live
                             # during backward) — rejected on memory fit;
                             # refuted outright on dense/MoE (§Perf).
                             seq_mode=overrides.get("seq_mode") or "full")
    info = {"profile": profile,
            "moe_mode": moe_mode_for(cfg, parallel) if cfg.moe else None,
            "seq_shard": parallel.seq_shard,
            "attn_pin": parallel.attn_pin}

    if kind == "train":
        opt_name = overrides.get("optimizer") or (
            "adafactor" if cfg.param_count() > BIG_PARAM_THRESHOLD
            else "adamw")
        sched = cosine_schedule(3e-4, 1000, 100000)
        opt = adafactor(sched) if opt_name == "adafactor" else adamw(sched)
        opt = mixed_precision(opt)
        info["optimizer"] = opt_name + "+mp"
        step_fn = make_train_step(cfg, opt, parallel)
        state_abs = abstract_train_state(cfg, opt)
        st_specs = tree_specs(train_state_logical_axes(cfg, opt), parallel, cfg)
        st_sh = tree_shardings(st_specs, mesh)
        baxes = act_batch_axes(parallel, SHAPE_DEFS[shape_name]["batch"])
        b_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(baxes, *([None] * (len(s.shape) - 1)))),
            spec["batch"])
        fn = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        return fn, (state_abs, spec["batch"]), info

    # serve profiles
    p_specs = tree_specs(param_logical_axes(cfg), parallel, cfg)
    p_sh = tree_shardings(p_specs, mesh)
    params_abs = abstract_params(cfg)
    B = SHAPE_DEFS[shape_name]["batch"]
    baxes = act_batch_axes(parallel, B)
    vocab_ax = "model" if cfg.padded_vocab % mesh.shape["model"] == 0 else None
    lg_sh = NamedSharding(mesh, P(baxes, None, vocab_ax))

    if kind == "prefill":
        S = spec["max_seq"]

        def prefill_fn(params, inputs):
            return prefill(params, inputs, cfg, max_seq=S, parallel=parallel,
                           logits_last_only=True)

        c_specs = tree_specs(cache_logical_axes(cfg), parallel, cfg)
        c_specs = _fix_cache_batch(c_specs, baxes)
        c_sh = tree_shardings(c_specs, mesh)
        in_sh = NamedSharding(mesh, P(baxes, *([None] * (len(spec["inputs"].shape) - 1))))
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, in_sh),
                     out_shardings=(lg_sh, c_sh))
        return fn, (params_abs, spec["inputs"]), info

    # decode
    S = spec["max_seq"]

    def decode_fn(params, token, cache, cache_pos):
        return decode_step(params, token, cache, cache_pos, cfg,
                           parallel=parallel)

    cache_abs = abstract_cache(cfg, B, S)
    c_specs = tree_specs(cache_logical_axes(cfg), parallel, cfg)
    c_specs = _fix_cache_batch(c_specs, baxes)
    c_sh = tree_shardings(c_specs, mesh)
    t_sh = NamedSharding(mesh, P(baxes, *([None] * (len(spec["token"].shape) - 1))))
    pos_sh = NamedSharding(mesh, P())
    fn = jax.jit(decode_fn, in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                 out_shardings=(lg_sh, c_sh), donate_argnums=(2,))
    return fn, (params_abs, spec["token"], cache_abs, spec["cache_pos"]), info


def _fix_cache_batch(c_specs, baxes):
    """Cache specs put cache_batch on the data axes; when the global batch
    does not divide them (long_500k B=1) fall back to replicated batch.
    The batch dim may sit at any position (stacked leaves lead with the
    layers dim), so strip data axes wherever they appear."""
    if baxes is not None:
        return c_specs
    data_like = {"data", "pod"}

    def strip(e):
        if e in data_like:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a not in data_like)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return e

    def fix(s):
        if isinstance(s, P):
            return P(*[strip(e) for e in s])
        return s
    return jax.tree.map(fix, c_specs, is_leaf=lambda x: isinstance(x, P))


def model_flops(cfg, shape_name: str) -> float:
    d = SHAPE_DEFS[shape_name]
    n = cfg.active_param_count()
    if d["kind"] == "train":
        return 6.0 * n * d["batch"] * d["seq"]
    if d["kind"] == "prefill":
        return 2.0 * n * d["batch"] * d["seq"]
    return 2.0 * n * d["batch"]  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             overrides: dict, out_dir: str, tag: str, force: bool) -> dict:
    cfg0 = get_config(arch)
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{cfg0.name.replace('/', '_')}_{shape_name}_{mesh_name}_{tag}.json"
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if not cell_runnable(cfg0, shape_name):
        res = {"arch": cfg0.name, "shape": shape_name, "mesh": mesh_name,
               "skipped": True, "reason": skip_reason(cfg0, shape_name)}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"[dryrun] SKIP {cfg0.name} x {shape_name}: sub-quadratic "
              f"requirement", flush=True)
        return res

    print(f"[dryrun] {cfg0.name} x {shape_name} x {mesh_name} "
          f"(devices={mesh.devices.size})", flush=True)
    t0 = time.time()
    fn, args, info = build_cell(cfg0, shape_name, mesh, overrides)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    ma = compiled.memory_analysis()
    print("  memory_analysis:", ma, flush=True)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: list of one dict
        ca = ca[0] if ca else {}
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (
        ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)), flush=True)
    hlo = analyze(compiled.as_text())

    chips = mesh.devices.size
    mf = model_flops(run_cfg(cfg0, shape_name, overrides), shape_name)
    compute_s = hlo["dot_flops"] / PEAK_FLOPS
    memory_s = hlo["traffic_bytes"] / HBM_BW
    coll_s = hlo["collective_traffic_total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    hlo_global_flops = hlo["dot_flops"] * chips
    res = {
        "arch": cfg0.name, "shape": shape_name, "mesh": mesh_name,
        "devices": chips, "kind": SHAPE_DEFS[shape_name]["kind"],
        "skipped": False, "tag": tag, "info": info,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed", 0.0)},
        "hlo": hlo,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_global_flops) if hlo_global_flops else 0.0,
        "terms": terms,
        "dominant": dominant,
        "step_time_est_s": max(terms.values()),
        "params": cfg0.param_count(),
        "active_params": cfg0.active_param_count(),
    }
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print(f"  terms: compute={compute_s:.4f}s memory={memory_s:.4f}s "
          f"collective={coll_s:.4f}s dominant={dominant} "
          f"useful_ratio={res['useful_flops_ratio']:.3f} "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return res


def run_cfg(cfg, shape_name, overrides):
    kind = SHAPE_DEFS[shape_name]["kind"]
    return runtime_config(cfg, kind, overrides)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable); default: all")
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(SHAPE_NAMES))
    ap.add_argument("--mesh", nargs="+", default=["pod"],
                    choices=["pod", "multipod", "custom"])
    ap.add_argument("--mesh-shape", default=None,
                    help="custom mesh, e.g. 2,4 (test mode)")
    ap.add_argument("--mesh-axes", default=None, help="e.g. data,model")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    # hillclimb overrides
    ap.add_argument("--seq-shard", default=None, choices=["on", "off"])
    ap.add_argument("--moe-mode", default=None, choices=["ep", "tp", "ep2d", "tp2d"])
    ap.add_argument("--optimizer", default=None,
                    choices=["adamw", "adafactor"])
    ap.add_argument("--remat", default=None,
                    choices=["none", "block", "moe_save"])
    ap.add_argument("--attn-pin", default=None, choices=["on", "off"])
    ap.add_argument("--seq-mode", default=None, choices=["full", "carry"])
    ap.add_argument("--attn-impl", default=None,
                    choices=["naive", "jax_chunked"])
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--compute-dtype", default=None)
    args = ap.parse_args()

    try:
        jax.config.update("jax_compilation_cache_dir",
                          os.path.abspath(".jax_cache"))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 5.0)
    except Exception:
        pass

    overrides = {
        "seq_shard": None if args.seq_shard is None else args.seq_shard == "on",
        "moe_mode": args.moe_mode,
        "optimizer": args.optimizer,
        "attn_impl": args.attn_impl,
        "remat": args.remat,
        "attn_pin": None if args.attn_pin is None else args.attn_pin == "on",
        "seq_mode": args.seq_mode,
        "attn_chunk": args.attn_chunk,
        "compute_dtype": args.compute_dtype,
    }
    archs = args.arch or ARCH_IDS
    shapes = args.shape or list(SHAPE_NAMES)

    failures = []
    for mesh_name in args.mesh:
        if mesh_name == "pod":
            mesh = make_production_mesh(multi_pod=False)
        elif mesh_name == "multipod":
            mesh = make_production_mesh(multi_pod=True)
        else:
            shape = tuple(int(x) for x in args.mesh_shape.split(","))
            axes = tuple(args.mesh_axes.split(","))
            mesh = make_mesh(shape, axes)
        for arch in archs:
            for shp in shapes:
                try:
                    run_cell(arch, shp, mesh, mesh_name, overrides,
                             args.out, args.tag, args.force)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shp, mesh_name, str(e)[:200]))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:", flush=True)
        for f in failures:
            print("   ", f, flush=True)
        sys.exit(1)
    print("[dryrun] all requested cells OK", flush=True)


if __name__ == "__main__":
    main()
