"""Post-SPMD HLO text analysis for the roofline.

`compiled.cost_analysis()` counts while-loop bodies ONCE (verified
empirically on this jax/XLA build), so scanned-layer programs would be
under-counted by ~n_layers. This module parses `compiled.as_text()`
itself and multiplies every computation's costs by the product of
enclosing while-loop trip counts (XLA annotates
`known_trip_count={"n":...}` after compilation).

Outputs (all PER DEVICE — the SPMD module is the per-device program):
  - dot_flops: 2*M*N*K over all dot ops (MXU work; elementwise VPU work
    excluded by design, stated in EXPERIMENTS.md)
  - traffic_bytes: operand+output bytes of top-level fusion/dot/scatter/
    gather/... ops — an HBM traffic model (fusions are XLA's units of
    memory residency)
  - collective_traffic: per-kind bytes with a ring-traffic model
    (AR: 2x operand, AG: output, RS: operand, A2A/CP: operand)
  - collective_operand_bytes: the assignment's literal "sum of operand
    sizes" number, reported alongside
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# HBM-traffic-relevant top-level opcodes (fusion bodies are on-chip).
TRAFFIC_OPS = {"fusion", "dot", "scatter", "gather", "dynamic-slice",
               "dynamic-update-slice", "reduce", "reduce-window",
               "select-and-scatter", "convolution", "concatenate",
               "slice", "pad", "sort"} | set(COLLECTIVES)

# Pure layout/dtype plumbing: free on TPU (folded into surrounding ops) or
# CPU-backend artifacts (bf16<->f32 converts around dots).
LAYOUT_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "copy", "convert", "transpose", "reshape",
              "broadcast", "iota", "select", "compare", "slice", "pad"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.+)$")
# Result type: tuple "(...)" (no nested parens in HLO types; may contain
# /*index=k*/ comments) or plain "dtype[dims]{layout}".
_OP_RE = re.compile(r"^(?P<type>\([^()]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
                    r"(?P<op>[\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(")


def shape_bytes(type_str: str, cap_elem_bytes: int = 0) -> int:
    """Bytes of a type. cap_elem_bytes>0 caps the element width — used to
    model TPU-width (bf16) traffic when XLA-CPU upcasts dot inputs to f32
    (the CPU backend has no bf16 ALU; those converts and f32 shadow
    buffers would not exist on the TPU target)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        eb = DTYPE_BYTES[dt]
        if cap_elem_bytes and eb > cap_elem_bytes and dt.startswith(("f", "bf")):
            eb = cap_elem_bytes
        total += n * eb
    return total


def shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


@dataclass
class Instr:
    name: str
    op: str
    type_str: str
    arg_str: str       # inside the parens
    attr_str: str      # after the closing paren
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> type_str


def _split_args(rest: str) -> Tuple[str, str]:
    """Split 'op(args...), attrs' at the matching close paren."""
    i = rest.find("(")
    depth = 0
    for j in range(i, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                return rest[i + 1:j], rest[j + 1:]
    return rest[i + 1:], ""


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group("name"))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        om = _OP_RE.match(rest)
        if not om:
            continue
        type_str, op = om.group("type"), om.group("op")
        arg_str, attr_str = _split_args(rest[om.start(2):])
        ins = Instr(m.group("name"), op, type_str, arg_str, attr_str)
        ins.operands = re.findall(r"%([\w.\-]+)", arg_str)
        cur.instrs.append(ins)
        cur.table[ins.name] = type_str
    return comps, entry


def while_multipliers(comps: Dict[str, Computation], entry: str,
                      default_trip: int = 1) -> Dict[str, float]:
    """Multiplier per computation = product of enclosing while trip counts."""
    mult: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        m = mult.get(cname, 1.0)
        for ins in comps[cname].instrs:
            children = re.findall(
                r"(?:body|condition|to_apply|calls)=\{?%?([\w.\-]+)",
                ins.attr_str)
            # fusion/call instructions may list calls={%a, %b}
            child_m = m
            if ins.op == "while":
                tm = re.search(r'known_trip_count[^0-9]*?(\d+)', ins.attr_str)
                trip = int(tm.group(1)) if tm else default_trip
                child_m = m * trip
            for ch in children:
                mult[ch] = max(mult.get(ch, 0.0), child_m)
                stack.append(ch)
    return mult


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    total = 0
    for op_name in ins.operands:
        t = comp.table.get(op_name)
        if t:
            total += shape_bytes(t)
    return total


def _nth_operand_bytes(comp: Computation, ins: Instr, n: int,
                       cap: int = 0) -> int:
    if n < len(ins.operands):
        t = comp.table.get(ins.operands[n])
        if t:
            return shape_bytes(t, cap)
    return 0


def _operand_bytes_capped(comp: Computation, ins: Instr, cap: int) -> int:
    total = 0
    for op_name in ins.operands:
        t = comp.table.get(op_name)
        if t:
            total += shape_bytes(t, cap)
    return total


def _instr_traffic(comp: Computation, ins: Instr,
                   comps: Dict[str, Computation], cap: int) -> float:
    """HBM traffic model for one top-level instruction (TPU-width capped).

    In-place ops (dynamic-update-slice, scatter) move only the update
    region; slices/gathers only the extracted region. Fusions: bodies
    with in-place updates move 2x the update sizes (XLA aliases the big
    target); layout-only fusions are free; arithmetic fusions move
    operands + outputs."""
    op = ins.op
    if op == "dynamic-update-slice":
        return 2.0 * _nth_operand_bytes(comp, ins, 1, cap)
    if op in ("dynamic-slice", "gather", "slice"):
        return 2.0 * shape_bytes(ins.type_str, cap)
    if op == "scatter":
        return 2.0 * _nth_operand_bytes(comp, ins, 2, cap)
    if op == "fusion":
        cm = re.search(r"calls=\{?%?([\w.\-]+)", ins.attr_str)
        body = comps.get(cm.group(1)) if cm else None
        if body is not None:
            dus_bytes = 0.0
            arithmetic = False
            has_ds = False
            for bi in body.instrs:
                if bi.op == "dynamic-update-slice":
                    dus_bytes += 2.0 * _nth_operand_bytes(body, bi, 1, cap)
                elif bi.op == "scatter":
                    dus_bytes += 2.0 * _nth_operand_bytes(body, bi, 2, cap)
                elif bi.op == "dynamic-slice":
                    has_ds = True
                elif bi.op not in LAYOUT_OPS:
                    arithmetic = True
            if dus_bytes:
                return dus_bytes
            if not arithmetic and not has_ds:
                return 0.0  # pure layout/dtype-plumbing fusion (CPU artifact)
            outb = shape_bytes(ins.type_str, cap)
            if has_ds:
                # Slice-extracting fusion: large operands are *indexed*,
                # not fully read — charging the whole carried KV cache per
                # layer inflated decode memory terms ~50x (analyzer
                # iteration, EXPERIMENTS.md §Perf).
                opb = 0
                for name in ins.operands:
                    t = comp.table.get(name)
                    if t:
                        opb += min(shape_bytes(t, cap), outb)
                return outb + opb
        return (shape_bytes(ins.type_str, cap)
                + _operand_bytes_capped(comp, ins, cap))
    return (shape_bytes(ins.type_str, cap)
            + _operand_bytes_capped(comp, ins, cap))


def control_flow_comps(comps: Dict[str, Computation], entry: str) -> set:
    """Entry + while bodies/conditions — the computations whose top-level
    instructions are the units of HBM residency. Fusion/reduce callees'
    costs are attributed at their call sites."""
    out = {entry}
    stack = [entry]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.op == "while":
                for ch in re.findall(r"(?:body|condition)=\{?%?([\w.\-]+)",
                                     ins.attr_str):
                    if ch not in out:
                        out.add(ch)
                        stack.append(ch)
    return out


def analyze(text: str, default_trip: int = 1,
            compute_elem_bytes: int = 2) -> dict:
    """compute_elem_bytes: TPU execution width cap for float traffic
    (2 = bf16); set 0 to disable capping."""
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = while_multipliers(comps, entry, default_trip)
    cf_comps = control_flow_comps(comps, entry)
    cap = compute_elem_bytes

    dot_flops = 0.0
    traffic = 0.0
    coll_traffic: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_operand: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_count = 0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_cf = cname in cf_comps
        for ins in comp.instrs:
            if ins.op == "dot":
                out_elems = 1
                for _, dims in shape_dims(ins.type_str):
                    for d in dims:
                        out_elems *= d
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                               ins.attr_str)
                k = 1
                if cm and ins.operands:
                    lhs_t = comp.table.get(ins.operands[0])
                    if lhs_t:
                        dims = shape_dims(lhs_t)[0][1]
                        for ci in cm.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                dot_flops += m * 2.0 * out_elems * k
                if not in_cf:
                    # dot inside a fusion body: its traffic is not seen at
                    # the control-flow level; add it here.
                    traffic += m * (shape_bytes(ins.type_str, cap)
                                    + _operand_bytes_capped(comp, ins, cap))
            if in_cf and ins.op in TRAFFIC_OPS:
                traffic += m * _instr_traffic(comp, ins, comps, cap)
            for kind in COLLECTIVES:
                if ins.op == kind or ins.op.startswith(kind + "-start"):
                    ob = _operand_bytes_capped(comp, ins, cap)
                    outb = shape_bytes(ins.type_str, cap)
                    coll_operand[kind] += m * ob
                    if kind == "all-reduce":
                        coll_traffic[kind] += m * 2.0 * ob
                    elif kind == "all-gather":
                        coll_traffic[kind] += m * outb
                    else:
                        coll_traffic[kind] += m * ob
                    coll_count += int(m)
                    break

    return {
        "dot_flops": dot_flops,
        "traffic_bytes": traffic,
        "collective_traffic": coll_traffic,
        "collective_traffic_total": sum(coll_traffic.values()),
        "collective_operand_bytes": coll_operand,
        "collective_operand_total": sum(coll_operand.values()),
        "collective_count": coll_count,
    }
