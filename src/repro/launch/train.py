"""Training launcher.

On this CPU container it runs reduced configs end-to-end (real steps,
checkpointing, resume); on a TPU slice the same entry point takes the
full configs — the mesh is built from whatever devices exist, shardings
come from the same rule tables the dry-run validates at 256/512 chips.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 100 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import MarkovLMTask, ByteCorpus, DataIterator
from repro.launch.mesh import make_mesh
from repro.sharding import make_parallel, tree_specs, tree_shardings
from repro.training.checkpoint import CheckpointManager
from repro.training.optim import (adamw, adafactor, cosine_schedule,
                                  mixed_precision)
from repro.training.step import (make_train_step, init_train_state,
                                 abstract_train_state,
                                 train_state_logical_axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-interval", type=int, default=50)
    ap.add_argument("--data", default="markov", choices=["markov", "bytes"])
    ap.add_argument("--mesh-shape", default=None, help="e.g. 2,4")
    args = ap.parse_args()

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    sched = cosine_schedule(args.lr, min(20, args.steps // 5), args.steps)
    opt = adamw(sched) if args.optimizer == "adamw" else adafactor(sched)
    opt = mixed_precision(opt)
    cfg = cfg.with_runtime(param_dtype="float32")

    n_dev = len(jax.devices())
    parallel = None
    shardings = None
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("data", "model")[:len(shape)] if len(shape) == 2 else \
            ("pod", "data", "model")
        mesh = make_mesh(shape, axes)
        parallel = make_parallel(mesh, "train", seq_shard=False)
        specs = tree_specs(train_state_logical_axes(cfg, opt), parallel, cfg)
        shardings = tree_shardings(specs, mesh)

    step_fn = make_train_step(cfg, opt, parallel)
    if shardings is not None:
        step_fn = jax.jit(step_fn, in_shardings=(shardings, None),
                          out_shardings=(shardings, None))
    else:
        step_fn = jax.jit(step_fn)

    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    if shardings is not None:
        state = jax.device_put(state, shardings)
    mgr = CheckpointManager(args.ckpt, save_interval=args.save_interval) \
        if args.ckpt else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        state, manifest = mgr.restore_latest(abstract_train_state(cfg, opt),
                                             shardings=shardings)
        start = manifest["step"]
        print(f"resumed from step {start}")

    source = (MarkovLMTask(vocab=cfg.vocab) if args.data == "markov"
              else ByteCorpus("src"))
    it = DataIterator(source, batch=args.batch, seq=args.seq, step=start)
    t0 = time.perf_counter()
    for d in it:
        state, m = step_fn(state, {"inputs": jnp.asarray(d["inputs"]),
                                   "labels": jnp.asarray(d["labels"])})
        s = int(state["step"])
        if mgr:
            mgr.maybe_save(jax.device_get(state), s)
        if s % 20 == 0 or s >= args.steps:
            dt = (time.perf_counter() - t0) * 1000 / max(s - start, 1)
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"({dt:.0f} ms/step, devices={n_dev})", flush=True)
        if s >= args.steps:
            break
    print("done")


if __name__ == "__main__":
    main()
