"""Reduced-device dry-run integration: the same launcher that targets the
512-chip production mesh must lower+compile on an 8-host-device mesh in a
subprocess (pytest's own process keeps 1 device), including a multi-pod
(2,2,2) mesh and the sharded-vs-dense MoE equivalence."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def run_dryrun(args, devices=8, timeout=420):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               REPRO_DRYRUN_DEVICES=str(devices))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, timeout=timeout,
        cwd=ROOT)


@pytest.mark.slow
def test_single_pod_cells(tmp_path):
    r = run_dryrun(["--arch", "stablelm-1.6b", "--shape", "train_4k",
                    "--shape", "decode_32k",
                    "--mesh", "custom", "--mesh-shape", "2,4",
                    "--mesh-axes", "data,model",
                    "--out", str(tmp_path), "--tag", "t", "--force"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for shape in ("train_4k", "decode_32k"):
        d = json.load(open(tmp_path / f"stablelm-1.6b_{shape}_custom_t.json"))
        assert d["hlo"]["dot_flops"] > 0
        assert d["terms"]["memory_s"] > 0
        assert d["memory"]["argument_bytes"] > 0


@pytest.mark.slow
def test_multipod_mesh_lowers(tmp_path):
    """The pod axis must shard: 2x2x2 mesh with ('pod','data','model')."""
    r = run_dryrun(["--arch", "gemma2-9b", "--shape", "decode_32k",
                    "--mesh", "custom", "--mesh-shape", "2,2,2",
                    "--mesh-axes", "pod,data,model",
                    "--out", str(tmp_path), "--tag", "t", "--force"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    d = json.load(open(tmp_path / "gemma2-9b_decode_32k_custom_t.json"))
    assert d["devices"] == 8
    assert d["hlo"]["collective_count"] > 0


@pytest.mark.slow
def test_long500k_skip_policy(tmp_path):
    r = run_dryrun(["--arch", "yi-9b", "--shape", "long_500k",
                    "--mesh", "custom", "--mesh-shape", "2,4",
                    "--mesh-axes", "data,model",
                    "--out", str(tmp_path), "--tag", "t", "--force"])
    assert r.returncode == 0
    d = json.load(open(tmp_path / "yi-9b_long_500k_custom_t.json"))
    assert d["skipped"] and "sub-quadratic" in d["reason"]


@pytest.mark.slow
def test_moe_cell_compiles_multidevice(tmp_path):
    r = run_dryrun(["--arch", "qwen3-moe-235b-a22b", "--shape", "decode_32k",
                    "--mesh", "custom", "--mesh-shape", "2,4",
                    "--mesh-axes", "data,model",
                    "--out", str(tmp_path), "--tag", "t", "--force"],
                   timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    d = json.load(open(
        tmp_path / "qwen3-moe-235b-a22b_decode_32k_custom_t.json"))
    assert d["info"]["moe_mode"] == "ep2d"
