"""Gradient accumulation + DiLoCo outer-sync features."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import MarkovLMTask
from repro.training.accum import make_accum_train_step
from repro.training.diloco import (init_outer, outer_sync, broadcast_anchor)
from repro.training.optim import adamw, constant_schedule
from repro.training.step import make_train_step, init_train_state


def test_accumulation_matches_monolithic_step():
    """n_micro microbatches must produce the same update as one big
    batch (same averaged gradients)."""
    cfg = reduced_config("stablelm_1_6b")
    opt = adamw(constant_schedule(1e-3))
    task = MarkovLMTask(vocab=cfg.vocab, seed=0)
    b = task.batch(0, 8, 16)
    batch = {"inputs": jnp.asarray(b["inputs"]),
             "labels": jnp.asarray(b["labels"])}
    state0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))

    mono = jax.jit(make_train_step(cfg, opt))
    accum = jax.jit(make_accum_train_step(cfg, opt, n_micro=4))
    s1, m1 = mono(state0, batch)
    s2, m2 = accum(state0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(s1["params"]),
                     jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-6)


def _pod_train(cfg, opt, params, task, pod, start, n):
    step = jax.jit(make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.asarray(start, jnp.int32)}
    for i in range(start, start + n):
        b = task.batch(i, 4, 16, host=pod)  # per-pod data shard
        state, m = step(state, {"inputs": jnp.asarray(b["inputs"]),
                                "labels": jnp.asarray(b["labels"])})
    return state["params"], float(m["loss"])


def test_diloco_outer_sync_converges_and_compresses():
    cfg = reduced_config("stablelm_1_6b")
    opt = adamw(constant_schedule(2e-3))
    task = MarkovLMTask(vocab=cfg.vocab, seed=1)
    params = init_train_state(cfg, opt, jax.random.PRNGKey(0))["params"]
    outer = init_outer(params, n_pods=2)

    losses = []
    step0 = 0
    for round_ in range(3):
        pod_params = []
        round_losses = []
        for pod in range(2):
            p = broadcast_anchor(outer, params)
            p, loss = _pod_train(cfg, opt, p, task, pod, step0, 5)
            pod_params.append(p)
            round_losses.append(loss)
        outer = outer_sync(outer, pod_params)
        losses.append(np.mean(round_losses))
        step0 += 5
    # learning happens across outer rounds
    assert losses[-1] < losses[0], losses
    # and the compressed sync moved ~4x fewer DCN bytes than fp32 deltas
    assert outer.bytes_sent < 0.30 * outer.bytes_fp32
    assert outer.syncs == 3


def test_diloco_quantization_error_bounded():
    """One outer sync with vs without quantization: anchors must agree to
    within the int8 scale (EF keeps residuals for the next round)."""
    cfg = reduced_config("yi_9b")
    opt = adamw(constant_schedule(1e-3))
    task = MarkovLMTask(vocab=cfg.vocab, seed=2)
    params = init_train_state(cfg, opt, jax.random.PRNGKey(0))["params"]
    pod_params = []
    for pod in range(2):
        p, _ = _pod_train(cfg, opt, params, task, pod, 0, 3)
        pod_params.append(p)
    exact = outer_sync(init_outer(params, 2), pod_params, quantize=False)
    quant = outer_sync(init_outer(params, 2), pod_params, quantize=True)
    for a, b in zip(jax.tree.leaves(exact.anchor),
                    jax.tree.leaves(quant.anchor)):
        rel = np.abs(np.asarray(a) - np.asarray(b)).max()
        assert rel < 2e-2, rel
