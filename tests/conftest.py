import os
import sys

# Tests must see exactly ONE device (the dry-run subprocess sets its own
# device count); keep any inherited flags out.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
