import os
import sys

# Tests must see exactly ONE device (the dry-run subprocess sets its own
# device count); keep any inherited flags out.  CI opts back in to a
# fake multi-device CPU topology via REPRO_HOST_DEVICES=N so the
# shard_map engine path is exercised on plain runners.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if os.environ.get("REPRO_HOST_DEVICES"):
    from repro.utils.config import configure
    configure(host_devices=int(os.environ["REPRO_HOST_DEVICES"]))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
