"""KV-cache correctness: prefill + decode must reproduce the full
forward for every architecture (exercises ring buffers, RG-LRU and SSD
state passing, and the carried-cache scan)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import init_params, forward, decode_step, init_cache
from repro.models.model import prefill

TOKEN_ARCHS = [a for a in ARCH_IDS
               if a not in ("musicgen_large", "chameleon_34b")]


@pytest.mark.parametrize("arch", TOKEN_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 14
    x = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full, _ = forward(params, x, cfg)
    _, cache = prefill(params, x[:, :T - 3], cfg, max_seq=32)
    pos = T - 3
    for t in range(T - 3, T):
        logits, cache = decode_step(params, x[:, t:t + 1], cache,
                                    jnp.int32(pos), cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=5e-3,
                                   rtol=1e-3)
        pos += 1


@pytest.mark.parametrize("arch", ["gemma2_9b", "recurrentgemma_2b"])
def test_ring_buffer_window_decode(arch):
    """Decode far beyond the window: ring-buffer cache must agree with a
    full forward over the whole sequence (window masking equal)."""
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 1
    T = 3 * cfg.window  # several wraps
    x = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full, _ = forward(params, x, cfg)
    _, cache = prefill(params, x[:, :4], cfg, max_seq=T)
    pos = 4
    for t in range(4, T):
        logits, cache = decode_step(params, x[:, t:t + 1], cache,
                                    jnp.int32(pos), cfg)
        pos += 1
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-3, rtol=1e-3)


def test_prefill_longer_than_window_ring_layout():
    cfg = reduced_config("gemma2_9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 20  # window is 8 in the reduced config
    x = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0, cfg.vocab)
    full, _ = forward(params, x, cfg)
    _, cache = prefill(params, x[:, :T], cfg, max_seq=64)
    logits, _ = decode_step(params, x[:, T:], cache, jnp.int32(T), cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-3, rtol=1e-3)


def test_embeddings_input_decode():
    cfg = reduced_config("chameleon_34b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    full, _ = forward(params, x, cfg)
    _, cache = prefill(params, x[:, :T - 1], cfg, max_seq=16)
    logits, _ = decode_step(params, x[:, T - 1:], cache, jnp.int32(T - 1), cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-3, rtol=1e-3)
