"""Trace capture & replay tests (serving/trace.py, DESIGN.md §11):
codec round trips and schema guards, recorder hooks on the serving
layers, CapturedTraceProcess replay modes, fleet reconstruction from
multi-device captures, the registered-capture resolution (and the
trace:<name> error fix), and the committed reference capture's
bit-for-bit regeneration pin."""

import json

import numpy as np
import pytest

from repro.configs.paper_zoo import (CAPTURE_SCENARIOS, SYNTHETIC_TRACES,
                                     capture_path, paper_profiles)
from repro.serving.fleet import FleetMixture
from repro.serving.network import make_network, trace_names
from repro.serving.router import Router
from repro.serving.simulator import SimConfig, simulate
from repro.serving.trace import (CAPTURE_MODES, SLA_UNKNOWN,
                                 TRACE_SCHEMA_VERSION,
                                 CapturedTraceProcess, Trace,
                                 TraceRecorder, load_capture,
                                 requests_from_trace)

COLUMNS = ("t_arrival", "device_id", "t_input_ms", "regime_id", "model",
           "sla_ok")


def small_trace(n=6, **over):
    kw = dict(
        t_arrival=np.arange(n, dtype=np.float64),
        device_id=np.array(["a", "b", "a", "b", "a", "b"][:n]),
        t_input_ms=np.linspace(10.0, 60.0, n),
        regime_id=np.array([0, 1, 0, 1, 0, 1][:n]),
        model=np.array(["m0", "m1", "m0", "m1", "m0", "m1"][:n]),
        sla_ok=np.array([1, 0, 1, 1, -1, 1][:n], np.int8),
        regime_names=["wifi", "lte"],
        name="unit", source="test", meta={"k": "v"})
    kw.update(over)
    return Trace(**kw)


def assert_traces_equal(a: Trace, b: Trace):
    for col in COLUMNS:
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert a.regime_names == b.regime_names
    assert (a.name, a.source, a.meta) == (b.name, b.source, b.meta)


# -- codec ------------------------------------------------------------------

@pytest.mark.parametrize("ext", ["jsonl", "npz"])
def test_trace_roundtrip_bit_exact(tmp_path, ext):
    tr = small_trace(meta={"exec_ms": [1.5, 2.5, 3.5, 4.5, 5.5, 6.5],
                           "t_sla": 300.0})
    # Awkward floats must survive the text codec bit-for-bit too.
    tr.t_input_ms[0] = 1.0 / 3.0
    tr.t_input_ms[1] = np.nextafter(63.0, 64.0)
    path = tmp_path / f"t.{ext}"
    tr.save(path)
    assert_traces_equal(tr, Trace.load(path))


def test_trace_schema_mismatch_fails_fast(tmp_path):
    tr = small_trace()
    path = tmp_path / "t.jsonl"
    tr.save(path)
    lines = path.read_text().splitlines()
    header = json.loads(lines[0])
    header["schema"] = TRACE_SCHEMA_VERSION + 1
    path.write_text("\n".join([json.dumps(header)] + lines[1:]) + "\n")
    with pytest.raises(ValueError, match="schema version"):
        Trace.load(path)
    # Not a trace at all -> the kind guard, not a KeyError.
    path.write_text(json.dumps({"whatever": 1}) + "\n")
    with pytest.raises(ValueError, match="repro.trace"):
        Trace.load(path)
    with pytest.raises(ValueError, match="extension"):
        tr.save(tmp_path / "t.csv")


def test_trace_jsonl_row_count_guard(tmp_path):
    tr = small_trace()
    path = tmp_path / "t.jsonl"
    tr.save(path)
    lines = path.read_text().splitlines()
    path.write_text("\n".join(lines[:-1]) + "\n")    # drop one record
    with pytest.raises(ValueError, match="declares"):
        Trace.load(path)


def test_trace_validation():
    with pytest.raises(ValueError, match="positive"):
        small_trace(t_input_ms=np.array([1.0, -2, 3, 4, 5, 6.0]))
    # NaN would replay as an always-met SLA — rejected at the boundary.
    with pytest.raises(ValueError, match="finite"):
        small_trace(t_input_ms=np.array([1.0, np.nan, 3, 4, 5, 6.0]))
    with pytest.raises(ValueError, match="finite"):
        small_trace(t_arrival=np.array([0.0, np.inf, 2, 3, 4, 5.0]))
    with pytest.raises(ValueError, match="finite"):
        CapturedTraceProcess([5.0, np.nan])
    with pytest.raises(ValueError, match="rows"):
        small_trace(model=np.array(["m0"]))
    with pytest.raises(ValueError, match="no name"):
        small_trace(regime_names=["only_one"])
    with pytest.raises(ValueError, match="-1/0/1"):
        small_trace(sla_ok=np.array([1, 0, 2, 1, 1, 1], np.int8))
    with pytest.raises(ValueError, match="at least one"):
        Trace(t_arrival=np.array([]), device_id=np.array([]),
              t_input_ms=np.array([]), regime_id=np.array([]),
              model=np.array([]), sla_ok=np.array([]))
    tr = small_trace()
    assert tr.attainment == pytest.approx(4 / 5)   # one unknown excluded
    assert tr.device_ids() == ["a", "b"]
    assert np.array_equal(tr.per_device()["b"], [1, 3, 5])
    # Over-wide strings are rejected, never silently truncated
    # (truncation could merge distinct device keys).
    with pytest.raises(ValueError, match="64 chars"):
        small_trace(device_id=np.array(["x" * 65] + ["b"] * 5))
    with pytest.raises(ValueError, match="64 chars"):
        small_trace(model=np.array(["m" * 65] + ["m1"] * 5))
    with pytest.raises(ValueError, match="64 chars"):
        TraceRecorder().record(t_arrival=0.0, t_input_ms=1.0,
                               model="m" * 65)


# -- recorder ---------------------------------------------------------------

def test_recorder_router_hook_records_admissions():
    from repro.serving.batching import Request
    router = Router(paper_profiles(), policy="greedy_nw")
    reqs = [Request(arrival=float(i), rid=i,
                    prompt=np.zeros(4, np.int32), sla_ms=300.0,
                    t_input_ms=50.0 + i, device_id="d%d" % (i % 2))
            for i in range(8)]
    with TraceRecorder().attach(router) as rec:
        router.submit(reqs[0])
        router.submit_many(reqs[1:])
    assert router.recorder is None                  # detached on exit
    tr = rec.to_trace(source="router")
    assert len(tr) == 8
    assert (tr.sla_ok == SLA_UNKNOWN).all()         # outcome unknown
    assert set(tr.model[:1]) <= set(router.order)
    assert tr.device_ids() == ["d0", "d1"]
    np.testing.assert_allclose(tr.t_input_ms, 50.0 + np.arange(8))
    with pytest.raises(ValueError, match="no recorder hook"):
        TraceRecorder().attach(object())
    with pytest.raises(ValueError, match="no requests"):
        TraceRecorder().to_trace()


def test_recorder_rejects_unset_t_input_at_record_time():
    """Request defaults t_input_ms to 0.0; the recorder must fail at
    the offending record, not at to_trace() after the run is lost."""
    rec = TraceRecorder()
    with pytest.raises(ValueError, match="positive t_input_ms"):
        rec.record(t_arrival=0.0, t_input_ms=0.0)
    assert len(rec) == 0


def test_recorder_exec_side_channel():
    rec = TraceRecorder()
    rec.record(t_arrival=0.0, t_input_ms=10.0, model="m", sla_ok=True,
               exec_ms=5.0)
    rec.record(t_arrival=1.0, t_input_ms=11.0, model="m", sla_ok=False,
               exec_ms=7.0)
    tr = rec.to_trace()
    assert tr.meta["exec_ms"] == [5.0, 7.0]
    assert tr.attainment == 0.5
    # A mixed capture (some layers outcome-blind) exports no exec_ms.
    rec.record(t_arrival=2.0, t_input_ms=12.0)
    assert "exec_ms" not in rec.to_trace().meta


def test_requests_from_trace_roundtrip_through_recorder():
    tr = small_trace()
    reqs = requests_from_trace(tr, sla_ms=250.0)
    assert [r.device_id for r in reqs[:2]] == ["a", "b"]
    rec = TraceRecorder()
    for r in reqs:
        rec.record_request(r, model="m0", sla_ok=True)
    back = rec.to_trace()
    np.testing.assert_array_equal(back.t_input_ms, tr.t_input_ms)
    np.testing.assert_array_equal(back.t_arrival, tr.t_arrival)
    np.testing.assert_array_equal(back.device_id, tr.device_id)


# -- replay process ---------------------------------------------------------

def test_captured_process_exact_replay_bit_for_bit():
    tr = small_trace()
    # Sub-millisecond measurements must survive exact replay — the
    # generator-side MIN_T_INPUT_MS clamp does not apply to captures.
    tr.t_input_ms[0] = 0.4
    p = CapturedTraceProcess(tr, mode="exact")
    t, reg = p.sample_trace(np.random.default_rng(0), len(tr))
    assert np.array_equal(t, tr.t_input_ms)
    assert t[0] == 0.4
    assert np.array_equal(reg, tr.regime_id)
    assert p.regime_names() == ["wifi", "lte"]
    assert p.mean == pytest.approx(tr.t_input_ms.mean())
    with pytest.raises(ValueError, match="exact replay"):
        p.sample_trace(np.random.default_rng(0), len(tr) + 1)


def test_captured_process_resampling_modes():
    tr = small_trace()
    rng = np.random.default_rng(3)
    loop = CapturedTraceProcess(tr, mode="loop")
    t, reg = loop.sample_trace(rng, 2 * len(tr) + 1)
    assert np.array_equal(t[:len(tr)], tr.t_input_ms)
    assert np.array_equal(t[len(tr):2 * len(tr)], tr.t_input_ms)
    # timewarp:2 doubles every dwell; timewarp:0.5 halves (skips).
    warp = CapturedTraceProcess(tr, mode="timewarp:2")
    t, _ = warp.sample_trace(rng, 4)
    assert np.array_equal(t, tr.t_input_ms[[0, 0, 1, 1]])
    fast = CapturedTraceProcess(tr, mode="timewarp:0.5")
    t, _ = fast.sample_trace(rng, 3)
    assert np.array_equal(t, tr.t_input_ms[[0, 2, 4]])
    # bootstrap: deterministic under a fixed seed, values all captured,
    # blocks preserve contiguity.
    boot = CapturedTraceProcess(tr, mode="bootstrap", block=2)
    a, _ = boot.sample_trace(np.random.default_rng(5), 50)
    b, _ = boot.sample_trace(np.random.default_rng(5), 50)
    assert np.array_equal(a, b)
    assert set(a) <= set(tr.t_input_ms)
    with pytest.raises(ValueError, match="unknown capture replay mode"):
        CapturedTraceProcess(tr, mode="shuffle")
    with pytest.raises(ValueError, match="factor"):
        CapturedTraceProcess(tr, mode="timewarp:0")
    with pytest.raises(ValueError, match="takes no"):
        CapturedTraceProcess(tr, mode="loop:3")
    assert "exact" in CAPTURE_MODES


def test_captured_process_from_arrays():
    p = CapturedTraceProcess([5.0, 6.0], mode="loop",
                             regimes=[0, 1], regime_names=["lo", "hi"])
    t, reg = p.sample_trace(np.random.default_rng(0), 4)
    assert np.array_equal(reg, [0, 1, 0, 1])
    assert p.regime_names() == ["lo", "hi"]
    with pytest.raises(ValueError, match="carries its own"):
        CapturedTraceProcess(small_trace(), regimes=[0] * 6)
    with pytest.raises(ValueError, match="align"):
        CapturedTraceProcess([5.0, 6.0], regimes=[0])
    with pytest.raises(ValueError, match="cover"):
        CapturedTraceProcess([5.0, 6.0], regimes=[0, 3],
                             regime_names=["only", "two"])
    # Default names always cover sparse regime ids.
    sparse = CapturedTraceProcess([5.0, 6.0], regimes=[0, 3])
    assert len(sparse.regime_names()) == 4


# -- sim capture / replay ---------------------------------------------------

def _sim_capture(policy="greedy_nw", n=400, fleet=None, network="lte"):
    profs = paper_profiles()
    cfg = SimConfig(t_sla=300.0, n_requests=n, seed=9, network=network,
                    fleet=fleet, policy=policy, t_estimator="ewma:0.2")
    r = simulate(profs, cfg)
    return r, Trace.from_sim(r, name="cap",
                             meta={"models": [p.name for p in profs]})


def test_trace_from_sim_and_exact_replay_attainment():
    r, tr = _sim_capture(network="lte_outages")
    assert len(tr) == 400
    assert tr.attainment == pytest.approx(r.attainment)
    assert tr.regime_names == ["lte", "degraded_lte", "outage"]
    assert set(tr.model) <= set(p.name for p in paper_profiles())
    # Exact replay with injected measured execution reproduces the
    # captured attainment almost to the request (deterministic policy;
    # only the cold-start prior differs).
    exec_ms = r.latencies - 2.0 * r.t_inputs
    over = np.full((len(tr), len(paper_profiles())), np.nan)
    names = [p.name for p in paper_profiles()]
    for i, m in enumerate(tr.model):
        over[i, names.index(str(m))] = exec_ms[i]
    rep = simulate(paper_profiles(), SimConfig(
        t_sla=300.0, n_requests=len(tr), seed=9,
        network=CapturedTraceProcess(tr, mode="exact"),
        policy="greedy_nw", t_estimator="ewma:0.2"), exec_override=over)
    assert abs(rep.attainment - tr.attainment) <= 2.0 / len(tr)


def test_exec_override_shape_guard():
    with pytest.raises(ValueError, match="exec_override"):
        simulate(paper_profiles(), SimConfig(t_sla=300.0, n_requests=10),
                 exec_override=np.zeros((3, 2)))


def test_fleet_from_capture_reconstructs_devices():
    _, tr = _sim_capture(fleet="mixed_fleet")
    fl = FleetMixture.from_capture(tr)
    assert set(fl.device_ids) == {"flagship", "midrange", "budget"}
    shares = {d: len(ix) / len(tr) for d, ix in tr.per_device().items()}
    for d, w in zip(fl.devices, fl.weights):
        assert w == pytest.approx(shares[d.device_id])
        assert d.on_device_ms > 0 or d.tier == "legacy"   # tier resolved
    # Device-prefixed regimes compose (no double prefix).
    assert "midrange:lte" in fl.regime_names()
    # Replays through the device-keyed estimator-bank path.
    rep = simulate(paper_profiles(), SimConfig(
        t_sla=300.0, n_requests=600, seed=1, fleet=fl,
        policy="greedy_nw", t_estimator="ewma:0.2"))
    assert set(rep.per_device()) == set(fl.device_ids)
    assert abs(rep.attainment - tr.attainment) < 0.1


def test_fleet_from_capture_untagged_and_overrides():
    from repro.serving.fleet import DeviceProfile
    tr = small_trace(device_id=np.array([""] * 6))
    fl = FleetMixture.from_capture(tr, profiles=None)
    assert fl.device_ids == ["<untagged>"]
    assert fl.devices[0].on_device_ms == 0.0
    # Overrides keyed by the visible id apply to untagged captures too.
    over = DeviceProfile("x", "lte", on_device_ms=350.0,
                         on_device_accuracy=0.7)
    fl2 = FleetMixture.from_capture(tr, profiles={"<untagged>": over})
    assert fl2.devices[0].on_device_ms == 350.0
    assert fl2.device_ids == ["<untagged>"]


# -- registry resolution (the trace:<name> error fix) -----------------------

def test_make_network_unknown_trace_lists_available():
    with pytest.raises(ValueError) as e:
        make_network("trace:no_such_trace")
    msg = str(e.value)
    for name in SYNTHETIC_TRACES:
        assert name in msg
    for name in CAPTURE_SCENARIOS:
        assert name in msg
    with pytest.raises(ValueError) as e:
        make_network("capture:no_such_capture")
    assert "reference_fleet" in str(e.value)
    assert sorted(trace_names()) == sorted(
        list(SYNTHETIC_TRACES) + list(CAPTURE_SCENARIOS))


def test_registered_capture_resolves_through_make_network():
    p = make_network("capture:reference_fleet")
    assert isinstance(p, CapturedTraceProcess)
    assert p.mode == CAPTURE_SCENARIOS["reference_fleet"]["mode"]
    # trace:<name> reaches captures too (one namespace for replay).
    p2 = make_network("trace:reference_fleet")
    assert isinstance(p2, CapturedTraceProcess)
    t, _ = p.sample_trace(np.random.default_rng(0), 16)
    assert (t > 0).all()
    with pytest.raises(ValueError, match="unknown capture"):
        capture_path("nope")


def test_reference_capture_regenerates_bit_for_bit():
    """The committed capture is exactly what --write-reference
    produces (numpy-only policy), so the capture→persist→replay loop
    cannot drift silently."""
    committed = load_capture("reference_fleet")
    profs = paper_profiles()
    r = simulate(profs, SimConfig(
        t_sla=float(committed.meta["t_sla"]),
        n_requests=int(committed.meta["n_requests"]),
        seed=int(committed.meta["seed"]),
        fleet=str(committed.meta["fleet"]),
        policy=str(committed.meta["policy"]),
        t_estimator=str(committed.meta["t_estimator"])))
    regen = Trace.from_sim(r, name=committed.name,
                           meta=dict(committed.meta))
    assert_traces_equal(committed, regen)
    assert committed.meta["models"] == [p.name for p in profs]
    assert (committed.sla_ok != SLA_UNKNOWN).all()
    assert committed.attainment == pytest.approx(
        1.0 - r.violations.mean())
