"""Measured serving (DESIGN.md §14): padded-prompt masking vs the
kernel oracle, slot backfill vs a from-scratch prefill, fp32/int8
engine equivalence, the prefill/per-token profile split, and the
exec_ms capture -> exec_override replay pin."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.kernels.ref import flash_attention_ref
from repro.models import init_params
from repro.models.layers import attention_naive
from repro.models.model import prefill
from repro.serving.batching import Request
from repro.serving.engine import InferenceEngine
from repro.serving.measured import build_model
from repro.serving.server import CNNSelectServer, ServedModel
from repro.serving.simulator import SimConfig, simulate
from repro.serving.trace import CapturedTraceProcess, Trace, TraceRecorder


@pytest.fixture(scope="module", params=["auto", "pallas"])
def small(request):
    """Every engine-level pin runs twice: once on the default (auto)
    impl and once forced onto the masked pallas fast path, so the PR 7
    batching/backfill behaviour is pinned on both."""
    cfg = dataclasses.replace(reduced_config("stablelm_1_6b"),
                              attn_impl=request.param)
    params = init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _engine(cfg, params, batch_size=2, max_seq=32):
    eng = InferenceEngine(cfg, params, batch_size=batch_size,
                          max_seq=max_seq)
    eng.warmup(prompt_len=8)
    return eng


# -- padded-prompt masking --------------------------------------------------

def test_attention_valid_from_matches_ref():
    """Left-padded rows with valid_from equal the kernel oracle run on
    the unpadded slice (causality is relative, so the absolute-position
    shift cancels)."""
    rng = np.random.default_rng(0)
    B, Hq, KV, hd, T, pad = 1, 4, 2, 16, 6, 3
    S = T + pad
    q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attention_naive(q, k, v, pos, pos, window=0, cap=0.0,
                          scale=hd ** -0.5,
                          valid_from=jnp.asarray([pad], jnp.int32))
    ref = flash_attention_ref(
        jnp.transpose(q[:, pad:], (0, 2, 1, 3)),
        jnp.transpose(k[:, pad:], (0, 2, 1, 3)),
        jnp.transpose(v[:, pad:], (0, 2, 1, 3)))
    np.testing.assert_allclose(
        np.asarray(out[:, pad:]),
        np.asarray(jnp.transpose(ref, (0, 2, 1, 3))), atol=1e-5)


def test_padded_prefill_matches_unpadded(small):
    """Engine-level pin: a left-padded row with lengths= produces the
    same logits as the unpadded prompt (RoPE is shift-invariant, pads
    are masked out of attention)."""
    cfg, params = small
    rng = np.random.default_rng(2)
    full = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    short = rng.integers(0, cfg.vocab, 5, dtype=np.int32)
    padded = np.zeros((2, 8), np.int32)
    padded[0] = full
    padded[1, 3:] = short
    eng = _engine(cfg, params)
    lp = eng.run_prefill(padded, lengths=[8, 5])
    ref = _engine(cfg, params)
    lu = ref.run_prefill(np.stack([short, short]))
    np.testing.assert_allclose(lp[1], lu[0], atol=1e-4)


def test_valid_from_zero_is_exact_noop(small):
    """valid_from=0 rows are bit-identical to the unmasked path (the
    causal mask already enforces pos_k >= 0), so maskable engines can
    always pass an array and keep a single jit trace."""
    cfg, params = small
    eng = _engine(cfg, params)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab, (2, 8), dtype=np.int32))
    a, _ = eng._prefill(params, toks, None)
    b, _ = eng._prefill(params, toks, jnp.zeros((2,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_padded_prefill_rejected_without_mask_support(small):
    cfg, params = small
    rec = dataclasses.replace(cfg, pattern=("rglru",))
    eng = InferenceEngine(rec, params, batch_size=2, max_seq=32)
    assert not eng._maskable and not eng._backfillable
    with pytest.raises(NotImplementedError, match="recurrent"):
        eng.run_prefill(np.zeros((2, 8), np.int32), lengths=[8, 4])


# -- engine equivalence -----------------------------------------------------

def test_engine_matches_unjitted_forward(small):
    cfg, params = small
    eng = _engine(cfg, params)
    toks = np.random.default_rng(4).integers(0, cfg.vocab, (2, 8),
                                             dtype=np.int32)
    got = eng.run_prefill(toks)
    want, _ = prefill(params, jnp.asarray(toks), cfg,
                      max_seq=eng.max_seq, logits_last_only=True)
    np.testing.assert_allclose(got, np.asarray(want)[:, 0], atol=1e-5)


def test_int8_engine_within_tolerance_of_fp32():
    """Same seed -> same base weights; the int8 zoo variant must differ
    (the quantization error is real) but stay close on the logit scale,
    and must be smaller on disk (the memory-budget frontier story)."""
    a = build_model("lm_small", batch_size=2, max_seq=32, seed=5)
    b = build_model("lm_small_int8", batch_size=2, max_seq=32, seed=5)
    assert b.size_bytes < a.size_bytes
    toks = np.random.default_rng(5).integers(
        0, a.engine.cfg.vocab, (2, 8), dtype=np.int32)
    la = a.engine.run_prefill(toks)
    lb = b.engine.run_prefill(toks)
    assert not np.array_equal(la, lb)
    assert np.abs(la - lb).max() < 0.1 * np.abs(la).max()


def test_int8_engine_holds_resident_int8_weights():
    """int8 zoo engines execute from the quantized tree directly: the
    live params contain int8 projection leaves (no dequantized fp32
    copy), and the reported size is the bytes the engine actually holds
    — well under half the fp32 twin."""
    m8 = build_model("lm_small_int8", batch_size=2, max_seq=32, seed=5)
    mf = build_model("lm_small", batch_size=2, max_seq=32, seed=5)
    leaves = jax.tree.leaves(m8.engine.params)
    n_int8 = sum(1 for x in leaves if x.dtype == jnp.int8)
    assert n_int8 > 0
    assert m8.size_bytes == m8.engine.resident_bytes
    assert m8.size_bytes < 0.55 * mf.size_bytes


def test_int8_exec_same_tokens_across_impls():
    """Greedy generation from the same int8 exec tree agrees between the
    naive reference attention and the masked pallas kernels — the int8
    matmul dispatch is orthogonal to the attention impl."""
    mp = build_model("lm_small_int8", batch_size=2, max_seq=32, seed=6,
                     attn_impl="pallas")
    mn = build_model("lm_small_int8", batch_size=2, max_seq=32, seed=6,
                     attn_impl="naive")
    prompts = np.random.default_rng(6).integers(
        0, mp.engine.cfg.vocab, (2, 6), dtype=np.int32)
    np.testing.assert_array_equal(
        mp.engine.generate(prompts, 5, greedy=True),
        mn.engine.generate(prompts, 5, greedy=True))


# -- decode fail-fast & profile split ---------------------------------------

def test_run_decode_fail_fast(small):
    cfg, params = small
    eng = InferenceEngine(cfg, params, batch_size=1, max_seq=16)
    with pytest.raises(RuntimeError, match="no KV cache"):
        eng.run_decode(np.zeros((1, 1), np.int32))


def test_measured_profile_reports_prefill_decode_split(small):
    cfg, params = small
    eng = _engine(cfg, params)
    p = eng.measured_profile(prompt_len=8, n_tokens=3, reps=2)
    assert set(p) == {"mu", "sigma", "prefill_ms", "per_token_ms",
                      "resident_bytes"}
    assert p["prefill_ms"] > 0 and p["per_token_ms"] > 0
    assert p["resident_bytes"] == eng.resident_bytes > 0
    # The split is a decomposition of the same timed reps, not an
    # independent measurement: mu == prefill + n_tokens * per_token.
    assert p["mu"] == pytest.approx(
        p["prefill_ms"] + 3 * p["per_token_ms"], rel=1e-9)


# -- slot backfill ----------------------------------------------------------

def test_backfill_matches_from_scratch_prefill(small):
    """Retire -> backfill lifecycle: a request joining mid-group via
    prefill_row sees logits (and subsequent decode steps) equal to a
    from-scratch prefill at the same absolute positions."""
    cfg, params = small
    rng = np.random.default_rng(6)
    p0, p1 = (rng.integers(0, cfg.vocab, 8, dtype=np.int32)
              for _ in range(2))
    p2 = rng.integers(0, cfg.vocab, 5, dtype=np.int32)
    eng = _engine(cfg, params)
    logits = eng.run_prefill(np.stack([p0, p1]))
    hist1 = list(p1)
    for _ in range(2):                      # row0 retires after 2 tokens
        nxt = logits.argmax(-1).astype(np.int32)
        hist1.append(int(nxt[1]))
        logits = eng.run_decode(nxt[:, None])
    # cache_pos is now 10; join p2 (5 real tokens) into freed slot 0.
    prompt = np.zeros(8, np.int32)
    prompt[3:] = p2
    lj = eng.prefill_row(prompt, 0, length=5)
    # Reference: fresh engine, both rows prefilled from scratch at the
    # same absolute positions (p2 right-aligned in width 10 -> 5..9).
    row0 = np.zeros(10, np.int32)
    row0[5:] = p2
    row1 = np.asarray(hist1, np.int32)
    ref = _engine(cfg, params)
    lr = ref.run_prefill(np.stack([row0, row1]), lengths=[5, 10])
    np.testing.assert_allclose(lj, lr[0], atol=1e-4)
    np.testing.assert_allclose(logits[1], lr[1], atol=1e-4)
    # Aligned decode continues identically for both rows.
    nxt = np.stack([lj.argmax(-1), logits[1].argmax(-1)]
                   ).astype(np.int32)
    np.testing.assert_allclose(eng.run_decode(nxt[:, None]),
                               ref.run_decode(nxt[:, None]), atol=1e-4)
    assert eng.stats.backfill_calls == 1


def test_prefill_row_guards(small):
    cfg, params = small
    eng = _engine(cfg, params)
    with pytest.raises(RuntimeError, match="no KV cache"):
        eng.prefill_row(np.zeros(4, np.int32), 0)
    eng.run_prefill(np.zeros((2, 8), np.int32))
    with pytest.raises(ValueError, match="slot"):
        eng.prefill_row(np.zeros(4, np.int32), 9)
    with pytest.raises(ValueError, match="longer than current context"):
        eng.prefill_row(np.zeros(12, np.int32), 0)


# -- exec_ms capture -> exec_override replay --------------------------------

def test_exec_ms_capture_replay_bit_exact(small, tmp_path):
    """Measured exec_ms survives trace save/load bit-for-bit, and an
    exact replay with exec_override reproduces each matched request's
    latency as exactly 2*t_input + exec_ms (no resampling)."""
    cfg, params = small
    models = [
        ServedModel(name=n, accuracy=acc,
                    engine=InferenceEngine(cfg, init_params(
                        cfg, jax.random.PRNGKey(s)),
                        batch_size=1, max_seq=32))
        for n, acc, s in [("a", 0.6, 0), ("b", 0.9, 1)]]
    srv = CNNSelectServer(models, t_threshold=10.0, n_tokens=2)
    srv.profile_models(prompt_len=8, reps=2)
    names = [m.name for m in models]
    rng = np.random.default_rng(7)
    t_sla = 60.0
    with TraceRecorder(name="pin").attach(srv) as rec:
        for i in range(12):
            srv.handle(Request(
                arrival=float(i), rid=i,
                prompt=rng.integers(0, 50, 8).astype(np.int32),
                t_input_ms=float(5.0 + (i % 3))), t_sla=t_sla)
        tr = rec.to_trace(source="server", meta={"models": names})
    path = tmp_path / "pin.jsonl"
    tr.save(path)
    back = Trace.load(path)
    exec_ms = np.asarray(tr.meta["exec_ms"], np.float64)
    np.testing.assert_array_equal(
        exec_ms, np.asarray(back.meta["exec_ms"], np.float64))
    # Replay: inject the measured exec time of each captured selection.
    over = np.full((len(back), len(names)), np.nan)
    for i, m in enumerate(back.model):
        over[i, names.index(str(m))] = exec_ms[i]
    profs = [dataclasses.replace(p, cold_mu=0.0, cold_sigma=0.0)
             for p in srv.current_profiles()]
    rep = simulate(profs, SimConfig(
        t_sla=t_sla, n_requests=len(back), seed=7,
        network=CapturedTraceProcess(back, mode="exact"),
        t_threshold=10.0), exec_override=over)
    cap_sel = np.array([names.index(str(m)) for m in back.model])
    matched = rep.selections == cap_sel
    assert matched.any()
    np.testing.assert_array_equal(
        rep.latencies[matched],
        2.0 * np.asarray(back.t_input_ms)[matched] + exec_ms[matched])
