"""Public-API surface gate (CI fast job).

The exported surface of every public package — names, function
signatures, class constructor + public-method signatures — is
snapshotted in tests/api_surface.txt. Any drift (a rename, a removed
export, a changed default) fails this test with a diff, so API changes
are always deliberate and reviewable in the same commit that makes
them.

Regenerate after an intentional change:

    REPRO_UPDATE_API_SURFACE=1 PYTHONPATH=src python -m pytest -q \
        tests/test_api_surface.py
"""

import importlib
import inspect
import os
import re

SNAPSHOT = os.path.join(os.path.dirname(__file__), "api_surface.txt")

# The import surfaces users consume: the package __init__s plus the
# serving submodules the DESIGN docs name as entry points.
PUBLIC_MODULES = [
    "repro",
    "repro.configs",
    "repro.core",
    "repro.data",
    "repro.kernels",
    "repro.models",
    "repro.quant",
    "repro.serving",
    "repro.sharding",
    "repro.training",
    "repro.utils",
]


def _sig(obj) -> str:
    """Signature with annotations stripped (they differ across Python
    versions) and memory addresses scrubbed from default reprs."""
    try:
        sig = inspect.signature(obj)
    except (ValueError, TypeError):
        return "(...)"
    parts, starred = [], False
    for p in sig.parameters.values():
        if p.name == "self":
            continue
        s = p.name
        if p.kind is p.VAR_POSITIONAL:
            s, starred = "*" + s, True
        elif p.kind is p.VAR_KEYWORD:
            s = "**" + s
        elif p.default is not p.empty:
            s += "=" + re.sub(r" at 0x[0-9a-f]+", "", repr(p.default))
        if p.kind is p.KEYWORD_ONLY and not starred:
            parts.append("*")
            starred = True
        parts.append(s)
    return "(" + ", ".join(parts) + ")"


def _describe(name: str, obj) -> list:
    if inspect.isclass(obj):
        lines = [f"class {name}{_sig(obj)}"]
        for mname, m in sorted(vars(obj).items()):
            if mname.startswith("_"):
                continue
            if isinstance(m, property):
                lines.append(f"  {name}.{mname} [property]")
            elif isinstance(m, staticmethod):
                lines.append(f"  {name}.{mname}"
                             f"{_sig(m.__func__)} [static]")
            elif isinstance(m, classmethod):
                lines.append(f"  {name}.{mname}"
                             f"{_sig(m.__func__)} [classmethod]")
            elif inspect.isfunction(m):
                lines.append(f"  {name}.{mname}{_sig(m)}")
        return lines
    if callable(obj):
        return [f"def {name}{_sig(obj)}"]
    return [f"{name} [{type(obj).__name__}]"]


def _exports(mod) -> list:
    if hasattr(mod, "__all__"):
        return sorted(mod.__all__)
    return sorted(n for n, v in vars(mod).items()
                  if not n.startswith("_") and not inspect.ismodule(v)
                  and n != "annotations")   # __future__ import leak


def build_surface() -> str:
    out = []
    for modname in PUBLIC_MODULES:
        mod = importlib.import_module(modname)
        out.append(f"[{modname}]")
        for name in _exports(mod):
            out.extend(_describe(name, getattr(mod, name)))
        out.append("")
    return "\n".join(out).rstrip() + "\n"


def test_api_surface_matches_snapshot():
    current = build_surface()
    if os.environ.get("REPRO_UPDATE_API_SURFACE"):
        with open(SNAPSHOT, "w") as f:
            f.write(current)
        return
    assert os.path.exists(SNAPSHOT), (
        f"missing {SNAPSHOT}; generate it with "
        "REPRO_UPDATE_API_SURFACE=1")
    with open(SNAPSHOT) as f:
        committed = f.read()
    if current != committed:
        import difflib
        diff = "\n".join(difflib.unified_diff(
            committed.splitlines(), current.splitlines(),
            "api_surface.txt (committed)", "api_surface (current)",
            lineterm=""))
        raise AssertionError(
            "public API surface drifted from the committed snapshot.\n"
            "If intentional, regenerate with "
            "REPRO_UPDATE_API_SURFACE=1 and commit the diff.\n" + diff)
