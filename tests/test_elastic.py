"""Elastic scaling & failure handling."""

import numpy as np
import pytest

from repro.training.elastic import HostMonitor, largest_rect


def test_monitor_detects_dead_hosts():
    m = HostMonitor(n_hosts=4, timeout_s=10.0)
    for h in range(4):
        m.beat(h, now=0.0)
    m.beat(0, now=20.0)
    m.beat(1, now=20.0)
    assert set(m.dead_hosts(now=25.0)) == {2, 3}


def test_monitor_flags_stragglers():
    m = HostMonitor(n_hosts=3, slow_factor=2.0)
    for h, t in [(0, 1.0), (1, 1.1), (2, 5.0)]:
        for _ in range(5):
            m.beat(h, now=0.0, step_time=t)
    assert m.slow_hosts() == [2]


def test_largest_rect_keeps_tp_degree():
    assert largest_rect(256, 16) == (16, 16)
    assert largest_rect(255, 16) == (15, 16)   # one host lost -> DP shrinks
    assert largest_rect(17, 16) == (1, 16)


def test_recover_reshards_onto_smaller_mesh(tmp_path):
    """Full elastic loop on host devices: checkpoint on a (4,2) mesh,
    lose devices, restore onto (2,2) and keep training."""
    import subprocess
    import sys
    import os
    ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.data import MarkovLMTask
from repro.training.optim import adamw, constant_schedule
from repro.training.step import (make_train_step, init_train_state,
                                 train_state_logical_axes, abstract_train_state)
from repro.training.checkpoint import CheckpointManager
from repro.training.elastic import shrink_mesh, recover
from repro.sharding import make_parallel, tree_specs, tree_shardings

cfg = reduced_config("stablelm_1_6b")
opt = adamw(constant_schedule(1e-3))
task = MarkovLMTask(vocab=cfg.vocab, seed=0)
devs = jax.devices()
mesh = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
par = make_parallel(mesh, "train", seq_shard=False)
specs = tree_specs(train_state_logical_axes(cfg, opt), par, cfg)
sh = tree_shardings(specs, mesh)
step = jax.jit(make_train_step(cfg, opt, par), in_shardings=(sh, None),
               out_shardings=(sh, None))
state = jax.device_put(init_train_state(cfg, opt, jax.random.PRNGKey(0)), sh)
mgr = CheckpointManager("{tmp_path}", keep_n=2, save_interval=2)
with mesh:
    for i in range(4):
        b = task.batch(i, 8, 16)
        state, m = step(state, dict(inputs=jnp.asarray(b["inputs"]),
                                    labels=jnp.asarray(b["labels"])))
        mgr.maybe_save(jax.device_get(state), i + 1)

# "lose" half the devices -> rebuild mesh, restore, keep stepping
new_mesh, dropped = shrink_mesh(devs[:4], model_degree=2)
assert new_mesh.devices.shape == (2, 2)
par2 = make_parallel(new_mesh, "train", seq_shard=False)
specs2 = tree_specs(train_state_logical_axes(cfg, opt), par2, cfg)
state2, step_no = recover(mgr, abstract_train_state(cfg, opt), new_mesh, specs2)
sh2 = tree_shardings(specs2, new_mesh)
step2 = jax.jit(make_train_step(cfg, opt, par2), in_shardings=(sh2, None),
                out_shardings=(sh2, None))
with new_mesh:
    b = task.batch(step_no, 8, 16)
    state2, m2 = step2(state2, dict(inputs=jnp.asarray(b["inputs"]),
                                    labels=jnp.asarray(b["labels"])))
assert np.isfinite(float(m2["loss"]))
assert int(state2["step"]) == step_no + 1
print("ELASTIC_OK", step_no)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420, cwd=ROOT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
