"""Sharded execution == single-device reference (subprocess: needs 8
host devices). Covers the shard_map MoE layouts (ep/tp/ep2d/tp2d), the
distributed flash-decode (incl. ring-window wrap), and sharded train
steps producing finite losses identical in expectation."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import init_params, forward, decode_step
from repro.models.model import prefill
from repro.sharding import make_parallel
from repro.launch.mesh import make_mesh

# 1. MoE sharded layouts vs dense reference (ample capacity => exact).
for arch, modes in [("qwen3_moe_235b", ["ep", "ep2d"]),
                    ("grok_1_314b", ["tp", "tp2d"])]:
    cfg = reduced_config(arch)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
    ref, _ = forward(params, x, cfg)
    mesh = make_mesh((2, 2), ("data", "model"))
    for mode in modes:
        par = make_parallel(mesh, "serve", moe_mode=mode)
        with mesh:
            out, _ = jax.jit(lambda p, t: forward(p, t, cfg, parallel=par))(
                params, x)
        err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
        assert err < 2e-3, (arch, mode, err)
print("MOE_OK")

# 2. Distributed flash-decode (kv < tp) vs reference, with window wrap.
for arch in ("yi_9b", "gemma2_9b", "recurrentgemma_2b"):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 4, 24
    x = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full, _ = forward(params, x, cfg)
    mesh = make_mesh((2, 4), ("data", "model"))
    par = make_parallel(mesh, "serve")
    assert cfg.n_kv_heads % 4 != 0  # flash-decode path engaged
    with mesh:
        _, cache = jax.jit(lambda p, t: prefill(
            p, t, cfg, max_seq=32, parallel=par))(params, x[:, :8])
        dec = jax.jit(lambda p, t, c, pos: decode_step(
            p, t, c, pos, cfg, parallel=par))
        pos = 8
        maxerr = 0.0
        for t in range(8, T):
            logits, cache = dec(params, x[:, t:t+1], cache, jnp.int32(pos))
            pos += 1
            maxerr = max(maxerr, float(np.abs(
                np.asarray(logits[:, 0]) - np.asarray(full[:, t])).max()))
    assert maxerr < 5e-3, (arch, maxerr)
print("DECODE_OK")

# 3. Sharded train step: finite loss, step increments, state stays sharded.
from repro.sharding import tree_specs, tree_shardings
from repro.training.optim import adamw, constant_schedule, mixed_precision
from repro.training.step import (make_train_step, init_train_state,
                                 train_state_logical_axes)
cfg = reduced_config("gemma2_9b").with_runtime(param_dtype="float32")
opt = mixed_precision(adamw(constant_schedule(1e-3)))
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
par = make_parallel(mesh, "train")
specs = tree_specs(train_state_logical_axes(cfg, opt), par, cfg)
sh = tree_shardings(specs, mesh)
step = jax.jit(make_train_step(cfg, opt, par), in_shardings=(sh, None),
               out_shardings=(sh, None))
state = jax.device_put(init_train_state(cfg, opt, jax.random.PRNGKey(0)), sh)
with mesh:
    for i in range(3):
        b = {"inputs": jax.random.randint(jax.random.PRNGKey(i), (8, 16),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(i + 9), (8, 16),
                                          0, cfg.vocab)}
        state, m = step(state, b)
assert np.isfinite(float(m["loss"]))
assert int(state["step"]) == 3
print("TRAIN_OK")
"""


@pytest.mark.slow
def test_sharded_execution_matches_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env=env, timeout=560, cwd=ROOT)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    for marker in ("MOE_OK", "DECODE_OK", "TRAIN_OK"):
        assert marker in r.stdout
