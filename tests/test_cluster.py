"""Multi-tenant cluster control plane (serving/cluster.py, DESIGN.md §16):
tenant workloads, cluster-wide placement/eviction, scaling, shedding,
hedging — and the replay pins: the placement/eviction/scale/shed event
log must reproduce bit-for-bit from a captured trace.
"""

import numpy as np
import pytest

from repro.configs.paper_zoo import (TENANT_MIXES, TENANT_SLA_CLASSES,
                                     paper_profiles)
from repro.serving.batching import Request
from repro.serving.cluster import (Cluster, ClusterPlacer, TenantSpec,
                                   capture_run, make_tenant_workload,
                                   make_tenants,
                                   requests_from_cluster_trace,
                                   replay_events)
from repro.serving.stack import SimReplicaStack

MODELS = ["mobilenetv1_025", "mobilenetv1_10", "inceptionv3"]


def _replicas(n=3, seed=100):
    return [SimReplicaStack(paper_profiles(MODELS), seed=seed + i,
                            name=f"r{i}") for i in range(n)]


def _cluster(mix="consumer_burst", budget=int(250e6), **kw):
    return Cluster(_replicas(), mix, memory_budget_bytes=budget, **kw)


# -- tenants and workloads -------------------------------------------------

def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="unknown SLA class"):
        TenantSpec("t", "platinum")
    with pytest.raises(ValueError, match="weight must be positive"):
        TenantSpec("t", "gold", weight=0.0)
    with pytest.raises(ValueError, match="unknown tenant mix"):
        make_tenants("nope")
    with pytest.raises(ValueError, match="duplicate tenant names"):
        make_tenants([TenantSpec("t", "gold"), TenantSpec("t", "bronze")])


def test_tenant_mixes_registry():
    for mix in TENANT_MIXES:
        tenants = make_tenants(mix)
        assert len(tenants) >= 2
        for t in tenants:
            assert t.sla_class in TENANT_SLA_CLASSES
            assert t.t_sla > 0


def test_workload_deterministic_and_tagged():
    a = make_tenant_workload("consumer_burst", n_requests=200,
                             rate_hz=20.0, seed=3)
    b = make_tenant_workload("consumer_burst", n_requests=200,
                             rate_hz=20.0, seed=3)
    assert [(r.arrival, r.device_id, r.t_input_ms) for r in a] \
        == [(r.arrival, r.device_id, r.t_input_ms) for r in b]
    assert [r.rid for r in a] == list(range(len(a)))
    assert all(r.arrival <= s.arrival for r, s in zip(a, a[1:]))
    tenants = {t.name: t for t in make_tenants("consumer_burst")}
    for r in a:
        assert r.tenant in tenants
        assert r.device_id.startswith(r.tenant + "/")
        assert r.sla_ms == tenants[r.tenant].t_sla
    c = make_tenant_workload("consumer_burst", n_requests=200,
                             rate_hz=20.0, seed=4)
    assert [r.arrival for r in a] != [r.arrival for r in c]


def test_workload_bursts_cluster_around_phase():
    # burst=4 in a 0.25-wide window centred at phase: the peak quarter
    # of the horizon must hold well over its uniform share.
    reqs = make_tenant_workload(
        [dict(tenant="t", sla_class="bronze", phase=0.5, burst=4.0)],
        n_requests=400, rate_hz=40.0, seed=0)
    horizon = 400 / 40.0 * 1000.0
    arr = np.array([r.arrival for r in reqs])
    frac = ((np.abs(arr / horizon - 0.5) < 0.125).mean())
    assert frac > 0.4          # uniform share would be 0.25


# -- cluster-wide placement ------------------------------------------------

def test_placer_evicts_global_lru():
    reps = _replicas(2)
    placer = ClusterPlacer(reps, memory_budget_bytes=int(120e6))
    # Heat inceptionv3 (95MB) on r0 at t=0, then on r1 at t=1: the
    # global budget fits only one copy, so r0's (older) is evicted.
    placer.ensure_hot(reps[0], "inceptionv3", 0.0)
    assert reps[0].router.zoo.entries["inceptionv3"].hot
    placer.ensure_hot(reps[1], "inceptionv3", 1.0)
    assert not reps[0].router.zoo.entries["inceptionv3"].hot
    assert reps[1].router.zoo.entries["inceptionv3"].hot
    kinds = [(e["kind"], e["replica"], e["model"]) for e in placer.events]
    assert kinds == [("place", 0, "inceptionv3"),
                     ("evict", 0, "inceptionv3"),
                     ("place", 1, "inceptionv3")]


def test_placer_never_evicts_the_copy_being_heated():
    reps = _replicas(1)
    placer = ClusterPlacer(reps, memory_budget_bytes=int(1e6))
    # Budget below the model size: nothing else to evict, model still
    # heats (the zoo's over-budget escape hatch).
    placer.ensure_hot(reps[0], "inceptionv3", 0.0)
    assert reps[0].router.zoo.entries["inceptionv3"].hot
    assert not any(e["kind"] == "evict" for e in placer.events)


# -- cluster behaviour -----------------------------------------------------

def _run(mix="consumer_burst", n=800, rate=40.0, **kw):
    reqs = make_tenant_workload(mix, n_requests=n, rate_hz=rate, seed=0)
    cl = _cluster(mix, **kw)
    cl.run(reqs)
    return cl


def test_cluster_serves_and_scales():
    cl = _run()
    s = cl.metrics.summary()
    assert s["served"] == 800
    assert 0.0 < s["attainment"] <= 1.0
    kinds = {e["kind"] for e in cl.events}
    assert "place" in kinds
    assert "evict" in kinds          # budget < 3 full hot sets
    assert "scale_up" in kinds       # bursts exceed one replica
    # Scale events carry the new active count within bounds.
    for e in cl.events:
        if e["kind"].startswith("scale"):
            assert 1 <= e["n_active"] <= 3


def test_cluster_sheds_to_on_device_under_overload():
    cl = _run(rate=80.0)             # 2x the benchmark rate: saturate
    sheds = [e for e in cl.events if e["kind"] == "shed"]
    assert sheds
    fallback_rows = [r for r in cl.metrics.records if r["fallback"]]
    assert len(fallback_rows) == len(sheds)
    assert all(r["model"] == "<on-device>" for r in fallback_rows)
    # Only devices that CAN serve locally shed.
    assert all(cl.on_device_ms[e["device"]] > 0 for e in sheds)


def test_cluster_hedges_degraded_requests():
    cl = _run("enterprise_degraded")     # outage fleet: degraded modes
    s = cl.metrics.summary()
    assert s.get("hedges", 0) > 0
    hedged = [r for r in cl.metrics.records if r["hedged"]]
    assert all(r["replica"] is not None for r in hedged)


def test_cluster_rows_tag_tenant_and_replica():
    cl = _run(n=200)
    per = cl.metrics.per_tenant()
    assert set(per) == {t.name for t in
                        make_tenants("consumer_burst")}
    assert sum(b["served"] for b in per.values()) == 200
    for r in cl.metrics.records:
        assert r["tenant"]
        if not r["fallback"]:
            assert r["replica"] in (0, 1, 2)


def test_cluster_nests_as_a_stack():
    # A cluster of clusters — the protocol composes.
    inner = [_cluster(min_active=1) for _ in range(2)]
    outer = Cluster(inner, "consumer_burst")
    req = Request(arrival=0.0, rid=0, prompt=np.zeros(4, np.int32),
                  max_new_tokens=2, sla_ms=1e6, t_input_ms=5.0,
                  device_id="gold-flagship/pixel7", tenant="gold-flagship")
    out = outer.submit(req)
    outer.drain()
    assert out.ok is not None
    assert outer.metrics.served == 1


# -- capture / replay pins -------------------------------------------------

def test_capture_replay_bit_for_bit():
    reqs = make_tenant_workload("consumer_burst", n_requests=600,
                                rate_hz=40.0, seed=0)
    mk = lambda: _cluster("consumer_burst")
    tr = capture_run(mk(), reqs)
    assert len(tr) == 600
    assert tr.meta["cluster_events"]
    assert replay_events(tr, mk) is True


def test_replay_detects_divergence():
    reqs = make_tenant_workload("consumer_burst", n_requests=400,
                                rate_hz=40.0, seed=0)
    tr = capture_run(_cluster("consumer_burst"), reqs)
    # A differently-budgeted cluster makes different decisions.
    assert replay_events(
        tr, lambda: _cluster("consumer_burst",
                             budget=int(140e6))) is False


def test_requests_round_trip_through_trace():
    reqs = make_tenant_workload("enterprise_degraded", n_requests=300,
                                rate_hz=40.0, seed=1)
    tr = capture_run(_cluster("enterprise_degraded"), reqs)
    back = requests_from_cluster_trace(tr)
    orig = sorted(reqs, key=lambda r: r.arrival)
    assert [(r.device_id, r.tenant, r.sla_ms) for r in back] \
        == [(r.device_id, r.tenant, r.sla_ms) for r in orig]
    np.testing.assert_allclose([r.arrival for r in back],
                               [r.arrival for r in orig], rtol=1e-6)
    np.testing.assert_allclose([r.t_input_ms for r in back],
                               [r.t_input_ms for r in orig], rtol=1e-6)


def test_cluster_determinism_pin():
    # Same replicas, same workload, same config -> identical metrics
    # rows and event log (the determinism the replay pin rests on).
    def go():
        reqs = make_tenant_workload("consumer_burst", n_requests=300,
                                    rate_hz=40.0, seed=0)
        cl = _cluster("consumer_burst")
        cl.run(reqs)
        return cl
    a, b = go(), go()
    assert a.events == b.events
    assert a.metrics.records == b.metrics.records
