"""Serving simulator + zoo behaviour (paper §5.2 claims, directional)."""

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.core.zoo import ModelZoo
from repro.serving.simulator import SimConfig, simulate, sla_sweep
from repro.serving.network import NetworkModel, resize_decision


def test_cnnselect_attains_earlier_than_greedy():
    """Paper Fig 13: CNNSelect meets SLAs in a regime where greedy fails."""
    profs = paper_profiles()
    for sla in (200, 250):
        ours = simulate(profs, SimConfig(t_sla=sla, n_requests=1500, seed=2))
        greedy = simulate(profs, SimConfig(t_sla=sla, n_requests=1500,
                                           policy="greedy", seed=2))
        assert ours.attainment > greedy.attainment + 0.1, sla


def test_cnnselect_converges_to_greedy_accuracy():
    profs = paper_profiles()
    ours = simulate(profs, SimConfig(t_sla=1200, n_requests=1500, seed=2))
    greedy = simulate(profs, SimConfig(t_sla=1200, n_requests=1500,
                                       policy="greedy", seed=2))
    assert ours.accuracy > greedy.accuracy - 0.02
    assert ours.attainment > 0.97


def test_accuracy_monotone_in_sla():
    profs = paper_profiles()
    res = sla_sweep(profs, [150, 300, 600, 1200], n_requests=1000, seed=0)
    accs = [r.accuracy for r in res]
    assert accs == sorted(accs) or max(
        a - b for a, b in zip(accs, accs[1:])) < 0.02


def test_oracle_dominates_all():
    profs = paper_profiles()
    for policy in ("cnnselect", "greedy"):
        r = simulate(profs, SimConfig(t_sla=300, n_requests=1000,
                                      policy=policy, seed=1))
        o = simulate(profs, SimConfig(t_sla=300, n_requests=1000,
                                      policy="oracle", seed=1))
        assert o.attainment >= r.attainment - 1e-9


def test_selection_histogram_shifts_with_sla():
    profs = paper_profiles()
    names = [p.name for p in profs]
    tight = simulate(profs, SimConfig(t_sla=160, n_requests=1500, seed=0))
    loose = simulate(profs, SimConfig(t_sla=2000, n_requests=1500, seed=0))
    h_t = tight.selection_histogram(names)
    h_l = loose.selection_histogram(names)
    # tight SLAs favour sub-30ms models; loose favour the accurate ones
    fast = [p.name for p in profs if p.mu < 30]
    slow_acc = [p.name for p in profs if p.accuracy > 0.79]
    assert sum(h_t[n] for n in fast) > 0.7
    assert sum(h_l[n] for n in slow_acc) > 0.5


def test_cold_starts_penalize_unwarmed_zoo():
    profs = paper_profiles()
    warm = simulate(profs, SimConfig(t_sla=400, n_requests=400, seed=0,
                                     prewarm=True))
    cold = simulate(profs, SimConfig(t_sla=400, n_requests=400, seed=0,
                                     prewarm=False))
    assert cold.cold_starts > 0
    assert warm.cold_starts == 0
    assert cold.mean_latency >= warm.mean_latency


def test_zoo_lru_eviction(rng):
    profs = paper_profiles()
    total = sum(p.size_bytes for p in profs)
    zoo = ModelZoo(memory_budget_bytes=total // 3)
    for p in profs:
        zoo.register(p)
    now = 0.0
    for i, p in enumerate(profs):
        zoo.ensure_hot(p.name, now=float(i))
    # budget respected up to the (unavoidable) size of the newest model
    biggest = max(p.size_bytes for p in profs)
    assert zoo.hot_bytes() <= max(total // 3, biggest)
    # the most-recently-used model must still be hot
    assert zoo.entries[profs[-1].name].hot
    assert sum(e.evictions for e in zoo.entries.values()) > 0


def test_queueing_increases_latency():
    profs = paper_profiles()
    free = simulate(profs, SimConfig(t_sla=400, n_requests=800, seed=0))
    loaded = simulate(profs, SimConfig(t_sla=400, n_requests=800, seed=0,
                                       arrival_rate_hz=40.0, n_servers=1))
    assert loaded.p95_latency >= free.p95_latency


def test_hedging_reduces_tail():
    profs = paper_profiles()
    base = simulate(profs, SimConfig(t_sla=400, n_requests=800, seed=0,
                                     arrival_rate_hz=50.0, n_servers=4))
    hedged = simulate(profs, SimConfig(t_sla=400, n_requests=800, seed=0,
                                       arrival_rate_hz=50.0, n_servers=4,
                                       hedge="p95"))
    assert hedged.p95_latency <= base.p95_latency + 1e-6


def test_estimator_recovers_attainment_under_regime_shift():
    """Time-varying acceptance bar (ISSUE 2): under the wifi->lte Markov
    handoff, cnnselect budgeting from the EWMA estimator attains at
    least the stationary-mean-budget variant, and beats greedy."""
    profs = paper_profiles()
    kw = dict(t_sla=320.0, n_requests=2500, network="wifi_lte_handoff")
    ewma = simulate(profs, SimConfig(**kw, t_estimator="ewma:0.2", seed=3))
    mean = simulate(profs, SimConfig(**kw, t_estimator="mean", seed=3))
    greedy = simulate(profs, SimConfig(**kw, policy="greedy", seed=3))
    assert ewma.attainment >= mean.attainment
    assert ewma.attainment > greedy.attainment
    # Per-regime reporting labels both states and covers the trace.
    per = ewma.per_regime()
    assert set(per) == {"campus_wifi", "lte"}
    assert sum(v["share"] for v in per.values()) == pytest.approx(1.0)


def test_trace_replay_network_in_simulator():
    profs = paper_profiles()
    r = simulate(profs, SimConfig(t_sla=320.0, n_requests=1000,
                                  network="trace:wifi_lte_step",
                                  t_estimator="ewma:0.2", seed=0))
    assert 0.0 < r.attainment <= 1.0
    assert r.regimes is not None and len(r.regimes) == 1000


def test_network_models_ordering(rng):
    wifi = NetworkModel.named("campus_wifi").sample_t_input(rng, 4000)
    hot = NetworkModel.named("cellular_hotspot").sample_t_input(rng, 4000)
    assert hot.mean() > wifi.mean() * 1.5  # paper: ~2x WiFi
    assert (wifi > 0).all()


def test_resize_decision_matches_paper():
    # paper: images 1..5 (<=226KB) upload directly; large images resize
    assert not resize_decision(172.0)
    assert resize_decision(2000.0)
