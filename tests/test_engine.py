"""Scan-engine equivalence: `engine="scan"` must reproduce the python
reference loop decision-for-decision (DESIGN.md §13) — selections,
modes, and switch events exactly; latencies and estimator-derived
floats to 1e-9 relative.

The matrix covers every registry policy x {static estimator, adaptive
controller} x {no fleet, mixed_fleet, lte_outage_fleet, ArrayFleet},
plus the estimator-lag ring, global scope, open-loop queueing, and the
sharded program (skipped unless the host exposes 2+ XLA devices — set
REPRO_HOST_DEVICES=2 or more to opt in, as the CI fast job does)."""

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import policy_names
from repro.serving.fleet import ArrayFleet, EstimatorBank
from repro.serving.simulator import SimConfig, simulate

N = 900
T_SLA = 350.0


@pytest.fixture(scope="module")
def profiles():
    return paper_profiles()


def run_both(profiles, **kw):
    out = {}
    for engine in ("python", "scan"):
        cfg = SimConfig(t_sla=T_SLA, n_requests=N, seed=5, engine=engine,
                        **kw)
        out[engine] = simulate(profiles, cfg)
    return out["python"], out["scan"]


def assert_equivalent(a, b):
    assert list(a.selections) == list(b.selections)
    np.testing.assert_allclose(np.asarray(a.latencies),
                               np.asarray(b.latencies), rtol=1e-9)
    assert a.hedges == b.hedges
    assert a.fallbacks == b.fallbacks
    assert a.cold_starts == b.cold_starts
    assert a.attainment == pytest.approx(b.attainment, rel=1e-12)
    assert a.accuracy == pytest.approx(b.accuracy, rel=1e-9)
    ma = [] if a.modes is None else list(a.modes)
    mb = [] if b.modes is None else list(b.modes)
    assert ma == mb
    ea = a.switch_events or []
    eb = b.switch_events or []
    assert len(ea) == len(eb)
    for x, y in zip(ea, eb):
        for k in ("request", "device", "from", "to", "alarm"):
            assert x[k] == y[k]
        for k in ("ref", "level"):
            assert x[k] == pytest.approx(y[k], rel=1e-6)


def _policy_kw(name, profiles):
    return {"policy": f"static:{profiles[0].name}"
            if name == "static" else name}


FLEETS = [None, "mixed_fleet", "lte_outage_fleet"]


@pytest.mark.parametrize("fleet", FLEETS,
                         ids=["nofleet", "mixed", "lte_outage"])
@pytest.mark.parametrize("policy", policy_names())
def test_static_plan_matches(profiles, policy, fleet):
    a, b = run_both(profiles, fleet=fleet, t_estimator="ewma:0.2",
                    **_policy_kw(policy, profiles))
    assert_equivalent(a, b)


@pytest.mark.parametrize("fleet", FLEETS,
                         ids=["nofleet", "mixed", "lte_outage"])
@pytest.mark.parametrize("policy", policy_names())
def test_controller_plan_matches(profiles, policy, fleet):
    a, b = run_both(profiles, fleet=fleet, controller="reactive",
                    **_policy_kw(policy, profiles))
    assert_equivalent(a, b)


@pytest.mark.parametrize("spec", ["observed", "mean", "ewma:0.35",
                                  "pctl:90", "pctl:50"])
def test_estimator_kinds_match(profiles, spec):
    a, b = run_both(profiles, fleet=ArrayFleet(150, seed=2),
                    policy="greedy_nw", t_estimator=spec)
    assert_equivalent(a, b)


def test_estimator_lag_and_global_scope_match(profiles):
    a, b = run_both(profiles, fleet="lte_outage_fleet",
                    policy="cnnselect", t_estimator="pctl:75",
                    estimator_lag=2)
    assert_equivalent(a, b)
    a, b = run_both(profiles, fleet="mixed_fleet", policy="greedy_nw",
                    t_estimator="ewma:0.2", estimator_scope="global")
    assert_equivalent(a, b)


def test_open_loop_hedging_matches(profiles):
    a, b = run_both(profiles, fleet="lte_outage_fleet",
                    controller="reactive", policy="cnnselect",
                    arrival_rate_hz=500.0, n_servers=2)
    assert_equivalent(a, b)


def test_array_fleet_controller_matches(profiles):
    a, b = run_both(profiles, fleet=ArrayFleet(200, seed=9),
                    controller="ph_reactive", policy="greedy_nw")
    assert_equivalent(a, b)
    assert (b.switch_events or []) != []      # regime shifts do fire


def test_scan_rejects_memory_budget(profiles):
    cfg = SimConfig(t_sla=T_SLA, n_requests=10, engine="scan",
                    memory_budget_bytes=1 << 30)
    with pytest.raises(ValueError, match="memory budget"):
        simulate(profiles, cfg)


def test_unknown_engine_rejected(profiles):
    cfg = SimConfig(t_sla=T_SLA, n_requests=10, engine="fortran")
    with pytest.raises(ValueError, match="engine"):
        simulate(profiles, cfg)


def test_sharded_program_bitwise_identical(profiles):
    import jax
    if jax.local_device_count() < 2:
        pytest.skip("needs 2+ XLA host devices "
                    "(run with REPRO_HOST_DEVICES=2 or more)")
    out = {}
    for shards in (1, 2):
        cfg = SimConfig(t_sla=T_SLA, n_requests=N, seed=5, engine="scan",
                        fleet=ArrayFleet(150, seed=2),
                        controller="reactive", policy="greedy_nw",
                        shards=shards)
        out[shards] = simulate(profiles, cfg)
    a, b = out[1], out[2]
    assert list(a.selections) == list(b.selections)
    assert np.array_equal(np.asarray(a.latencies),
                          np.asarray(b.latencies))
    assert list(a.modes) == list(b.modes)
    assert (a.switch_events or []) == (b.switch_events or [])


def test_estimator_bank_parses_spec_once(monkeypatch):
    """Regression: the bank must parse its spec string exactly once and
    stamp per-device estimators from the parsed factory — re-parsing on
    every cold device is an O(fleet) cost the scan engine exposed."""
    import repro.serving.fleet as fleet_mod
    calls = []
    real = fleet_mod.estimator_factory

    def counting(spec, **kw):
        calls.append(spec)
        return real(spec, **kw)

    monkeypatch.setattr(fleet_mod, "estimator_factory", counting)
    bank = EstimatorBank("ewma:0.3", default_prior=50.0)
    for key in range(64):
        bank.observe(key, 10.0 + key)
        bank.estimate(key)
    assert calls == ["ewma:0.3"]
    assert len(bank.keys()) == 64
