"""Real engine + continuous batcher + CNNSelect server (CPU execution)."""

import numpy as np
import pytest

import jax

from repro.configs import reduced_config
from repro.data import CopyTask
from repro.models import init_params
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.engine import InferenceEngine
from repro.serving.server import CNNSelectServer, ServedModel


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, batch_size=4, max_seq=64)
    eng.warmup(prompt_len=8)
    return eng


def test_engine_generate_deterministic(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.cfg.vocab, (4, 8), dtype=np.int32)
    a = engine.generate(prompts, 6)
    b = engine.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 6)


def test_engine_profile_measures(engine):
    p = engine.measured_profile(prompt_len=8, n_tokens=4, reps=2)
    assert p["mu"] > 0 and p["sigma"] >= 0


def test_continuous_batcher_slots():
    b = ContinuousBatcher(batch_size=2, prompt_len=4)
    reqs = [Request(arrival=float(i), rid=i,
                    prompt=np.array([1, 2, 3, 4]), max_new_tokens=2)
            for i in range(3)]
    for r in reqs:
        b.submit(r)
    g = b.form_group(now=10.0)
    assert len(g) == 2 and b.n_active == 2
    assert b.form_group(now=10.0) is None  # group must drain first
    toks = np.array([7, 8])
    b.record_tokens(toks, now=11.0)
    b.record_tokens(toks, now=12.0)
    assert b.n_active == 0 and len(b.done) == 2
    g2 = b.form_group(now=12.0)
    assert len(g2) == 1  # third request now scheduled
    assert b.done[0].tokens == [7, 7]


def drive_batcher(batch_size, prompt_len, specs, budget=None):
    """Drive a ContinuousBatcher to completion through the loop's
    schedule (seed group -> decode round -> backfill joins), checking
    slot invariants every round. specs: [(arrival, max_new_tokens)].
    Shared with the hypothesis property suite (test_properties)."""
    b = ContinuousBatcher(batch_size, prompt_len)
    for i, (arr, mnt) in enumerate(specs):
        b.submit(Request(arrival=float(arr), rid=i,
                         prompt=np.array([1, 2, 3]),
                         max_new_tokens=int(mnt)))
    now, rounds = 0.0, 0
    while b.has_work:
        rounds += 1
        assert rounds < 10 * sum(m for _, m in specs) + 100, \
            "batcher failed to drain"
        if b.n_active == 0:
            now = max(now, b.queue[0].arrival)
            assert b.form_group(now) is not None
        live = [r.rid for r in b.slots if r is not None]
        assert len(live) == len(set(live))          # one slot per request
        b.record_tokens(np.full(batch_size, 7), now)
        for slot, r in b.backfill(now, budget):
            assert r.arrival <= now                 # no time travel
            assert b.slots[slot] is r
            if budget is not None:
                assert r.max_new_tokens <= budget   # budget respected
            b.record_token(slot, 7, now)            # first token at join
        now += 1.0
    # Every request retired exactly once with its full token quota.
    assert sorted(r.rid for r in b.done) == list(range(len(specs)))
    for r in b.done:
        assert len(r.tokens) == r.max_new_tokens
        assert r.start_exec >= r.arrival
        assert r.finish >= r.start_exec
    return b


def test_batcher_retire_then_backfill_lifecycle():
    """Deterministic retire->backfill: r1 retires after one token and
    r2 joins its exact slot mid-group, while r0 keeps decoding."""
    b = ContinuousBatcher(batch_size=2, prompt_len=4)
    r0, r1, r2 = (Request(arrival=0.0, rid=i, prompt=np.array([1, 2]),
                          max_new_tokens=m)
                  for i, m in [(0, 3), (1, 1), (2, 2)])
    for r in (r0, r1, r2):
        b.submit(r)
    assert [r.rid for r in b.form_group(0.0)] == [0, 1]
    b.record_tokens(np.array([5, 6]), now=1.0)      # r1 retires -> slot 1
    assert b.slots[1] is None and b.done == [r1]
    joins = b.backfill(2.0)
    assert joins == [(1, r2)] and b.slots[1] is r2  # mid-group join
    assert r2.start_exec == 2.0
    b.record_token(1, 7, now=2.0)                   # join-round token
    b.record_tokens(np.array([5, 8]), now=3.0)      # r2 hits quota
    assert b.done == [r1, r2] and b.n_active == 1
    b.record_tokens(np.array([5, 9]), now=4.0)      # stale slot ignored
    assert r2.tokens == [7, 8]
    assert r0.tokens == [5, 5, 5] and b.done == [r1, r2, r0]
    assert not b.has_work


def test_batcher_backfill_defers_over_budget():
    """A joiner needing more decode steps than the engine's remaining
    cache rows must wait for the next fresh group, without losing its
    queue position or blocking smaller requests behind it."""
    b = ContinuousBatcher(batch_size=2, prompt_len=4)
    big = Request(arrival=0.0, rid=0, prompt=np.array([1]),
                  max_new_tokens=8)
    small = Request(arrival=0.0, rid=1, prompt=np.array([1]),
                    max_new_tokens=2)
    live = Request(arrival=0.0, rid=2, prompt=np.array([1]),
                   max_new_tokens=4)
    b.submit(live)
    b.form_group(0.0)
    b.submit(big)
    b.submit(small)
    assert b.backfill(1.0, budget=3) == [(1, small)]
    assert b.queue[0] is big                        # deferred, not lost
    assert b.backfill(1.0, budget=3) == []


def test_batcher_drain_full_schedule():
    drive_batcher(2, 4, [(0, 3), (0, 1), (0, 2), (5, 2), (5, 4)])
    drive_batcher(3, 4, [(0, 2), (1, 5), (9, 1)], budget=6)


def test_batcher_pad_prompts():
    b = ContinuousBatcher(batch_size=3, prompt_len=5)
    b.submit(Request(arrival=0.0, rid=0, prompt=np.array([1, 2])))
    b.form_group(now=0.0)
    padded = b.pad_prompts()
    assert padded.shape == (3, 5)
    np.testing.assert_array_equal(padded[0, -2:], [1, 2])
    assert padded[1:].sum() == 0


def _mk_server(policy="cnnselect"):
    models = []
    for name, arch, acc in [("tiny", "stablelm_1_6b", 0.6),
                            ("small", "yi_9b", 0.9)]:
        cfg = reduced_config(arch)
        if name == "small":
            import dataclasses
            cfg = dataclasses.replace(cfg, n_layers=cfg.n_layers * 4,
                                      d_model=192, n_heads=8, head_dim=24,
                                      d_ff=512)
        params = init_params(cfg, jax.random.PRNGKey(1))
        eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
        models.append(ServedModel(name=name, engine=eng, accuracy=acc))
    srv = CNNSelectServer(models, t_threshold=40.0, policy=policy,
                          n_tokens=4)
    srv.profile_models(prompt_len=8, reps=5)
    return srv


@pytest.fixture(scope="module")
def server():
    return _mk_server()


def test_server_profiles_separate_models(server):
    profs = {p.name: p for p in server.current_profiles()}
    assert profs["small"].mu > profs["tiny"].mu  # bigger model is slower


def test_server_selects_by_budget(server):
    tiny_mu = server.store.mu_sigma("tiny")[0]
    small_mu = server.store.mu_sigma("small")[0]
    # budget below small's mu: must pick tiny
    tight = tiny_mu * 1.5 + 1.0
    picks = {server.select(t_sla=tight, t_input=0.0) for _ in range(20)}
    assert picks == {"tiny"}
    # generous budget: small must appear (and dominate the base choice)
    loose = small_mu * 4 + 100
    picks = [server.select(t_sla=loose, t_input=0.0) for _ in range(20)]
    assert "small" in picks


def test_server_handles_request_end_to_end(server):
    req = Request(arrival=0.0, rid=1,
                  prompt=np.arange(8, dtype=np.int32) % 50,
                  t_input_ms=5.0)
    rec = server.handle(req, t_sla=10_000.0)
    assert rec["model"] in ("tiny", "small")
    assert rec["mode"] == "static"
    assert len(rec["tokens"]) == 4
    assert server.metrics.served == 1
    s = server.metrics.summary()
    assert 0.0 <= s["attainment"] <= 1.0
    assert "by_mode" not in s           # static plane: no mode column


def test_server_adaptive_controller_issues_on_device_advisory(server):
    """The server drives the shared control plane (DESIGN.md §12):
    sustained degradation escalates the device's mode, and a degraded
    request whose estimated cloud path cannot meet the SLA while the
    device can serve locally is answered with an on-device advisory
    (no cloud execution)."""
    from repro.serving.control import ControlPlane

    saved_control, saved_metrics = server.control, server.metrics
    saved_od = server.on_device_ms
    try:
        server.control = ControlPlane(server.router,
                                      controller="reactive")
        server.on_device_ms = {"phone": 150.0}
        server.metrics = type(server.metrics)()
        rng = np.random.default_rng(0)
        prompt = np.arange(8, dtype=np.int32) % 50
        # Warm stationary traffic, then a sustained collapse: uploads
        # so slow that 2*T_input alone blows the SLA.
        recs = []
        for i in range(40):
            t_in = 5.0 if i < 20 else 500.0
            recs.append(server.handle(
                Request(arrival=float(i), rid=i, prompt=prompt,
                        t_input_ms=t_in, device_id="phone"),
                t_sla=400.0))
        modes = [r["mode"] for r in recs]
        assert modes[0] == "stationary" and modes[-1] == "degraded"
        advisories = [r for r in recs if r["model"] == "<on-device>"]
        assert advisories and advisories[-1]["ok"]   # 150ms <= 400ms
        s = server.metrics.summary()
        assert s["by_mode"]["degraded"]["served"] >= 1
        assert s["fallbacks"] == len(advisories)
    finally:
        server.control, server.metrics = saved_control, saved_metrics
        server.on_device_ms = saved_od
