"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps
(assignment: sweep shapes/dtypes and assert_allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.quant import quantize_int8

SHAPES = [
    # (B, Hq, KV, T, hd, window, cap)
    (1, 2, 2, 32, 16, 0, 0.0),
    (2, 4, 2, 64, 16, 0, 0.0),
    (1, 4, 1, 32, 8, 16, 0.0),     # MQA + window
    (2, 8, 2, 48, 32, 0, 50.0),    # softcap
    (1, 2, 2, 40, 64, 24, 30.0),   # window + softcap
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_vs_ref(shape, dtype, rng):
    B, Hq, KV, T, hd, win, cap = shape
    q = jnp.asarray(rng.normal(size=(B, T, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    out = ops.flash_attention_btHd(q, k, v, window=win, softcap=cap,
                                   block_q=16, block_k=16)
    ref = R.flash_attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                                jnp.moveaxis(v, 2, 1), window=win, cap=cap)
    ref = jnp.moveaxis(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_nonmultiple_lengths(rng):
    """Padding path: T, S not multiples of the block size."""
    B, Hq, KV, T, hd = 1, 2, 1, 37, 16
    q = jnp.asarray(rng.normal(size=(B, T, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    out = ops.flash_attention_btHd(q, k, v, block_q=16, block_k=16)
    ref = R.flash_attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                                jnp.moveaxis(v, 2, 1))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(ref, 1, 2)),
                               atol=3e-5)


@pytest.mark.parametrize("ring", [False, True])
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_attention_vs_ref(ring, dtype, rng):
    B, Hq, KV, S, hd = 2, 4, 2, 64, 16
    cache_pos = 50
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    if ring:
        # ring layout: slot s holds position (cache_pos - window + ...) etc.
        pos = jnp.asarray((np.arange(S) + 17) % 61, jnp.int32)
        pos = jnp.where(pos <= cache_pos, pos, -1)
    else:
        pos = jnp.asarray(np.where(np.arange(S) <= cache_pos,
                                   np.arange(S), -1), jnp.int32)
    out = ops.decode_attention(q, k, v, pos, jnp.int32(cache_pos),
                               block_s=16)
    ref = R.decode_attention_ref(q[:, 0], jnp.moveaxis(k, 2, 1),
                                 jnp.moveaxis(v, 2, 1), pos, cache_pos)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_decode_attention_window(rng):
    B, Hq, KV, S, hd = 1, 2, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.asarray(np.arange(S), jnp.int32)
    out = ops.decode_attention(q, k, v, pos, jnp.int32(63), window=16,
                               block_s=16)
    ref = R.decode_attention_ref(q[:, 0], jnp.moveaxis(k, 2, 1),
                                 jnp.moveaxis(v, 2, 1), pos, 63, window=16)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=2e-5)


# -- valid_from masking (block_q = block_k = 16 everywhere below) -----------
#
# The batch covers every block-boundary case at once: vf=0 (no-op),
# vf=7 (mid-block), vf=16 (exact block edge: block 0 skippable), and a
# fully-masked row (vf past every attendable key -> exact zeros).

def _qkv(rng, B, T, Hq, KV, hd, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, T, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("win,cap", [(0, 0.0), (24, 0.0), (0, 30.0)])
def test_flash_attention_valid_from_vs_ref(win, cap, rng):
    B, Hq, KV, T, hd = 4, 4, 2, 48, 16
    q, k, v = _qkv(rng, B, T, Hq, KV, hd)
    vf = jnp.asarray([0, 7, 16, T], jnp.int32)
    out = ops.flash_attention_btHd(q, k, v, vf, window=win, softcap=cap,
                                   block_q=16, block_k=16)
    ref = R.flash_attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                                jnp.moveaxis(v, 2, 1), window=win, cap=cap,
                                valid_from=vf)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(ref, 1, 2)),
                               atol=1e-5, rtol=1e-5)
    # Fully-masked row: every query attends to nothing -> exact zeros.
    assert not np.asarray(out[3]).any()


def test_flash_attention_valid_from_zero_bit_identical(rng):
    """vf=0 must be bitwise equal to the unmasked kernel — engines keep
    one jit trace by always passing an array (PR 7 pin, now in-kernel)."""
    B, Hq, KV, T, hd = 2, 4, 2, 48, 16
    q, k, v = _qkv(rng, B, T, Hq, KV, hd)
    a = ops.flash_attention_btHd(q, k, v, block_q=16, block_k=16)
    b = ops.flash_attention_btHd(q, k, v, jnp.zeros((B,), jnp.int32),
                                 block_q=16, block_k=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flash_attention_valid_from_offset_positions(rng):
    """The ops wrapper rebases absolute valid_from into kernel
    coordinates (pos_k[0] shift) — the backfill prefill_row path."""
    B, Hq, KV, T, hd, off = 1, 2, 2, 32, 16, 64
    q, k, v = _qkv(rng, B, T, Hq, KV, hd)
    pos = jnp.arange(off, off + T, dtype=jnp.int32)
    vf = jnp.asarray([off + 9], jnp.int32)
    out = ops.flash_attention(q, k, v, pos, pos, vf)
    ref = R.flash_attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                                jnp.moveaxis(v, 2, 1),
                                valid_from=jnp.asarray([9], jnp.int32))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(ref, 1, 2)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("ring", [False, True])
def test_decode_attention_valid_from_vs_ref(ring, rng):
    B, Hq, KV, S, hd = 4, 4, 2, 64, 16
    cache_pos = 40
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    if ring:
        pos = jnp.asarray((np.arange(S) + 17) % 61, jnp.int32)
        pos = jnp.where(pos <= cache_pos, pos, -1)
    else:
        pos = jnp.asarray(np.where(np.arange(S) <= cache_pos,
                                   np.arange(S), -1), jnp.int32)
    # vf=41 > cache_pos: nothing attendable -> exact zeros.
    vf = jnp.asarray([0, 7, 16, 41], jnp.int32)
    out = ops.decode_attention(q, k, v, pos, jnp.int32(cache_pos), vf,
                               block_s=16, linear=not ring)
    ref = R.decode_attention_ref(q[:, 0], jnp.moveaxis(k, 2, 1),
                                 jnp.moveaxis(v, 2, 1), pos, cache_pos,
                                 valid_from=vf)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert not np.asarray(out[3]).any()


def test_decode_attention_valid_from_zero_bit_identical(rng):
    B, Hq, KV, S, hd = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.asarray(np.arange(S), jnp.int32)
    a = ops.decode_attention(q, k, v, pos, jnp.int32(50), block_s=16,
                             linear=True)
    b = ops.decode_attention(q, k, v, pos, jnp.int32(50),
                             jnp.zeros((B,), jnp.int32), block_s=16,
                             linear=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_attention_block_skip_matches_full_scan(rng):
    """linear=True enables the early block skip; the ring path (no skip)
    over the same linear cache must agree to the last ulp — the skipped
    blocks contribute exactly nothing."""
    B, Hq, KV, S, hd = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.asarray(np.arange(S), jnp.int32)
    vf = jnp.asarray([33, 18], jnp.int32)
    skip = ops.decode_attention(q, k, v, pos, jnp.int32(50), vf,
                                block_s=16, linear=True)
    full = ops.decode_attention(q, k, v, pos, jnp.int32(50), vf,
                                block_s=16, linear=False)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(full))


@pytest.mark.parametrize("mnk", [(32, 48, 64), (64, 80, 96), (16, 16, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_int8_matmul_vs_ref(mnk, dtype, rng):
    M, N, K = mnk
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    wq, sc = quantize_int8(w, axis=0)
    out = ops.int8_matmul(x, wq, sc.reshape(-1), block_m=16, block_n=16,
                          block_k=32)
    ref = R.int8_matmul_ref(x, wq, sc.reshape(-1))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_int8_quantization_error_small(rng):
    """End-to-end: int8 matmul approximates the fp32 matmul (paper Fig 6:
    small accuracy cost for 75% storage saving)."""
    x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    wq, sc = quantize_int8(w, axis=0)
    exact = x @ w
    approx = ops.int8_matmul(x, wq, sc.reshape(-1), block_m=16, block_n=16,
                             block_k=32)
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02
    # storage: int8 + per-col scale vs fp32
    bytes_q = wq.size + 4 * sc.size
    assert bytes_q < 0.27 * (w.size * 4)
