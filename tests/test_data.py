"""Data pipeline: determinism, host sharding, resumability."""

import numpy as np

from repro.data import MarkovLMTask, CopyTask, ByteCorpus, DataIterator


def test_markov_deterministic():
    t = MarkovLMTask(vocab=64, seed=1)
    a = t.batch(5, 4, 16)
    b = t.batch(5, 4, 16)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = t.batch(6, 4, 16)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_markov_learnable_structure():
    t = MarkovLMTask(vocab=32, branching=2, seed=0)
    b = t.batch(0, 8, 64)
    # every transition must be one of the 2 allowed successors
    for row_in, row_lab in zip(b["inputs"], b["labels"]):
        for x, y in zip(row_in, row_lab):
            assert y in t.next_tokens[x]


def test_hosts_draw_different_data():
    t = MarkovLMTask(vocab=64, seed=1)
    a = t.batch(5, 4, 16, host=0)
    b = t.batch(5, 4, 16, host=1)
    assert not np.array_equal(a["inputs"], b["inputs"])


def test_copy_task_layout():
    t = CopyTask(vocab=16, prompt_len=5)
    b = t.batch(0, 3)
    assert b["inputs"].shape == (3, 10)  # 2*5+1 tokens -> inputs 10
    # labels for the second half reproduce the prompt
    np.testing.assert_array_equal(b["labels"][:, -5:],
                                  b["prompt"][:, :5])


def test_iterator_resume():
    t = MarkovLMTask(vocab=64, seed=1)
    it = DataIterator(t, batch=2, seq=8)
    first = [next(it) for _ in range(4)]
    it2 = DataIterator(t, batch=2, seq=8, step=2)
    np.testing.assert_array_equal(first[2]["inputs"],
                                  next(it2)["inputs"])


def test_byte_corpus_reads_repo():
    c = ByteCorpus(root="src", max_bytes=100_000)
    assert len(c.data) > 1000
    b = c.batch(0, 2, 32)
    assert b["inputs"].shape == (2, 32)
    assert (b["inputs"] >= 0).all() and (b["inputs"] < 256).all()
    b2 = c.batch(0, 2, 32)
    np.testing.assert_array_equal(b["inputs"], b2["inputs"])
