"""Checkpointing: atomicity, bitwise resume, retention, torn writes."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import MarkovLMTask
from repro.training.checkpoint import (CheckpointManager, save_checkpoint,
                                       restore_checkpoint, committed_steps)
from repro.training.optim import adamw, constant_schedule
from repro.training.step import make_train_step, init_train_state


def _mk_state():
    cfg = reduced_config("stablelm_1_6b")
    opt = adamw(constant_schedule(1e-3))
    return cfg, opt, init_train_state(cfg, opt, jax.random.PRNGKey(0))


def test_save_restore_bitwise(tmp_path):
    cfg, opt, state = _mk_state()
    save_checkpoint(str(tmp_path), state, step=7)
    restored, manifest = restore_checkpoint(str(tmp_path), state)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_rejected(tmp_path):
    cfg, opt, state = _mk_state()
    save_checkpoint(str(tmp_path), state, step=1)
    other = reduced_config("yi_9b")
    other_state = init_train_state(other, opt, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), other_state)


def test_torn_write_is_ignored(tmp_path):
    cfg, opt, state = _mk_state()
    save_checkpoint(str(tmp_path), state, step=1)
    # simulate a crash mid-write: directory exists but no _COMMITTED
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert committed_steps(str(tmp_path)) == [1]
    _, manifest = restore_checkpoint(str(tmp_path), state)
    assert manifest["step"] == 1


def test_manager_retention(tmp_path):
    cfg, opt, state = _mk_state()
    mgr = CheckpointManager(str(tmp_path), keep_n=2, save_interval=10)
    for s in (10, 20, 30, 40):
        assert mgr.maybe_save(state, s) is not None
    assert mgr.maybe_save(state, 41) is None
    assert committed_steps(str(tmp_path)) == [30, 40]
    assert mgr.latest_step() == 40


def test_resume_equivalence(tmp_path):
    """Train 6 steps straight vs. 3 steps -> checkpoint -> restore -> 3
    steps: final params must match bitwise (deterministic data + step)."""
    cfg, opt, state = _mk_state()
    task = MarkovLMTask(vocab=cfg.vocab, seed=3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run(state, start, n):
        for i in range(start, start + n):
            b = task.batch(i, 4, 16)
            state, _ = step_fn(state, {"inputs": jnp.asarray(b["inputs"]),
                                       "labels": jnp.asarray(b["labels"])})
        return state

    straight = run(state, 0, 6)
    half = run(state, 0, 3)
    save_checkpoint(str(tmp_path), half, step=3)
    restored, manifest = restore_checkpoint(str(tmp_path), half)
    resumed = run(restored, manifest["step"], 3)
    for a, b in zip(jax.tree.leaves(straight["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
