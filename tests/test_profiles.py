"""Welford profile store vs numpy, staleness, priors. (The hypothesis
property test lives in test_properties.py.)"""

import numpy as np

from repro.core.profiles import OnlineProfile, ProfileStore


def test_welford_matches_numpy_fixed():
    xs = list(np.random.default_rng(0).normal(50.0, 20.0, 64))
    p = OnlineProfile()
    for x in xs:
        p.update(x)
    np.testing.assert_allclose(p.mean, np.mean(xs), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(p.std, np.std(xs, ddof=1), rtol=1e-5,
                               atol=1e-5)


def test_prior_blending():
    s = ProfileStore()
    s.set_prior("m", 100.0, 10.0)
    mu, sg = s.mu_sigma("m")
    assert mu == 100.0 and sg == 10.0
    for _ in range(2):
        s.record("m", 50.0)
    mu, _ = s.mu_sigma("m", min_obs=4)  # half weight on observations
    assert 50.0 < mu < 100.0
    for _ in range(10):
        s.record("m", 50.0)
    mu, _ = s.mu_sigma("m", min_obs=4)
    assert abs(mu - 50.0) < 8.0


def test_staleness_and_dynamic_threshold():
    s = ProfileStore()
    s.set_prior("a", 10, 1)
    s.record("a", 10.0, now=0.0)
    assert s.staleness("a", now=100.0) == 100.0
    th = s.dynamic_threshold(["a"], now=100.0, base=10.0, t_device=200.0)
    assert 10.0 < th <= 200.0
    # bounded by T_D per the paper
    th2 = s.dynamic_threshold(["a"], now=1e9, base=10.0, t_device=200.0)
    assert th2 == 200.0
