"""ServingStack conformance suite (DESIGN.md §16).

One behavioural contract run against every implementation —
`CNNSelectServer` (batch-of-one), `ServingLoop` (continuous batching),
`SimReplicaStack` (simulated replica), and `Cluster` (the composite):
protocol shape, submit -> metrics round trip, tenant tagging, and the
observe_outcome feedback path. Plus the deprecation pins for the
pre-unification metrics aliases and `Router.enqueue`.
"""

import warnings
from dataclasses import replace

import numpy as np
import pytest

import jax

from repro.configs import reduced_config
from repro.configs.paper_zoo import paper_profiles
from repro.models import init_params
from repro.serving.batching import Request
from repro.serving.cluster import Cluster
from repro.serving.engine import InferenceEngine
from repro.serving.loop import ServingLoop
from repro.serving.metrics import ServingMetrics
from repro.serving.router import Router
from repro.serving.server import CNNSelectServer, ServedModel
from repro.serving.stack import (ServingStack, SimReplicaStack,
                                 StackOutcome)

MODELS = ["mobilenetv1_025", "mobilenetv1_10"]


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, batch_size=2, max_seq=32)
    eng.warmup(8)
    return eng


def _make_stack(kind, engine):
    if kind == "server":
        s = CNNSelectServer(
            [ServedModel("a", engine, 0.9),
             ServedModel("b", engine, 0.8)], t_threshold=10.0,
            n_tokens=2)
        s.profile_models(prompt_len=8, reps=1)
        return s
    if kind == "loop":
        profs = [replace(p, name=n) for p, n in
                 zip(paper_profiles(MODELS), ("a", "b"))]
        return ServingLoop({"a": engine, "b": engine}, profiles=profs,
                           t_threshold=10.0)
    if kind == "sim":
        return SimReplicaStack(paper_profiles(MODELS), seed=7)
    if kind == "cluster":
        return Cluster(
            [SimReplicaStack(paper_profiles(MODELS), seed=7 + i)
             for i in range(2)],
            [{"tenant": "t0", "sla_class": "bronze"}])
    raise AssertionError(kind)


def _req(rid=0, tenant="t0", arrival=0.0):
    return Request(arrival=arrival, rid=rid,
                   prompt=np.zeros(4, np.int32), max_new_tokens=2,
                   sla_ms=1e6, t_input_ms=5.0,
                   device_id=f"{tenant}/dev", tenant=tenant)


KINDS = ["server", "loop", "sim", "cluster"]


@pytest.mark.parametrize("kind", KINDS)
def test_stack_protocol_shape(kind, engine):
    s = _make_stack(kind, engine)
    assert isinstance(s, ServingStack)
    assert isinstance(s.metrics, ServingMetrics)


@pytest.mark.parametrize("kind", KINDS)
def test_stack_submit_metrics_round_trip(kind, engine):
    s = _make_stack(kind, engine)
    outs = [s.submit(_req(i, arrival=float(5 * i)), now=float(5 * i))
            for i in range(3)]
    s.drain()
    assert all(isinstance(o, StackOutcome) for o in outs)
    assert s.metrics.served == 3
    # Outcomes resolve either inline or at drain — never silently.
    for o in outs:
        assert o.pending or o.ok is not None
    for rec in s.metrics.records:
        assert rec["model"]
        assert rec["ok"] is not None
        assert rec["e2e_ms"] >= 2 * 5.0       # 2*T_input floor
    # Unified summary schema.
    sm = s.metrics.summary()
    for key in ("served", "attainment", "mean_ms", "p95_ms",
                "selections"):
        assert key in sm
    assert sm["served"] == 3


@pytest.mark.parametrize("kind", KINDS)
def test_stack_tenant_tagging(kind, engine):
    s = _make_stack(kind, engine)
    s.submit(_req(0, tenant="t0"))
    s.drain()
    assert [r["tenant"] for r in s.metrics.records] == ["t0"]
    assert "t0" in s.metrics.per_tenant()


@pytest.mark.parametrize("kind", KINDS)
def test_stack_observe_outcome(kind, engine):
    # The feedback path must accept measured latencies without a prior
    # submit (the cluster fans it to replicas that never saw the req).
    s = _make_stack(kind, engine)
    name = "a" if kind in ("server", "loop") else MODELS[0]
    s.observe_outcome(name, 12.5)
    s.observe_outcome(name, 14.0, cold=True, now=1.0)


# -- deprecation pins ------------------------------------------------------

def test_metrics_aliases_warn():
    m = ServingMetrics()
    m.add(_req(0), "a", 1.0, 2.0)
    for name, repl in [("latencies_ms", "records"),
                       ("accuracies", "records"),
                       ("selections", "summary()['selections']"),
                       ("by_device", "per_device()"),
                       ("by_mode", "per_mode()")]:
        with pytest.deprecated_call(
                match=f"ServingMetrics.{name} is deprecated"):
            getattr(m, name)
    # The aliases still return the old shapes.
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert m.latencies_ms == [m.records[0]["e2e_ms"]]
        assert m.selections == {"a": 1}
        assert m.by_mode == {"static": 1}


def test_router_enqueue_warns():
    r = Router(paper_profiles(MODELS), t_threshold=10.0)
    with pytest.deprecated_call(match="Router.enqueue is deprecated"):
        r.enqueue(_req(0), MODELS[0])
    # Deprecated path still admits: the request reached the queue.
    assert len(r.queues[MODELS[0]]) == 1
