"""Continuous-batching serving loop integration."""

import numpy as np
import pytest

import jax

from repro.configs import reduced_config
from repro.core.selection import ModelProfile
from repro.models import init_params
from repro.serving.engine import InferenceEngine
from repro.serving.batching import Request
from repro.serving.loop import ServingLoop


def _engine(batch_size=2, seed=0):
    cfg = reduced_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.PRNGKey(seed))
    eng = InferenceEngine(cfg, params, batch_size=batch_size, max_seq=32)
    eng.warmup(8)
    return eng


@pytest.fixture(scope="module")
def loop():
    return ServingLoop({"m": _engine()})


def _reqs(n, rng, sla=1e9):
    return [Request(arrival=float(i * 5), rid=i,
                    prompt=rng.integers(0, 50, 6).astype(np.int32),
                    max_new_tokens=3, sla_ms=sla, t_input_ms=5.0)
            for i in range(n)]


def test_loop_serves_all_requests(loop):
    rng = np.random.default_rng(0)
    metrics = loop.run(_reqs(5, rng))
    s = metrics.summary()
    assert s["served"] == 5
    assert s["attainment"] == 1.0  # generous SLA
    assert all(len(r["model"]) for r in metrics.records)
    # every request produced its tokens
    done = loop.batchers["m"].done
    assert all(len(r.tokens) == 3 for r in done)


def test_loop_groups_by_batch_capacity():
    loop = ServingLoop({"m": _engine()})
    rng = np.random.default_rng(1)
    reqs = _reqs(4, rng)
    for r in reqs:
        r.arrival = 0.0  # all at once; batch_size=2 -> 2 groups
    metrics = loop.run(reqs)
    assert metrics.summary()["served"] == 4
    # second group queued behind the first
    q = sorted(r["queue_ms"] for r in metrics.records)
    assert q[-1] > 0.0


def test_loop_routes_with_cnnselect():
    engines = {"fast": _engine(seed=0), "slow": _engine(seed=1)}
    profiles = [ModelProfile("fast", accuracy=0.5, mu=5.0, sigma=1.0),
                ModelProfile("slow", accuracy=0.9, mu=400.0, sigma=10.0)]
    loop = ServingLoop(engines, profiles=profiles, t_threshold=20.0)
    rng = np.random.default_rng(2)
    tight = _reqs(3, rng, sla=40.0)
    loose = _reqs(3, rng, sla=5000.0)
    for i, r in enumerate(loose):
        r.rid = 100 + i
    loop.run(tight + loose)
    by_model = {}
    for rec in loop.metrics.records:
        by_model.setdefault(rec["model"], []).append(rec["rid"])
    # tight SLAs must land on the fast engine
    assert set(by_model.get("fast", [])) >= {0, 1, 2}


def test_loop_adaptive_controller_switches_modes():
    """The loop drives the shared control plane (DESIGN.md §12): with a
    controller attached, a device whose uploads degrade mid-trace is
    escalated live and the per-mode breakdown reports both modes."""
    engines = {"fast": _engine(seed=0), "slow": _engine(seed=1)}
    profiles = [ModelProfile("fast", accuracy=0.5, mu=5.0, sigma=1.0),
                ModelProfile("slow", accuracy=0.9, mu=400.0, sigma=10.0)]
    loop = ServingLoop(engines, profiles=profiles, t_threshold=20.0,
                       controller="reactive")
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(60):
        t_in = 20.0 if i < 30 else 400.0   # mid-trace degradation
        reqs.append(Request(arrival=float(i * 5), rid=i,
                            prompt=rng.integers(0, 50, 6).astype(np.int32),
                            max_new_tokens=2, sla_ms=5000.0,
                            t_input_ms=t_in, device_id="phone"))
    metrics = loop.run(reqs)
    assert metrics.summary()["served"] == 60
    pm = metrics.per_mode()
    assert set(pm) == {"stationary", "degraded"}
    assert pm["stationary"]["served"] + pm["degraded"]["served"] == 60
    assert loop.control.controller.events
    assert loop.control.controller.events[0]["to"] == "degraded"


def test_loop_recorder_captures_run(loop):
    """The ServingLoop recorder hook (DESIGN.md §11): every drained
    request lands in the trace with its outcome and measured exec."""
    from repro.serving.trace import TraceRecorder
    rng = np.random.default_rng(2)
    with TraceRecorder().attach(loop) as rec:
        loop.run(_reqs(4, rng))
    assert loop.recorder is None
    tr = rec.to_trace(source="loop")
    assert len(tr) == 4
    assert (tr.sla_ok == 1).all()           # generous SLA, outcomes known
    assert set(tr.model) == {"m"}
    assert len(tr.meta["exec_ms"]) == 4
    assert all(e > 0 for e in tr.meta["exec_ms"])
    # sla_ms=0 means "no SLA": captured as unknown, not fabricated MET.
    with TraceRecorder().attach(loop) as rec2:
        loop.run(_reqs(2, rng, sla=0.0))
    assert (rec2.to_trace(source="loop").sla_ok == -1).all()
