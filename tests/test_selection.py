"""CNNSelect unit tests + numpy/jnp agreement. (Hypothesis property
sweeps live in test_properties.py; the policy-layer agreement tests in
test_policy.py.)"""

import numpy as np
import pytest

from repro.core.selection import (ModelProfile, cnnselect, cnnselect_batch,
                                  greedy_select, oracle_select)
from repro.configs.paper_zoo import paper_profiles


def mk_profiles(mus, sigmas, accs):
    return [ModelProfile(f"m{i}", a, m, s)
            for i, (m, s, a) in enumerate(zip(mus, sigmas, accs))]


def test_stage1_picks_most_accurate_feasible(rng):
    # m1 fast/low-acc, m2 slower/high-acc, m3 too slow.
    profs = mk_profiles([30, 60, 300], [2, 5, 10], [0.5, 0.8, 0.95])
    r = cnnselect(profs, t_sla=250, t_input=20, t_threshold=50, rng=rng)
    # T_U = 210, T_L = 160: m3 fails (300+10 > 210); base = m2.
    assert r.base_index == 1
    assert not r.fallback


def test_fallback_fastest_when_infeasible(rng):
    profs = mk_profiles([100, 200], [5, 5], [0.6, 0.9])
    r = cnnselect(profs, t_sla=50, t_input=10, t_threshold=10, rng=rng)
    assert r.fallback
    assert r.base_index == 0
    assert r.index == 0  # exploration collapses to the fallback


def test_base_always_eligible(rng):
    profs = mk_profiles([30, 60, 90], [2, 5, 7], [0.5, 0.8, 0.9])
    r = cnnselect(profs, t_sla=400, t_input=20, t_threshold=40, rng=rng)
    assert r.eligible[r.base_index]


def test_paper_zoo_tight_sla_uses_fast_models(rng):
    profs = paper_profiles()
    # ~115ms SLA over campus wifi (63ms avg input): budget is tiny.
    counts = np.zeros(len(profs))
    for _ in range(200):
        r = cnnselect(profs, 115, 55, 30, rng)
        counts[r.index] += 1
    fast = {i for i, p in enumerate(profs) if p.mu < 40}
    assert counts[list(fast)].sum() >= 0.9 * counts.sum()


def test_convergence_to_most_accurate_at_large_sla(rng):
    profs = paper_profiles()
    best = int(np.argmax([p.accuracy for p in profs]))
    counts = np.zeros(len(profs))
    for _ in range(200):
        r = cnnselect(profs, 5000, 60, 50, rng)
        counts[r.index] += 1
    assert r.base_index == best
    assert counts[best] > 0


@pytest.mark.parametrize("t_sla,t_input,seed", [
    (115.0, 55.0, 0), (250.0, 63.0, 1), (400.0, 20.0, 2),
    (900.0, 126.0, 3), (2000.0, 95.0, 4),
])
def test_numpy_jnp_agreement(t_sla, t_input, seed):
    """The vectorized jnp path must agree with the numpy reference on
    base model, eligibility, and probabilities."""
    import jax

    profs = paper_profiles()
    mu = np.array([p.mu for p in profs])
    sg = np.array([p.sigma for p in profs])
    acc = np.array([p.accuracy for p in profs])
    rng = np.random.default_rng(seed)
    r = cnnselect(profs, t_sla, t_input, 40.0, rng)
    sel, probs, base = cnnselect_batch(
        mu, sg, acc, np.array([t_sla]), np.array([t_input]), 40.0,
        jax.random.PRNGKey(seed))
    assert int(base[0]) == r.base_index
    np.testing.assert_allclose(np.asarray(probs[0]), r.probs, atol=1e-4)
    assert r.eligible[int(sel[0])]


def test_greedy_ignores_network():
    profs = mk_profiles([50, 190], [1, 1], [0.5, 0.9])
    # Greedy (paper variant) fits mu <= SLA and picks the accurate one
    # even though 2*T_input pushes it over.
    assert greedy_select(profs, 200) == 1
    assert greedy_select(profs, 200, t_input=50, use_network=True) == 0


def test_oracle_upper_bound(rng):
    profs = paper_profiles()
    realized = np.array([p.mu for p in profs])
    idx = oracle_select(profs, 400, 60, realized)
    # oracle never violates if some model fits
    assert realized[idx] + 120 <= 400
