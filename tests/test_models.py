"""Per-architecture smoke tests (assignment-required): a REDUCED config
of each family runs one forward and one train step on CPU, asserting
output shapes and finiteness; analytic param counts match the tree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import init_params, forward
from repro.training.optim import adamw, constant_schedule
from repro.training.step import make_train_step, init_train_state
from repro.utils import tree_size, tree_allfinite

B, T = 2, 12


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert tree_size(params) == cfg.param_count(), "param count drift"

    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    else:
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                    cfg.vocab)
    logits, extras = forward(params, inputs, cfg)
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()

    opt = adamw(constant_schedule(1e-3))
    step = make_train_step(cfg, opt)
    state = init_train_state(cfg, opt, key)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    batch = {"inputs": inputs, "labels": labels}
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert tree_allfinite(new_state["params"])
    assert int(new_state["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    spec = {
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "stablelm_1_6b": (24, 2048, 32, 32, 5632, 100352),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "yi_9b": (48, 4096, 32, 4, 11008, 64000),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "mamba2_2_7b": (64, 2560, None, None, 0, 50280),
        "qwen3_moe_235b": (94, 4096, 64, 4, 0, 151936),
        "grok_1_314b": (64, 6144, 48, 8, 0, 131072),
    }[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == spec[0]
    assert cfg.d_model == spec[1]
    if spec[2] is not None:
        assert cfg.n_heads == spec[2]
        assert cfg.n_kv_heads == spec[3]
    assert cfg.d_ff == spec[4]
    assert cfg.vocab == spec[5]
    if arch == "qwen3_moe_235b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
        assert cfg.moe.d_ff_expert == 1536
    if arch == "grok_1_314b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
        assert cfg.moe.d_ff_expert == 32768
    if arch == "mamba2_2_7b":
        assert cfg.ssd.d_state == 128


def test_moe_active_params_match_public_numbers():
    q = get_config("qwen3_moe_235b")
    assert 20e9 < q.active_param_count() < 24e9  # "a22b"
    g = get_config("grok_1_314b")
    assert 300e9 < g.param_count() < 330e9
