"""Policy registry + Router: the shared admission layer of all three
serving stacks, and the scalar/batched agreement that pins the jit'd
`cnnselect_batch` path to the paper's numpy semantics."""

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import (CNNSelectPolicy, GreedyPolicy,
                                  ModelProfile, OraclePolicy, Policy,
                                  RandomPolicy, StaticPolicy, cnnselect,
                                  make_policy, policy_names)
from repro.serving.batching import Request
from repro.serving.router import Router


def random_zoo(rng, k):
    mu = np.sort(rng.uniform(10.0, 500.0, k))
    sg = rng.uniform(1.0, 30.0, k)
    acc = np.sort(rng.uniform(0.3, 0.99, k))  # slower models more accurate
    return [ModelProfile(f"m{i}", float(acc[i]), float(mu[i]), float(sg[i]))
            for i in range(k)]


# -- registry --------------------------------------------------------------

def test_registry_resolves_every_name():
    for name in policy_names():
        spec = name if name != "static" else "static:mobilenetv1_025"
        p = make_policy(spec, t_threshold=40.0, seed=0)
        assert isinstance(p, Policy)
        assert p.name == spec or p.name == name


def test_registry_types():
    assert isinstance(make_policy("cnnselect"), CNNSelectPolicy)
    assert isinstance(make_policy("greedy"), GreedyPolicy)
    assert isinstance(make_policy("greedy_nw"), GreedyPolicy)
    assert make_policy("greedy_nw").use_network
    assert isinstance(make_policy("random"), RandomPolicy)
    assert isinstance(make_policy("oracle"), OraclePolicy)
    assert isinstance(make_policy("static:x"), StaticPolicy)


def test_registry_rejects_unknown_and_passthrough():
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("nope")
    with pytest.raises(ValueError, match="static"):
        make_policy("static")
    p = CNNSelectPolicy(t_threshold=10.0)
    assert make_policy(p) is p


# -- scalar vs batched agreement (pins the refactor to paper semantics) ----

@pytest.mark.parametrize("stage2_variant", ["figure", "text"])
@pytest.mark.parametrize("zoo_seed", [0, 1, 2])
def test_cnnselect_scalar_batch_agreement(stage2_variant, zoo_seed):
    """`cnnselect` (numpy, per-request) and `cnnselect_batch` (jit,
    via Policy.select_batch) must pick identical stage-1 base models and
    identical exploration sets M_E for the same requests."""
    rng = np.random.default_rng(zoo_seed)
    profs = random_zoo(rng, k=3 + zoo_seed * 2)
    n = 64
    t_sla = rng.uniform(40.0, 1500.0, n)
    t_input = rng.uniform(0.0, 200.0, n)
    pol = CNNSelectPolicy(t_threshold=40.0, stage2_variant=stage2_variant,
                          seed=zoo_seed, chunk=32)  # force >1 chunk
    batch = pol.select_batch(profs, t_sla, t_input, detail=True)
    for i in range(n):
        r = cnnselect(profs, float(t_sla[i]), float(t_input[i]), 40.0,
                      np.random.default_rng(0), stage2_variant)
        assert int(batch.base[i]) == r.base_index, i
        np.testing.assert_array_equal(batch.eligible[i], r.eligible,
                                      err_msg=f"request {i}")
        np.testing.assert_allclose(batch.probs[i], r.probs, atol=1e-4)
        assert r.eligible[int(batch.indices[i])]


def test_cnnselect_agreement_on_paper_zoo():
    profs = paper_profiles()
    rng = np.random.default_rng(3)
    t_sla = rng.uniform(60.0, 2000.0, 128)
    t_input = rng.uniform(10.0, 150.0, 128)
    pol = CNNSelectPolicy(t_threshold=40.0, seed=0)
    batch = pol.select_batch(profs, t_sla, t_input, detail=True)
    for i in range(128):
        r = cnnselect(profs, float(t_sla[i]), float(t_input[i]), 40.0,
                      np.random.default_rng(0))
        assert int(batch.base[i]) == r.base_index
        np.testing.assert_array_equal(batch.eligible[i], r.eligible)


def test_chunking_invariant():
    """Base models / exploration sets must not depend on the chunk size
    (only the Gumbel draws may differ)."""
    profs = paper_profiles()
    rng = np.random.default_rng(5)
    t_sla = rng.uniform(100.0, 1000.0, 100)
    t_input = rng.uniform(10.0, 120.0, 100)
    a = CNNSelectPolicy(seed=0, chunk=16).select_batch(
        profs, t_sla, t_input, detail=True)
    b = CNNSelectPolicy(seed=0, chunk=128).select_batch(
        profs, t_sla, t_input, detail=True)
    np.testing.assert_array_equal(a.base, b.base)
    np.testing.assert_array_equal(a.eligible, b.eligible)
    np.testing.assert_allclose(a.probs, b.probs, atol=1e-6)


@pytest.mark.parametrize("spec", ["greedy", "greedy_nw", "oracle",
                                  "static:m1"])
def test_baseline_batch_matches_scalar(spec):
    rng = np.random.default_rng(11)
    profs = random_zoo(rng, 5)
    n = 40
    t_sla = rng.uniform(50.0, 1200.0, n)
    t_input = rng.uniform(0.0, 150.0, n)
    realized = rng.uniform(10.0, 500.0, (n, 5))
    pol = make_policy(spec, seed=0)
    batch = pol.select_batch(profs, t_sla, t_input, realized=realized)
    for i in range(n):
        assert int(batch[i]) == pol.select(
            profs, float(t_sla[i]), float(t_input[i]),
            realized=realized[i]), (spec, i)


# -- Router ----------------------------------------------------------------

def test_router_owns_store_zoo_queues():
    profs = paper_profiles()
    r = Router(profs, policy="greedy", t_threshold=40.0)
    assert r.order == [p.name for p in profs]
    assert set(r.queues) == set(r.order)
    # priors seeded from the registered profiles
    mu, sg = r.store.mu_sigma(profs[0].name)
    assert mu == profs[0].mu and sg == profs[0].sigma


def test_router_route_pays_cold_start_once():
    profs = paper_profiles(["squeezenet", "inceptionv4"])
    r = Router(profs, policy="static:inceptionv4")
    d1 = r.route(1e9, 0.0, now=0.0)
    d2 = r.route(1e9, 0.0, now=1.0)
    assert d1.name == "inceptionv4"
    assert d1.startup_ms > 0.0      # cold on first touch
    assert d2.startup_ms == 0.0     # hot after
    assert r.zoo.total_cold_starts == 1


def test_router_online_profiles_shift_selection():
    profs = [ModelProfile("a", 0.6, 30.0, 2.0),
             ModelProfile("b", 0.9, 60.0, 3.0)]
    r = Router(profs, policy="greedy")
    assert r.order[r.select(t_sla=70.0, t_input=0.0)] == "b"
    # b's measured latency degrades far past the SLA -> greedy flips to a
    for _ in range(10):
        r.record("b", 500.0)
    assert r.order[r.select(t_sla=70.0, t_input=0.0)] == "a"


def test_router_submit_many_fills_queues():
    profs = [ModelProfile("fast", 0.5, 5.0, 1.0),
             ModelProfile("slow", 0.9, 400.0, 10.0)]
    r = Router(profs, policy="cnnselect", t_threshold=20.0, seed=0)
    reqs = [Request(arrival=float(i), rid=i, prompt=np.arange(4),
                    sla_ms=40.0 if i < 3 else 5000.0, t_input_ms=5.0)
            for i in range(6)]
    names = r.submit_many(reqs)
    assert len(names) == 6
    # tight-SLA requests must land on the fast model's queue
    assert [q.rid for q in r.queues["fast"].items][:3] == [0, 1, 2]
    assert all(req.model in ("fast", "slow") for req in reqs)
    assert sum(len(q) for q in r.queues.values()) == 6


def test_router_batch_and_scalar_same_profiles_view():
    profs = paper_profiles()
    r = Router(profs, policy="greedy")
    t_sla = np.array([200.0, 2000.0])
    t_in = np.array([60.0, 60.0])
    idx = r.route_batch(t_sla, t_in)
    assert int(idx[0]) == r.select(200.0, 60.0)
    assert int(idx[1]) == r.select(2000.0, 60.0)
