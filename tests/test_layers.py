"""Layer-level equivalences and invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, MoEConfig, SSDConfig, RGLRUConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssd as S
from repro.models.params import block_tree


def base_cfg(**kw):
    d = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
             n_kv_heads=4, head_dim=8, d_ff=64, vocab=64)
    d.update(kw)
    return ModelConfig(**d)


def test_gqa_equals_mha_when_repeated(rng):
    B, T, H, hd = 2, 16, 4, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    kv2 = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    v2 = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    pos = jnp.arange(T)
    out_g = L.attention_naive(q, kv2, v2, pos, pos, window=0, cap=0.0,
                              scale=0.125)
    k4 = jnp.repeat(kv2, 2, axis=2)
    v4 = jnp.repeat(v2, 2, axis=2)
    out_m = L.attention_naive(q, k4, v4, pos, pos, window=0, cap=0.0,
                              scale=0.125)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_m),
                               atol=1e-6)


def test_sliding_window_masks_old_tokens(rng):
    B, T, H, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    pos = jnp.arange(T)
    win = 8
    out = L.attention_naive(q, k, v, pos, pos, window=win, cap=0.0, scale=1.0)
    # Perturb a key outside every window of the last query: positions < T-win
    k2 = k.at[:, : T - win].set(k[:, : T - win] + 100.0)
    out2 = L.attention_naive(q, k2, v, pos, pos, window=win, cap=0.0, scale=1.0)
    np.testing.assert_allclose(np.asarray(out[:, -1]), np.asarray(out2[:, -1]),
                               atol=1e-5)


def test_chunked_equals_naive(rng):
    B, T, H, hd = 2, 24, 4, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    pos = jnp.arange(T)
    for win, cap in [(0, 0.0), (8, 0.0), (0, 20.0)]:
        a = L.attention_naive(q, k, v, pos, pos, window=win, cap=cap, scale=0.3)
        b = L.attention_chunked(q, k, v, pos, pos, window=win, cap=cap,
                                scale=0.3, chunk_q=7, chunk_k=5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_valid_from_skip_equals_naive(rng):
    """The chunked path's whole-KV-chunk early skip (chunks entirely
    below min(valid_from)) changes nothing observable: parity with naive
    at skip-triggering, mid-chunk, and fully-masked valid_from."""
    B, T, H, hd = 3, 24, 4, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, 2, hd)), jnp.float32)
    pos = jnp.arange(T)
    # min(vf)=10 with chunk_k=5: KV chunks 0 and 1 are skipped outright.
    vf = jnp.asarray([10, 13, T], jnp.int32)
    a = L.attention_naive(q, k, v, pos, pos, window=0, cap=0.0, scale=0.3,
                          valid_from=vf)
    b = L.attention_chunked(q, k, v, pos, pos, window=0, cap=0.0,
                            scale=0.3, chunk_q=7, chunk_k=5, valid_from=vf)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert not np.asarray(b[2]).any()       # fully-masked row -> zeros


def test_attention_impl_registry_parity(rng):
    """Every registered impl produces the same masked attention through
    the public dispatcher."""
    cfg = base_cfg(attn_chunk=8)
    B, T, hd = 2, 16, 8
    q = jnp.asarray(rng.normal(size=(B, T, 4, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, 4, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, 4, hd)), jnp.float32)
    pos = jnp.arange(T)
    vf = jnp.asarray([0, 5], jnp.int32)
    import dataclasses
    outs = {}
    for impl in sorted(L.ATTN_IMPLS):
        c = dataclasses.replace(cfg, attn_impl=impl)
        outs[impl] = np.asarray(L.attention(q, k, v, pos, pos, c, window=0,
                                            valid_from=vf))
    for impl, out in outs.items():
        np.testing.assert_allclose(out, outs["naive"], atol=2e-5,
                                   err_msg=impl)


def test_attention_unknown_impl_lists_valid_impls(rng):
    import dataclasses
    cfg = dataclasses.replace(base_cfg(), attn_impl="flashinfer")
    q = jnp.zeros((1, 4, 4, 8), jnp.float32)
    pos = jnp.arange(4)
    with pytest.raises(ValueError, match=r"jax_chunked, naive, pallas"):
        L.attention(q, q, q, pos, pos, cfg, window=0)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(L.softcap(x, 0.0)), np.asarray(x))


def test_rope_relative_property(rng):
    """RoPE dot products depend only on relative positions."""
    hd = 16
    q = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot_at(pq, pk):
        qr = L.rope(q, jnp.array([pq]), theta=1e4)
        kr = L.rope(k, jnp.array([pk]), theta=1e4)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 0) - dot_at(107, 100)) < 1e-3


def test_rglru_scan_equals_stepwise(rng):
    cfg = base_cfg(pattern=("rglru",), rglru=RGLRUConfig(lru_width=16),
                   d_model=16)
    key = jax.random.PRNGKey(0)
    counter = [0]

    def mk(shape, axes, init):
        counter[0] += 1
        return jax.random.normal(jax.random.fold_in(key, counter[0]),
                                 shape) * 0.3
    p = block_tree(cfg, "rglru", mk)
    x = jnp.asarray(rng.normal(size=(2, 10, 16)), jnp.float32)
    u = jnp.einsum("btd,dw->btw", x, p["w_in"])
    y_seq, h_last = R.rglru_scan(p, u, cfg)
    h = jnp.zeros((2, 16), jnp.float32)
    outs = []
    for t in range(10):
        yt, h = R.rglru_step(p, u[:, t:t + 1], cfg, h)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


def test_causal_conv_matches_loop(rng):
    w = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 9, 6)), jnp.float32)
    y, state = R.causal_conv1d(w, x)
    # loop reference
    xp = np.concatenate([np.zeros((2, 3, 6), np.float32), np.asarray(x)], 1)
    ref = np.zeros((2, 9, 6), np.float32)
    for t in range(9):
        for i in range(4):
            ref[:, t] += xp[:, t + i] * np.asarray(w)[:, i]
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), xp[:, -3:], atol=1e-6)
    # streaming: feed one token at a time with carried state
    st = None
    ys = []
    for t in range(9):
        yt, st = R.causal_conv1d(w, x[:, t:t + 1], st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)), ref,
                               atol=1e-5)


def test_ssd_chunked_equals_naive_recurrence(rng):
    B, T, H, P, N = 2, 12, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, T, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, 1, N)), jnp.float32)
    for chunk in (4, 5, 12):
        y, S_last = S.ssd_chunked(x, dt, A, Bm, Cm, chunk)
        # naive recurrence
        St = np.zeros((B, H, N, P), np.float32)
        ys = np.zeros((B, T, H, P), np.float32)
        for t in range(T):
            a = np.exp(np.asarray(dt)[:, t] * np.asarray(A))  # (B,H)
            Bt = np.repeat(np.asarray(Bm)[:, t], H, axis=1)  # (B,H,N)
            Ct = np.repeat(np.asarray(Cm)[:, t], H, axis=1)
            xdt = np.asarray(x)[:, t] * np.asarray(dt)[:, t][..., None]
            St = a[..., None, None] * St + np.einsum("bhn,bhp->bhnp", Bt, xdt)
            ys[:, t] = np.einsum("bhn,bhnp->bhp", Ct, St)
        np.testing.assert_allclose(np.asarray(y), ys, atol=2e-4)
        np.testing.assert_allclose(np.asarray(S_last), St, atol=2e-4)


def test_ssd_step_continues_chunked(rng):
    B, T, H, P, N = 1, 8, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(B, T + 1, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, T + 1, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T + 1, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T + 1, 1, N)), jnp.float32)
    y_all, _ = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    y_pre, S_pre = S.ssd_chunked(x[:, :T], dt[:, :T], A, Bm[:, :T],
                                 Cm[:, :T], chunk=4)
    y_step, _ = S.ssd_step(x[:, T:], dt[:, T:], A, Bm[:, T:], Cm[:, T:], S_pre)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_all[:, T]), atol=1e-4)


def test_moe_dense_routes_topk(rng):
    cfg = base_cfg(pattern=("moe",), d_ff=0,
                   moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                                 capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    counter = [0]

    def mk(shape, axes, init):
        counter[0] += 1
        return jax.random.normal(jax.random.fold_in(key, counter[0]),
                                 shape) * 0.2
    p = block_tree(cfg, "moe", mk)
    x = jnp.asarray(rng.normal(size=(2, 6, 32)), jnp.float32)
    out, aux = M.moe_ffn_dense({k: p[k] for k in
                                ("router", "w_up", "w_gate", "w_down")},
                               x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0  # load-balance loss positive
    # manual reference for one token
    x0 = np.asarray(x)[0, 0]
    logits = x0 @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max())
    probs /= probs.sum()
    top = np.argsort(probs)[-2:]
    w = probs[top] / probs[top].sum()
    ref = np.zeros(32, np.float32)
    for wi, e in zip(w, top):
        g = x0 @ np.asarray(p["w_gate"])[e]
        u = x0 @ np.asarray(p["w_up"])[e]
        h = (g * (1 / (1 + np.exp(-g)))) * u  # silu(g)*u
        ref += wi * (h @ np.asarray(p["w_down"])[e])
    np.testing.assert_allclose(np.asarray(out)[0, 0], ref, atol=1e-4)
