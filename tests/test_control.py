"""Online control plane (serving/control.py, DESIGN.md §12):
change-point detectors, the adaptive controller, and the shared
per-request control step. Deterministic unit pins; the calibration
properties (false-positive rate / bounded detection delay) live in
tests/test_properties.py."""

import copy

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import (CONTROL_MODES, ControlMode, make_mode,
                                  mode_names)
from repro.serving.control import (AdaptiveController, ControlPlane,
                                   CusumDetector, PageHinkleyDetector,
                                   make_controller, make_detector)
from repro.serving.fleet import EstimatorBank
from repro.serving.router import Router
from repro.serving.simulator import SimConfig, simulate
from repro.serving.trace import CapturedTraceProcess, Trace


# -- detectors --------------------------------------------------------------

def test_cusum_alarm_position_pinned():
    """Fixed scale=1, k=0.5, h=8: a +3-sigma step accumulates 2.5 per
    update, so the alarm fires on exactly the 4th shifted sample."""
    det = CusumDetector(threshold=8.0, drift=0.5, scale=1.0)
    for _ in range(20):
        assert det.update(0.0) == 0
    hits = [det.update(3.0) for _ in range(4)]
    assert hits == [0, 0, 0, 1]
    assert det.statistic == 0.0          # self-reset after the alarm


def test_cusum_down_alarm_and_sign():
    det = CusumDetector(threshold=8.0, drift=0.5, scale=1.0)
    hits = [det.update(-3.0) for _ in range(4)]
    assert hits == [0, 0, 0, -1]


def test_cusum_no_alarm_below_drift():
    """A sustained offset smaller than the drift never accumulates."""
    det = CusumDetector(threshold=8.0, drift=0.5, scale=1.0)
    assert all(det.update(0.4) == 0 for _ in range(10000))


def test_page_hinkley_alarm_positions():
    """delta=0.25, h=8: a +1.25 step adds 1.0 to the up side per
    update -> alarm on the 9th shifted sample; and a zero-mean stream
    never alarms (each side carries its own drift — a shared sum would
    walk away from its extremum and false-alarm)."""
    det = PageHinkleyDetector(threshold=8.0, delta=0.25, scale=1.0)
    for _ in range(5000):
        assert det.update(0.0) == 0
    hits = [det.update(1.25) for _ in range(9)]
    assert hits == [0] * 8 + [1]
    hits = [det.update(-1.25) for _ in range(9)]
    assert hits == [0] * 8 + [-1]


def test_detector_scale_priming_and_self_normalization():
    det = CusumDetector(threshold=8.0, drift=0.5)
    det.prime_scale(10.0)
    # Residual 30 = 3 sigma at the primed scale: alarm on 4th sample.
    hits = [det.update(30.0) for _ in range(4)]
    assert hits[-1] == 1
    fixed = CusumDetector(threshold=8.0, drift=0.5, scale=5.0)
    fixed.prime_scale(50.0)              # no-op with a fixed scale
    assert fixed.fixed_scale == 5.0


def test_make_detector_registry_errors():
    assert isinstance(make_detector("cusum"), CusumDetector)
    assert isinstance(make_detector("ph:12"), PageHinkleyDetector)
    assert make_detector("cusum:5").threshold == 5.0
    with pytest.raises(ValueError, match="known: cusum"):
        make_detector("ewma")
    with pytest.raises(ValueError, match="numeric"):
        make_detector("cusum:high")
    with pytest.raises(ValueError, match="ChangePointDetector"):
        make_detector(7)
    with pytest.raises(ValueError):
        CusumDetector(threshold=-1)
    with pytest.raises(ValueError):
        PageHinkleyDetector(delta=-0.1)


# -- mode table -------------------------------------------------------------

def test_mode_registry():
    assert set(mode_names()) >= {"stationary", "degraded"}
    m = make_mode("degraded")
    assert m.degraded and m.hedge == "outage" and m.on_device_fallback
    assert make_mode(m) is m
    with pytest.raises(ValueError, match="known:"):
        make_mode("panic")
    with pytest.raises(ValueError):
        make_mode(3.5)


def test_controller_validation_errors():
    with pytest.raises(ValueError, match="at least two"):
        AdaptiveController(modes=("stationary",))
    with pytest.raises(ValueError, match="duplicate"):
        AdaptiveController(modes=("stationary", "stationary"))
    with pytest.raises(ValueError, match="hedge"):
        AdaptiveController(modes=(
            "stationary", ControlMode(name="x", hedge="always")))
    with pytest.raises(ValueError, match="estimator"):
        AdaptiveController(modes=(
            "stationary", ControlMode(name="x", t_estimator="kalman")))
    with pytest.raises(ValueError, match="start"):
        AdaptiveController(start=5)
    with pytest.raises(ValueError, match="cooldown"):
        AdaptiveController(cooldown=-1)
    with pytest.raises(ValueError, match="known:"):
        make_controller("zen")
    with pytest.raises(ValueError, match="AdaptiveController"):
        make_controller(1.5)
    named = make_controller("reactive")
    assert named.name == "reactive"
    assert named.mode_names() == ["stationary", "degraded"]
    assert make_controller(named) is named
    assert make_controller(None) is None


def test_controller_detects_step_and_recovery():
    """60ms traffic -> sustained 300ms -> back to 60ms: escalate on the
    shift, de-escalate on the recovery, events recorded in order."""
    ctrl = AdaptiveController(detector="cusum:8", monitor="ewma:0.2",
                              cooldown=4)
    ctrl.prime({}, 60.0)
    stream = [60.0] * 40 + [300.0] * 40 + [60.0] * 40
    modes = [ctrl.observe("dev", x).name for x in stream]
    assert modes[:40] == ["stationary"] * 40
    assert "degraded" in modes[40:60]     # bounded escalation delay
    assert modes[79] == "degraded"
    assert modes[-1] == "stationary"      # recovered
    ev = ctrl.events
    assert [e["to"] for e in ev[:2]] == ["degraded", "stationary"]
    assert ev[0]["alarm"] == 1 and ev[1]["alarm"] == -1
    assert 40 <= ev[0]["request"] < 60
    assert ev[0]["device"] == "dev"


def test_controller_scalar_matches_run_series():
    """The scalar observe() protocol and the vectorized run_series()
    walk identical detector state: same modes, same events."""
    rng = np.random.default_rng(0)
    stream = np.concatenate([
        rng.normal(60, 8, 60).clip(1), rng.normal(250, 30, 60).clip(1),
        rng.normal(60, 8, 60).clip(1)])
    keys = list(np.where(np.arange(180) % 2 == 0, "a", "b"))
    a = AdaptiveController(cooldown=4)
    a.prime({"a": 60.0, "b": 60.0}, 60.0)
    scalar = [a.modes.index(a.observe(k, float(x)))
              for k, x in zip(keys, stream)]
    b = AdaptiveController(cooldown=4)
    b.prime({"a": 60.0, "b": 60.0}, 60.0)
    series = b.run_series(stream, keys)
    assert np.array_equal(np.asarray(scalar), series)
    # Event floats (ref/level) may differ in the last ulp: the EWMA's
    # estimate_series uses the blocked closed form (documented
    # round-off vs the sequential protocol). Decisions must agree
    # exactly.
    assert len(a.events) == len(b.events)
    for ea, eb in zip(a.events, b.events):
        assert {k: v for k, v in ea.items()
                if k not in ("ref", "level")} == \
            {k: v for k, v in eb.items() if k not in ("ref", "level")}
        assert ea["ref"] == pytest.approx(eb["ref"], rel=1e-9)
        assert ea["level"] == pytest.approx(eb["level"], rel=1e-9)


def test_controller_per_device_isolation():
    """One device's outage cannot switch another device's mode."""
    ctrl = AdaptiveController(cooldown=4)
    ctrl.prime({"good": 60.0, "bad": 60.0}, 60.0)
    for _ in range(50):
        ctrl.observe("bad", 400.0)
        ctrl.observe("good", 60.0)
    assert ctrl.mode_of("bad").name == "degraded"
    assert ctrl.mode_of("good").name == "stationary"
    assert all(e["device"] == "bad" for e in ctrl.events)


# -- control plane ----------------------------------------------------------

def _profiles():
    return paper_profiles(["mobilenetv1_05", "mobilenetv1_10",
                           "inceptionv3"])


def test_static_plane_step_matches_router_flow():
    """ControlPlane.step with no controller must be exactly the old
    observe_t_input -> select sequence (the server's pre-plane path)."""
    profs = _profiles()
    plane_router = Router(profs, policy="greedy_nw",
                          t_estimator="ewma:0.2")
    plane = ControlPlane(plane_router)
    mirror = Router(profs, policy="greedy_nw", t_estimator="ewma:0.2")
    rng = np.random.default_rng(1)
    for t_input in rng.lognormal(4.0, 0.4, 50):
        d = plane.step(300.0, float(t_input))
        est = mirror.observe_t_input(float(t_input))
        assert d.index == mirror.select(300.0, est)
        assert d.t_est == est
        assert d.mode == "static" and not d.fallback


def test_plane_step_adaptive_decisions():
    """Degraded-regime decisions: conservative estimator, hedge flag,
    and on-device fallback when the cloud path cannot meet the SLA."""
    profs = _profiles()
    plane = ControlPlane(Router(profs, policy="greedy_nw"),
                         controller=AdaptiveController(cooldown=2),
                         priors={"dev": 60.0}, default_prior=60.0)
    for _ in range(40):
        d = plane.step(300.0, 60.0, device_id="dev")
    assert d.mode == "stationary" and not d.hedge
    for _ in range(40):
        d = plane.step(300.0, 400.0, device_id="dev",
                       on_device_ms=150.0)
    # 2*400ms upload + fastest mu >> 300ms SLA; device does 150ms.
    assert d.mode == "degraded"
    assert d.fallback and d.index == -1 and d.name == "<on-device>"
    d2 = plane.step(300.0, 400.0, device_id="dev")   # no local model
    assert not d2.fallback and d2.hedge and d2.degraded


def test_simulate_adaptive_deterministic_and_counts():
    profs = paper_profiles()
    cfg = SimConfig(t_sla=320.0, n_requests=800, seed=3,
                    network="wifi_lte_handoff", controller="reactive")
    a = simulate(profs, cfg)
    b = simulate(profs, cfg)
    assert np.array_equal(a.selections, b.selections)
    assert np.array_equal(a.modes, b.modes)
    assert a.switch_events == b.switch_events
    assert a.mode_names == ["stationary", "degraded"]
    assert len(a.modes) == 800
    pm = a.per_mode()
    assert pm and sum(v["share"] for v in pm.values()) == pytest.approx(1.0)


def test_simulate_does_not_mutate_caller_controller():
    profs = paper_profiles()
    ctrl = AdaptiveController(cooldown=4)
    cfg = SimConfig(t_sla=320.0, n_requests=300, seed=3,
                    network="wifi_lte_handoff", controller=ctrl)
    a = simulate(profs, cfg)
    assert ctrl._n_seen == 0 and not ctrl.events
    b = simulate(profs, cfg)                  # reusable config
    assert np.array_equal(a.selections, b.selections)


def test_static_run_has_no_modes():
    r = simulate(paper_profiles(), SimConfig(t_sla=320.0,
                                             n_requests=100, seed=0))
    assert r.modes is None and r.switch_events is None
    assert r.per_mode() == {}


def test_switch_events_ride_in_capture_and_replay_identically():
    """Trace.from_sim persists the adaptation sequence; replaying the
    capture bit-for-bit through the same controller preset reproduces
    the identical switches — the adaptation is a function of the
    recorded upload-time stream (and its long-run mean prior) alone,
    independent of the policy/execution RNG (hence the different
    seed)."""
    profs = paper_profiles()
    # A recorded workload (any source); the adaptive run is captured
    # over it, then the capture itself is replayed.
    workload = Trace.from_sim(
        simulate(profs, SimConfig(t_sla=320.0, n_requests=600, seed=3,
                                  network="wifi_lte_handoff",
                                  policy="greedy_nw")),
        name="workload", meta={"models": [p.name for p in profs]})
    cap_run = simulate(profs, SimConfig(
        t_sla=320.0, n_requests=600, seed=3,
        network=CapturedTraceProcess(workload, mode="exact"),
        controller="reactive"))
    assert cap_run.switch_events
    trace = Trace.from_sim(cap_run, name="ctl",
                           meta={"models": [p.name for p in profs]})
    assert trace.meta["control_events"] == cap_run.switch_events
    assert trace.meta["control_modes"] == ["stationary", "degraded"]
    replay = simulate(profs, SimConfig(
        t_sla=320.0, n_requests=600, seed=99,
        network=CapturedTraceProcess(trace, mode="exact"),
        controller="reactive"))
    assert replay.switch_events == cap_run.switch_events
    assert np.array_equal(replay.modes, cap_run.modes)


def test_per_mode_buckets_follow_mode_index():
    r = simulate(paper_profiles(), SimConfig(
        t_sla=350.0, n_requests=600, seed=5, fleet="lte_outage_fleet",
        controller="reactive"))
    pm = r.per_mode()
    for k, name in enumerate(r.mode_names):
        mask = r.modes == k
        if not mask.any():
            assert name not in pm
            continue
        assert pm[name]["share"] == pytest.approx(mask.mean())
        assert pm[name]["attainment"] == pytest.approx(
            1.0 - r.violations[mask].mean())


def test_plane_preserves_caller_primed_controller():
    """A controller the caller already primed with device priors (the
    server/loop path, where the plane has no fleet info) must keep
    them — the plane only re-primes when it has priors of its own."""
    ctrl = AdaptiveController(detector="cusum:20")
    ctrl.prime({"phone": 60.0}, 60.0)
    plane = ControlPlane(Router(_profiles(), policy="greedy_nw"),
                         controller=ctrl)
    assert ctrl._priors == {"phone": 60.0}
    assert ctrl._default_prior == 60.0
    rng = np.random.default_rng(2)
    for x in rng.normal(60.0, 12.0, 15):
        d = plane.step(260.0, float(max(x, 1.0)), device_id="phone")
    assert d.mode == "stationary"
    # The stationary mode's per-request outage valve works off those
    # priors: one moderate hopeless spike (est > 2x prior; cloud path
    # 2*130 + fastest mu > 260ms SLA; device serves in 150ms) draws an
    # on-device advisory without any regime switch.
    d = plane.step(260.0, 130.0, device_id="phone", on_device_ms=150.0)
    assert d.mode == "stationary" and d.degraded and d.fallback


# -- satellites -------------------------------------------------------------

def test_hedge_at_p95_emits_pinned_deprecation():
    profs = paper_profiles()
    cfg = SimConfig(t_sla=300.0, n_requests=20, seed=0,
                    hedge_at_p95=True)
    with pytest.warns(DeprecationWarning, match="hedge_at_p95"):
        simulate(profs, cfg)


def test_router_invalid_estimator_spec_registry_error():
    """Satellite: a bad estimator spec through Router.__init__ raises
    the registry-style ValueError naming the valid spec forms (it used
    to surface as an opaque float() conversion error)."""
    profs = _profiles()
    with pytest.raises(ValueError, match=r"known: observed, mean, "
                                         r"ewma\[:alpha\], pctl\[:q\]"):
        Router(profs, t_estimator="ewma:fast")
    with pytest.raises(ValueError, match="known: observed"):
        Router(profs, t_estimator="kalman")
    with pytest.raises(ValueError, match="takes no"):
        Router(profs, t_estimator="observed:1")
    with pytest.raises(ValueError, match="TInputEstimator"):
        Router(profs, t_estimator=3.5)


def test_estimator_bank_validates_spec_eagerly():
    """The bank resolves estimators lazily per device; a bad spec must
    still fail at construction, not mid-run on first use."""
    with pytest.raises(ValueError, match="numeric"):
        EstimatorBank("pctl:high")
    with pytest.raises(ValueError, match="known: observed"):
        EstimatorBank("kalman")
    with pytest.raises(ValueError, match="TInputEstimator"):
        EstimatorBank(42)


def test_control_modes_registry_is_frozen_dataclass():
    m = CONTROL_MODES["degraded"]
    with pytest.raises(Exception):
        m.hedge = "none"
    assert copy.deepcopy(m) == m
