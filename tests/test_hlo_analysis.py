"""The HLO analyzer is the roofline's measurement instrument — pin its
parsing semantics with synthetic HLO text."""

import pytest

from repro.launch import hlo_analysis as H

HLO = """
HloModule jit_f

%wide.body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %a = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %b = f32[128,64]{1,0} parameter(1)
  %dot.1 = f32[8,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,64]{1,0} all-reduce(%dot.1), replica_groups={{0,1}}, to_apply=%sum.1
  %dus = f32[8,128]{1,0} dynamic-update-slice(%a, %small, %i0, %i1)
  %small = f32[8,8]{1,0} parameter(2)
  ROOT %t = (s32[], f32[8,128]) tuple(%c, %a)
}

%sum.1 (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add = f32[] add(%x, %y)
}

ENTRY %main.1 (arg: f32[8,128]) -> f32[8,128] {
  %arg = f32[8,128]{1,0} parameter(0)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%wide.body.1, backend_config={"known_trip_count":{"n":"12"}}
  %ag = f32[16,128]{1,0} all-gather(%arg), replica_groups={{0,1}}, dimensions={0}
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}

%cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  ROOT %lt = pred[] compare(%c, %n), direction=LT
}
"""


def test_shape_bytes_and_cap():
    assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H.shape_bytes("f32[8,128]{1,0}", cap_elem_bytes=2) == 8 * 128 * 2
    assert H.shape_bytes("s32[8]") == 32  # ints not capped
    assert H.shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_parse_and_trip_counts():
    comps, entry = H.parse_hlo(HLO)
    assert entry == "main.1"
    assert "wide.body.1" in comps
    mult = H.while_multipliers(comps, entry)
    assert mult["wide.body.1"] == 12.0
    assert mult["cond.1"] == 12.0
    assert mult["sum.1"] == 12.0  # via to_apply inside the body


def test_dot_flops_multiplied_by_trip():
    res = H.analyze(HLO, compute_elem_bytes=0)
    # dot: 2*M*N*K = 2*8*64*128, x12 trips
    assert res["dot_flops"] == 2 * 8 * 64 * 128 * 12


def test_collective_accounting():
    res = H.analyze(HLO, compute_elem_bytes=0)
    # all-reduce inside the while: operand 8*64*4 bytes, traffic 2x, x12
    ar = res["collective_traffic"]["all-reduce"]
    assert ar == 2 * 8 * 64 * 4 * 12
    # all-gather in entry: output bytes, x1
    ag = res["collective_traffic"]["all-gather"]
    assert ag == 16 * 128 * 4
    assert res["collective_operand_bytes"]["all-reduce"] == 8 * 64 * 4 * 12


def test_dus_counts_update_not_buffer():
    res = H.analyze(HLO, compute_elem_bytes=0)
    # the DUS moves 2x the 8x8 update (x12), never the full 8x128 buffer
    assert res["traffic_bytes"] >= 2 * 8 * 8 * 4 * 12
    # upper bound: no term should include the full buffer per iteration
    # except the dot reads; assemble expected components:
    dot_traffic = (8 * 64 + 8 * 128 + 128 * 64) * 4 * 12
    dus_traffic = 2 * 8 * 8 * 4 * 12
    ar_out_and_operand = (8 * 64 * 4) * 2 * 12
    ag_traffic = (16 * 128 + 8 * 128) * 4
    expected_max = dot_traffic + dus_traffic + ar_out_and_operand + ag_traffic
    assert res["traffic_bytes"] <= expected_max + 1


def test_control_flow_comps():
    comps, entry = H.parse_hlo(HLO)
    cf = H.control_flow_comps(comps, entry)
    assert cf == {"main.1", "wide.body.1", "cond.1"}
    assert "sum.1" not in cf  # reduce callee: cost attributed at call site
