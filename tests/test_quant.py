"""int8 quantization + error-feedback. (The hypothesis property test
lives in test_properties.py.)"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import (quantize_int8, dequantize_int8, quantize_tree,
                         dequantize_tree, ef_compress)


def test_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    # per-channel max error <= scale/2 (+eps for rounding at the edge)
    assert float((err - 0.51 * s).max()) <= 0.0


def test_storage_saving_75pct(rng):
    """Paper Fig 6: 8-bit quantization saves ~75% storage."""
    tree = {"w1": jnp.asarray(rng.normal(size=(128, 64)), jnp.float32),
            "w2": jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)}
    qt = quantize_tree(tree, min_size=1)
    raw = sum(x.size * 4 for x in jax.tree.leaves(tree))
    packed = 0
    for leaf in (qt["w1"], qt["w2"]):
        packed += leaf["q"].size * 1 + leaf["scale"].size * 4
    assert packed < 0.30 * raw
    back = dequantize_tree(qt, like=tree)
    rel = float(jnp.linalg.norm(back["w1"] - tree["w1"])
                / jnp.linalg.norm(tree["w1"]))
    assert rel < 0.02


def test_error_feedback_unbiased_accumulation_fixed():
    """One fixed-seed instance of the ef-compression drift bound (the
    hypothesis sweep is in test_properties.py)."""
    rng = np.random.default_rng(7)
    shape = (8, 16)
    resid = jnp.zeros(shape, jnp.float32)
    total_true = np.zeros(shape, np.float32)
    total_sent = np.zeros(shape, np.float32)
    for _ in range(12):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        q, s, resid = ef_compress(x, resid)
        total_true += np.asarray(x)
        total_sent += np.asarray(dequantize_int8(q, s))
    np.testing.assert_allclose(total_true - total_sent, np.asarray(resid),
                               atol=1e-4)
    assert float(np.abs(np.asarray(resid)).max()) < 0.1


def test_quantize_tree_skips_small_and_1d(rng):
    tree = {"big": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32),
            "bias": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
    qt = quantize_tree(tree, min_size=1024)
    assert isinstance(qt["big"], dict)
    assert not isinstance(qt["bias"], dict)  # 1-D left alone
