"""Optimizers vs handwritten references; loss decreases on a real task."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import CopyTask, MarkovLMTask
from repro.models import init_params
from repro.training.optim import (adamw, adafactor, constant_schedule,
                                  cosine_schedule, global_norm,
                                  clip_by_global_norm)
from repro.training.step import (make_train_step, init_train_state,
                                 cross_entropy)


def test_adamw_matches_reference_math():
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.01
    opt = adamw(constant_schedule(lr), b1, b2, eps, wd, clip_norm=1e9)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.5, 0.5, -1.0])}
    st = opt.init(p)
    new_p, st, _ = opt.update(g, st, p, jnp.int32(0))
    m = 0.1 * np.array([0.5, 0.5, -1.0])
    v = 0.05 * np.array([0.25, 0.25, 1.0])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    ref = (np.array([1.0, -2.0, 3.0])
           - lr * (mhat / (np.sqrt(vhat) + eps)
                   + wd * np.array([1.0, -2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_adamw_clip():
    g = {"w": jnp.array([3.0, 4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["w"]), [0.6, 0.8],
                               rtol=1e-6)


def test_adafactor_state_is_factored():
    opt = adafactor(constant_schedule(1e-2))
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(p)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (32,)
    assert st["v"]["b"]["v"].shape == (64,)
    # memory: factored state is O(m+n) not O(m*n)
    n_state = sum(x.size for x in jax.tree.leaves(st))
    assert n_state == 64 + 32 + 64


def test_adafactor_descends_quadratic():
    opt = adafactor(constant_schedule(0.1))
    p = {"w": jnp.full((8, 4), 5.0)}
    st = opt.init(p)
    for i in range(50):
        g = {"w": 2 * p["w"]}  # grad of ||w||^2
        p, st, _ = opt.update(g, st, p, jnp.int32(i))
    assert float(jnp.abs(p["w"]).max()) < 4.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(55)) < 1.0
    assert abs(float(lr(100)) - 0.1) < 1e-2


def test_cross_entropy_matches_manual():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 7)),
                         jnp.float32)
    labels = jnp.asarray([[1, 2, 3], [0, 6, 5]], jnp.int32)
    loss = cross_entropy(logits, labels)
    ref = -np.take_along_axis(
        np.asarray(jax.nn.log_softmax(logits, -1)),
        np.asarray(labels)[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(loss), ref, rtol=1e-6)


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "mamba2_2_7b"])
def test_loss_decreases(arch):
    """A few dozen steps on the Markov task must cut the loss clearly."""
    cfg = reduced_config(arch)
    task = MarkovLMTask(vocab=cfg.vocab, seed=0)
    opt = adamw(constant_schedule(3e-3))
    step = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    losses = []
    for i in range(30):
        b = task.batch(i, 8, 32)
        state, m = step(state, {"inputs": jnp.asarray(b["inputs"]),
                                "labels": jnp.asarray(b["labels"])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_remat_block_same_loss():
    cfg = reduced_config("yi_9b")
    task = MarkovLMTask(vocab=cfg.vocab, seed=0)
    b = task.batch(0, 4, 16)
    batch = {"inputs": jnp.asarray(b["inputs"]),
             "labels": jnp.asarray(b["labels"])}
    opt = adamw(constant_schedule(1e-3))
    out = {}
    for remat in ("none", "block"):
        c = cfg.with_runtime(remat=remat)
        step = jax.jit(make_train_step(c, opt))
        state = init_train_state(c, opt, jax.random.PRNGKey(0))
        _, m = step(state, batch)
        out[remat] = float(m["loss"])
    np.testing.assert_allclose(out["none"], out["block"], rtol=1e-5)
