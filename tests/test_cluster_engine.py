"""Scan cluster engine equivalence (serving/cluster_engine.py).

The contract is DESIGN.md §17's: `Cluster.run(engine="scan")` is the
python submit loop compiled into one jit `lax.scan` program, and every
integer-valued output is bit-for-bit the reference's — the
place/evict/scale/shed event log, the metrics rows (floats included:
the scan arithmetic is FMA-guarded to round exactly like numpy), the
per-replica zoo/queue/RNG state left behind, and the shared
controller's event log. The matrix covers every `TENANT_MIXES`
workload x feature toggles (hedge, shed, controller, memory budget)
plus the sharded controller program (skipped unless the host exposes
2+ XLA devices — set REPRO_HOST_DEVICES=2 or more to opt in, as the
CI fast job does).

Also pins `BlockNormals` (serving/stack.py): blocked refills and bulk
`take` must consume the generator stream exactly like scalar
``Generator.normal`` calls — the scan engine pre-draws whole replica
streams through it.
"""

import numpy as np
import pytest

from repro.configs.paper_zoo import (TENANT_MIXES, paper_profiles,
                                     scale_tenant_mix)
from repro.serving.cluster import (Cluster, make_tenant_columns,
                                   make_tenant_workload)
from repro.serving.stack import BlockNormals, SimReplicaStack

MODELS = ["mobilenetv1_025", "mobilenetv1_10", "inceptionv3"]
BUDGET = int(250e6)          # ~2 of 3 hot sets: eviction is exercised
N = 600
RATE = 40.0


def _replicas(n=3, seed=100):
    return [SimReplicaStack(paper_profiles(MODELS), seed=seed + i,
                            name=f"r{i}") for i in range(n)]


def _state(cl):
    """Everything a follow-up run could observe: queue clocks, zoo
    placement state, cold-start counters, and the exact RNG streams."""
    out = []
    for r in cl.replicas:
        pol_rng = getattr(r.router.policy, "rng", None)
        out.append(dict(
            free=r._server_free,
            zoo={n: (e.hot, e.last_used, e.loads, e.evictions)
                 for n, e in r.router.zoo.entries.items()},
            colds=r.router.zoo.total_cold_starts,
            rng=r.rng.gen.bit_generator.state["state"],
            block=(r.rng._i, r.rng._z.tolist()),
            pol_rng=(None if pol_rng is None
                     else pol_rng.bit_generator.state["state"])))
    return out


def _pair(mix, *, n=N, rate=RATE, shards=1, budget=BUDGET, seed=7, **kw):
    wl = make_tenant_workload(mix, n_requests=n, rate_hz=rate, seed=seed)
    cp = Cluster(_replicas(), mix, memory_budget_bytes=budget,
                 engine="python", **kw)
    cs = Cluster(_replicas(), mix, memory_budget_bytes=budget,
                 engine="scan", shards=shards, **kw)
    cp.run(list(wl))
    cs.run(list(wl))
    return cp, cs


def _assert_bitwise(cp, cs):
    assert cs.events == cp.events
    assert cs.metrics.records == cp.metrics.records
    assert cs.n_active == cp.n_active
    assert _state(cs) == _state(cp)
    if cp.controller is not None:
        assert cs.controller.events == cp.controller.events


@pytest.mark.parametrize("mix", sorted(TENANT_MIXES))
def test_tenant_mixes_bitwise(mix):
    _assert_bitwise(*_pair(mix))


@pytest.mark.parametrize("kw", [
    dict(hedge=False),
    dict(controller=None),
    dict(shed_factor=1e9),
    dict(min_active=2, scale_headroom=0.05),
], ids=["hedge-off", "controller-off", "shed-off", "scale-params"])
def test_feature_toggles_bitwise(kw):
    _assert_bitwise(*_pair("enterprise_degraded", **kw))


def test_heavy_shed_bitwise():
    """Saturating rate: most requests shed to on-device fallback."""
    cp, cs = _pair("consumer_burst", rate=300.0)
    assert any(e["kind"] == "shed" for e in cp.events)
    _assert_bitwise(cp, cs)


def test_no_budget_bitwise():
    """budget=None selects the eviction-free compile path (no vict
    outputs, hedge leg under lax.cond) — still bitwise."""
    cp, cs = _pair("enterprise_degraded", budget=None)
    assert not any(e["kind"] == "evict" for e in cp.events)
    _assert_bitwise(cp, cs)


def test_columnar_workload_bitwise():
    """`TenantColumns` straight into both engines (the fleet-scale
    path: array fleets, no Request materialization on the scan side)."""
    mix = scale_tenant_mix(1_000)
    wl = make_tenant_columns(mix, n_requests=N, rate_hz=12.0, seed=7)
    cp = Cluster(_replicas(), mix, engine="python")
    cs = Cluster(_replicas(), mix, engine="scan")
    cp.run(wl)
    cs.run(wl)
    _assert_bitwise(cp, cs)


def test_sharded_bitwise():
    import jax
    if jax.local_device_count() < 2:
        pytest.skip("needs 2+ XLA host devices "
                    "(run with REPRO_HOST_DEVICES=2 or more)")
    cp, cs2 = _pair("consumer_burst", shards=2)
    _assert_bitwise(cp, cs2)


# -- BlockNormals (the pre-drawn replica streams) --------------------------

def test_blocknormals_matches_scalar_stream():
    ref = np.random.default_rng(123)
    bn = BlockNormals(np.random.default_rng(123), block=7)
    locs = np.random.default_rng(1).uniform(-50, 50, 300)
    scales = np.random.default_rng(2).uniform(0.1, 20, 300)
    for loc, scale in zip(locs, scales):
        assert bn.normal(loc, scale) == ref.normal(loc, scale)


def test_blocknormals_take_advances_like_scalars():
    """`take(n)` hands out the next n standard normals and leaves the
    stream exactly where n scalar draws would — mixed freely with
    scalar draws across block boundaries."""
    ref = np.random.default_rng(9)
    bn = BlockNormals(np.random.default_rng(9), block=5)
    got = [bn.normal(), *bn.take(13), bn.normal(2.0, 3.0),
           *bn.take(4), bn.normal()]
    want = [ref.normal(), *[ref.normal() for _ in range(13)],
            ref.normal(2.0, 3.0), *[ref.normal() for _ in range(4)],
            ref.normal()]
    assert got == want
