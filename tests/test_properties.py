"""Hypothesis property tests (selection invariants, Welford vs numpy,
error-feedback quantization, NetworkProcess/TInputEstimator
invariants). Split out of the per-module test files so the tier-1
suite collects cleanly without the optional `hypothesis` dependency
(install via the `test` extra); the plain (example-based) NetworkProcess
tests live in test_network.py."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.paper_zoo import NETWORKS, sample_network
from repro.core.profiles import OnlineProfile
from repro.core.selection import ModelProfile, cnnselect
from repro.serving.network import (MIN_T_INPUT_MS, EWMAEstimator,
                                   MarkovProcess, StationaryProcess)


def mk_profiles(mus, sigmas, accs):
    return [ModelProfile(f"m{i}", a, m, s)
            for i, (m, s, a) in enumerate(zip(mus, sigmas, accs))]


# -- CNNSelect invariants (from test_selection.py) -------------------------

@settings(max_examples=200, deadline=None)
@given(
    mus=st.lists(st.floats(1, 1000), min_size=2, max_size=8),
    sigs=st.lists(st.floats(0.1, 100), min_size=8, max_size=8),
    accs=st.lists(st.floats(0.01, 1.0), min_size=8, max_size=8),
    t_sla=st.floats(10, 2000),
    t_input=st.floats(0, 300),
    t_threshold=st.floats(0, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_properties(mus, sigs, accs, t_sla, t_input, t_threshold, seed):
    k = len(mus)
    profs = mk_profiles(mus, sigs[:k], accs[:k])
    rng = np.random.default_rng(seed)
    r = cnnselect(profs, t_sla, t_input, t_threshold, rng)
    # 1. probabilities form a distribution supported on the eligible set
    assert abs(r.probs.sum() - 1.0) < 1e-6
    assert (r.probs >= 0).all()
    assert r.probs[~r.eligible].sum() < 1e-9
    # 2. the selected model is eligible
    assert r.eligible[r.index]
    # 3. the base model is always eligible
    assert r.eligible[r.base_index]
    # 4. fallback iff stage-1 constraints infeasible
    mu = np.array(mus[:k])
    sg = np.array(sigs[:k])
    feas = (mu + sg < r.t_up) & (mu - sg < r.t_low)
    assert r.fallback == (not feas.any())
    if r.fallback:
        assert r.index == int(np.argmin(mu))
    else:
        # 5. stage-1 base maximizes accuracy among feasible
        acc = np.array(accs[:k])
        assert acc[r.base_index] >= acc[feas].max() - 1e-9


# -- Welford profile store (from test_profiles.py) -------------------------

@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=2, max_size=200))
def test_welford_matches_numpy(xs):
    p = OnlineProfile()
    for x in xs:
        p.update(x)
    np.testing.assert_allclose(p.mean, np.mean(xs), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(p.std, np.std(xs, ddof=1), rtol=1e-5,
                               atol=1e-5)


# -- NetworkProcess invariants (plain variants in test_network.py) ---------

@settings(max_examples=100, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mean=st.floats(0.5, 400.0),
    std=st.floats(0.01, 200.0),
    n=st.integers(1, 500),
    dist=st.sampled_from(["lognormal", "normal"]),
)
def test_network_process_positive_and_deterministic(seed, mean, std, n,
                                                    dist):
    proc = StationaryProcess("x", mean, std, dist=dist)
    a = proc.sample_t_input(np.random.default_rng(seed), n)
    b = proc.sample_t_input(np.random.default_rng(seed), n)
    assert np.array_equal(a, b)                 # seeded determinism
    assert (a >= MIN_T_INPUT_MS).all()          # unified clamp


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    name=st.sampled_from(sorted(NETWORKS)),
    n=st.integers(1, 300),
)
def test_stationary_matches_legacy_draws_bit_for_bit(seed, name, n):
    """StationaryProcess consumes the identical RNG stream as the
    pre-refactor `sample_network`; the only difference is the clamp."""
    legacy = sample_network(name, np.random.default_rng(seed), n)
    proc = StationaryProcess.named(name).sample_t_input(
        np.random.default_rng(seed), n)
    assert np.array_equal(np.maximum(legacy, MIN_T_INPUT_MS), proc)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    p01=st.floats(0.05, 0.5),
    p10=st.floats(0.05, 0.5),
)
def test_markov_occupancy_converges_to_stationary(seed, p01, p10):
    mk = MarkovProcess([("a", 50.0, 10.0), ("b", 100.0, 20.0)],
                       [[1.0 - p01, p01], [p10, 1.0 - p10]])
    pi = mk.stationary_distribution()
    np.testing.assert_allclose(
        pi, [p10 / (p01 + p10), p01 / (p01 + p10)], atol=1e-8)
    _, reg = mk.sample_trace(np.random.default_rng(seed), 40000)
    occ = np.bincount(reg, minlength=2) / 40000.0
    # Worst-case occupancy std here is ~0.011 (rho = 1-p01-p10 = 0.9);
    # 0.05 is a >4-sigma bound.
    np.testing.assert_allclose(occ, pi, atol=0.05)


@settings(max_examples=50, deadline=None)
@given(
    xs=st.lists(st.floats(1.0, 1e4), min_size=2, max_size=100),
    alpha=st.floats(0.01, 1.0),
    prior=st.floats(1.0, 1e4),
)
def test_ewma_series_causal_and_bounded(xs, alpha, prior):
    xs = np.asarray(xs)
    s = EWMAEstimator(alpha=alpha, prior=prior).estimate_series(xs)
    # Cold start answers the prior; every estimate is a convex
    # combination of the prior and past observations.
    assert s[0] == prior
    lo, hi = min(prior, xs.min()), max(prior, xs.max())
    tol = 1e-6 * max(1.0, hi)        # blocked closed-form round-off
    assert ((s >= lo - tol) & (s <= hi + tol)).all()
    # Causality: changing the last observation cannot move any earlier
    # estimate (identical float ops -> bitwise equality).
    mutated = xs.copy()
    mutated[-1] = 12345.0
    s2 = EWMAEstimator(alpha=alpha, prior=prior).estimate_series(mutated)
    assert np.array_equal(s[:-1], s2[:-1])


# -- Change-point detector calibration (serving/control.py, §12) -----------

@settings(max_examples=100, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(50, 400),
    kind=st.sampled_from(["cusum", "ph"]),
)
def test_detector_false_positive_rate_on_stationary_stream(seed, n, kind):
    """Calibration: on standardized stationary residuals the default
    thresholds alarm at most once per 400 observations (the in-control
    ARL is ~70k+ for cusum h=10 k=0.5; empirically 12/3000 streams of
    400 see one alarm, none see two)."""
    from repro.serving.control import CusumDetector, PageHinkleyDetector

    det = (CusumDetector(threshold=10.0, drift=0.5, scale=1.0)
           if kind == "cusum"
           else PageHinkleyDetector(threshold=12.0, delta=0.5,
                                    scale=1.0))
    draws = np.random.default_rng(seed).normal(0.0, 1.0, n)
    alarms = sum(det.update(float(z)) != 0 for z in draws)
    assert alarms <= 1


@settings(max_examples=100, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    prefix=st.integers(0, 200),
    shift=st.floats(3.0, 8.0),
)
def test_detector_bounded_delay_on_injected_mean_step(seed, prefix,
                                                      shift):
    """Calibration: a >=3-sigma injected mean step fires the up-alarm
    within 30 post-shift observations (empirical worst case over 3000
    seeds: 7), regardless of the stationary prefix length."""
    from repro.serving.control import CusumDetector

    det = CusumDetector(threshold=10.0, drift=0.5, scale=1.0)
    rng = np.random.default_rng(seed)
    for z in rng.normal(0.0, 1.0, prefix):
        det.update(float(z))
    post = rng.normal(shift, 1.0, 30)
    assert any(det.update(float(z)) == 1 for z in post)


# -- Trace codec round trip (serving/trace.py, DESIGN.md §11) --------------

_trace_strategy = st.integers(1, 40).flatmap(lambda n: st.fixed_dictionaries({
    "t_arrival": st.lists(st.floats(0, 1e7, allow_nan=False,
                                    allow_infinity=False),
                          min_size=n, max_size=n),
    "device_id": st.lists(st.text(
        alphabet=st.characters(min_codepoint=33, max_codepoint=126),
        max_size=12), min_size=n, max_size=n),
    "t_input_ms": st.lists(st.floats(1e-3, 1e6, allow_nan=False,
                                     allow_infinity=False,
                                     exclude_min=True),
                           min_size=n, max_size=n),
    "regime_id": st.lists(st.integers(0, 5), min_size=n, max_size=n),
    "model": st.lists(st.text(
        alphabet=st.characters(min_codepoint=33, max_codepoint=126),
        max_size=12), min_size=n, max_size=n),
    "sla_ok": st.lists(st.sampled_from([-1, 0, 1]), min_size=n,
                       max_size=n),
}))


@settings(max_examples=60, deadline=None)
@given(cols=_trace_strategy, ext=st.sampled_from(["jsonl", "npz"]),
       name=st.text(max_size=16), seed=st.integers(0, 2**31 - 1))
def test_trace_codec_roundtrip_bit_exact(cols, ext, name, seed):
    """Any valid trace survives save/load bit-exact through both
    codecs (json float text is shortest-repr, which parses back to the
    identical double)."""
    import tempfile

    from repro.serving.trace import Trace

    tr = Trace(regime_names=[f"r{k}" for k in range(6)], name=name,
               source="property", meta={"seed": seed}, **cols)
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/t.{ext}"
        tr.save(path)
        back = Trace.load(path)
    for col in ("t_arrival", "device_id", "t_input_ms", "regime_id",
                "model", "sla_ok"):
        assert np.array_equal(getattr(tr, col), getattr(back, col)), col
    assert back.regime_names == tr.regime_names
    assert (back.name, back.source, back.meta) == (tr.name, tr.source,
                                                   tr.meta)
    assert back.schema_version == tr.schema_version


@settings(max_examples=30, deadline=None)
@given(cols=_trace_strategy, bad_schema=st.integers(-5, 100))
def test_trace_schema_mismatch_fails_fast(cols, bad_schema):
    import json as _json
    import tempfile

    from repro.serving.trace import TRACE_SCHEMA_VERSION, Trace

    hypothesis.assume(bad_schema != TRACE_SCHEMA_VERSION)
    tr = Trace(regime_names=[f"r{k}" for k in range(6)], **cols)
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/t.jsonl"
        tr.save(path)
        with open(path) as f:
            lines = f.read().splitlines()
        header = _json.loads(lines[0])
        header["schema"] = bad_schema
        with open(path, "w") as f:
            f.write("\n".join([_json.dumps(header)] + lines[1:]) + "\n")
        with pytest.raises(ValueError, match="schema version"):
            Trace.load(path)


# -- int8 error feedback (from test_quant.py) ------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), steps=st.integers(2, 30))
def test_error_feedback_unbiased_accumulation(seed, steps):
    """sum of dequantized ef-compressed xs tracks sum of xs: the residual
    absorbs the quantization error instead of letting it accumulate."""
    import jax.numpy as jnp

    from repro.quant import dequantize_int8, ef_compress

    rng = np.random.default_rng(seed)
    shape = (8, 16)
    resid = jnp.zeros(shape, jnp.float32)
    total_true = np.zeros(shape, np.float32)
    total_sent = np.zeros(shape, np.float32)
    for _ in range(steps):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        q, s, resid = ef_compress(x, resid)
        total_true += np.asarray(x)
        total_sent += np.asarray(dequantize_int8(q, s))
    # Residual bounds the drift: |sum_true - sum_sent| == |resid|
    np.testing.assert_allclose(total_true - total_sent, np.asarray(resid),
                               atol=1e-4)
    assert float(np.abs(np.asarray(resid)).max()) < 0.1  # one-step error


# -- masked flash kernel vs naive attention (from test_kernels.py) ---------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    t=st.integers(4, 24),
    window=st.sampled_from([0, 8]),
    edge=st.booleans(),
)
def test_flash_valid_from_matches_naive(seed, b, t, window, edge):
    """flash(valid_from) == naive(valid_from) for arbitrary per-row
    valid_from in [0, T] — including rows masked past every key (exact
    zeros) and, when edge, values pinned to block boundaries so the
    early-skip path is exercised."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.models.layers import attention_naive

    rng = np.random.default_rng(seed)
    hq, kv, hd = 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, t, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)), jnp.float32)
    vf_np = rng.integers(0, t + 1, size=b)
    if edge:
        vf_np = np.minimum((vf_np // 8) * 8, t)
    vf = jnp.asarray(vf_np, jnp.int32)
    pos = jnp.arange(t, dtype=jnp.int32)
    flash = ops.flash_attention_btHd(q, k, v, vf, window=window,
                                     block_q=8, block_k=8)
    naive = attention_naive(q, k, v, pos, pos, window=window, cap=0.0,
                            scale=hd ** -0.5, valid_from=vf)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               atol=2e-5, rtol=2e-5)


# -- scan engine vs python engine (from test_engine.py) --------------------

@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_devices=st.integers(1, 6),
    estimator=st.sampled_from(["observed", "ewma:0.4", "pctl:90",
                               "pctl:50"]),
    lag=st.integers(0, 2),
    controller=st.booleans(),
)
def test_scan_engine_matches_python_engine(seed, n_devices, estimator,
                                           lag, controller):
    """Arbitrary small fleet workloads: the jit lax.scan column program
    and the python reference loop make identical decisions (DESIGN.md
    §13)."""
    from repro.configs.paper_zoo import paper_profiles
    from repro.serving.fleet import ArrayFleet
    from repro.serving.simulator import SimConfig, simulate

    kw = ({"controller": "reactive", "estimator_lag": lag}
          if controller else
          {"t_estimator": estimator, "estimator_lag": lag})
    out = {}
    for engine in ("python", "scan"):
        cfg = SimConfig(t_sla=350.0, n_requests=48, seed=seed,
                        fleet=ArrayFleet(n_devices, seed=seed),
                        policy="greedy_nw", engine=engine, **kw)
        out[engine] = simulate(paper_profiles(), cfg)
    a, b = out["python"], out["scan"]
    assert list(a.selections) == list(b.selections)
    np.testing.assert_allclose(np.asarray(a.latencies),
                               np.asarray(b.latencies), rtol=1e-9)
    ea = a.switch_events or []
    eb = b.switch_events or []
    assert [(e["request"], e["device"], e["from"], e["to"])
            for e in ea] == [(e["request"], e["device"], e["from"],
                              e["to"]) for e in eb]


# -- scan cluster engine vs python Cluster (test_cluster_engine.py) --------

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(20, 120),
    rate=st.floats(5.0, 300.0, allow_nan=False, allow_infinity=False),
    mix=st.sampled_from(["consumer_burst", "enterprise_degraded"]),
    hedge=st.booleans(),
    budget=st.booleans(),
    controller=st.booleans(),
)
def test_cluster_scan_matches_python(seed, n, rate, mix, hedge, budget,
                                     controller):
    """Arbitrary small multi-tenant workloads: the jit lax.scan cluster
    program and the python Cluster loop emit identical event logs,
    metrics rows, and end-state (DESIGN.md §17)."""
    from test_cluster_engine import _assert_bitwise, _pair

    cp, cs = _pair(mix, n=n, rate=rate, seed=seed, hedge=hedge,
                   budget=int(250e6) if budget else None,
                   controller="reactive" if controller else None)
    _assert_bitwise(cp, cs)


# -- continuous batcher slot lifecycle (from test_serving.py) --------------

@settings(max_examples=60, deadline=None)
@given(
    batch_size=st.integers(1, 4),
    specs=st.lists(
        st.tuples(st.floats(0, 50, allow_nan=False, allow_infinity=False),
                  st.integers(1, 5)),
        min_size=1, max_size=16),
    budget=st.one_of(st.none(), st.integers(1, 8)),
)
def test_batcher_slot_lifecycle(batch_size, specs, budget):
    """Arbitrary arrival schedules: the form_group -> decode ->
    backfill loop retires every request exactly once with its full
    token quota, never double-books a slot, never starts a request
    before it arrives, and defers over-budget joiners rather than
    dropping them. The harness (shared with the deterministic
    test_serving tests, so the logic runs without hypothesis too)
    asserts the invariants every round."""
    from test_serving import drive_batcher

    drive_batcher(batch_size, 4, specs, budget)
