"""Hypothesis property tests (selection invariants, Welford vs numpy,
error-feedback quantization). Split out of the per-module test files so
the tier-1 suite collects cleanly without the optional `hypothesis`
dependency (install via the `test` extra)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.profiles import OnlineProfile
from repro.core.selection import ModelProfile, cnnselect


def mk_profiles(mus, sigmas, accs):
    return [ModelProfile(f"m{i}", a, m, s)
            for i, (m, s, a) in enumerate(zip(mus, sigmas, accs))]


# -- CNNSelect invariants (from test_selection.py) -------------------------

@settings(max_examples=200, deadline=None)
@given(
    mus=st.lists(st.floats(1, 1000), min_size=2, max_size=8),
    sigs=st.lists(st.floats(0.1, 100), min_size=8, max_size=8),
    accs=st.lists(st.floats(0.01, 1.0), min_size=8, max_size=8),
    t_sla=st.floats(10, 2000),
    t_input=st.floats(0, 300),
    t_threshold=st.floats(0, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_properties(mus, sigs, accs, t_sla, t_input, t_threshold, seed):
    k = len(mus)
    profs = mk_profiles(mus, sigs[:k], accs[:k])
    rng = np.random.default_rng(seed)
    r = cnnselect(profs, t_sla, t_input, t_threshold, rng)
    # 1. probabilities form a distribution supported on the eligible set
    assert abs(r.probs.sum() - 1.0) < 1e-6
    assert (r.probs >= 0).all()
    assert r.probs[~r.eligible].sum() < 1e-9
    # 2. the selected model is eligible
    assert r.eligible[r.index]
    # 3. the base model is always eligible
    assert r.eligible[r.base_index]
    # 4. fallback iff stage-1 constraints infeasible
    mu = np.array(mus[:k])
    sg = np.array(sigs[:k])
    feas = (mu + sg < r.t_up) & (mu - sg < r.t_low)
    assert r.fallback == (not feas.any())
    if r.fallback:
        assert r.index == int(np.argmin(mu))
    else:
        # 5. stage-1 base maximizes accuracy among feasible
        acc = np.array(accs[:k])
        assert acc[r.base_index] >= acc[feas].max() - 1e-9


# -- Welford profile store (from test_profiles.py) -------------------------

@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=2, max_size=200))
def test_welford_matches_numpy(xs):
    p = OnlineProfile()
    for x in xs:
        p.update(x)
    np.testing.assert_allclose(p.mean, np.mean(xs), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(p.std, np.std(xs, ddof=1), rtol=1e-5,
                               atol=1e-5)


# -- int8 error feedback (from test_quant.py) ------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), steps=st.integers(2, 30))
def test_error_feedback_unbiased_accumulation(seed, steps):
    """sum of dequantized ef-compressed xs tracks sum of xs: the residual
    absorbs the quantization error instead of letting it accumulate."""
    import jax.numpy as jnp

    from repro.quant import dequantize_int8, ef_compress

    rng = np.random.default_rng(seed)
    shape = (8, 16)
    resid = jnp.zeros(shape, jnp.float32)
    total_true = np.zeros(shape, np.float32)
    total_sent = np.zeros(shape, np.float32)
    for _ in range(steps):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        q, s, resid = ef_compress(x, resid)
        total_true += np.asarray(x)
        total_sent += np.asarray(dequantize_int8(q, s))
    # Residual bounds the drift: |sum_true - sum_sent| == |resid|
    np.testing.assert_allclose(total_true - total_sent, np.asarray(resid),
                               atol=1e-4)
    assert float(np.abs(np.asarray(resid)).max()) < 0.1  # one-step error
