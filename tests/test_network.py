"""NetworkProcess hierarchy + TInputEstimator unit/boundary tests
(plain — the hypothesis-driven property tests live in
test_properties.py). Covers the unified positivity clamp, seeded
determinism, legacy bit-for-bit compatibility, Markov regime behaviour,
trace replay, estimator cold start / tracking lag, and the
resize_decision boundary cases."""

import numpy as np
import pytest

from repro.configs.paper_zoo import (NETWORK_SCENARIOS, NETWORKS,
                                     sample_network, synthetic_trace)
from repro.serving.network import (MIN_T_INPUT_MS, EWMAEstimator,
                                   MarkovProcess, MeanEstimator,
                                   NetworkModel, ObservedEstimator,
                                   PercentileEstimator, StationaryProcess,
                                   TraceReplayProcess, make_estimator,
                                   make_network, resize_decision)

ALL_SPECS = (list(NETWORKS) + list(NETWORK_SCENARIOS)
             + ["trace:wifi_lte_step", "trace:diurnal"])


# -- processes --------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_SPECS)
def test_seeded_determinism_and_positivity(spec):
    proc = make_network(spec)
    a, ra = proc.sample_trace(np.random.default_rng(7), 2000)
    b, rb = proc.sample_trace(np.random.default_rng(7), 2000)
    assert np.array_equal(a, b) and np.array_equal(ra, rb)
    assert (a >= MIN_T_INPUT_MS).all()
    assert len(proc.regime_names()) >= ra.max() + 1


def test_clamp_applies_to_every_process():
    """The 1.0 ms floor is unified in the base class — pre-refactor only
    the legacy fallback path clamped."""
    rng = np.random.default_rng(0)
    # A normal with mean << 0 would emit negative times unclamped.
    t = StationaryProcess("x", 0.5, 5.0, dist="normal").sample_t_input(
        rng, 5000)
    assert (t >= MIN_T_INPUT_MS).all() and (t == MIN_T_INPUT_MS).any()
    # Legacy ad-hoc NetworkModel keeps the same clamped-normal path.
    t = NetworkModel("custom", 0.5, 5.0).sample_t_input(rng, 5000)
    assert (t >= MIN_T_INPUT_MS).all()
    # Markov states with sub-ms means clamp too.
    mk = MarkovProcess([("a", 0.01, 0.5), ("b", 0.02, 0.5)],
                       [[0.5, 0.5], [0.5, 0.5]])
    assert (mk.sample_t_input(rng, 2000) >= MIN_T_INPUT_MS).all()


def test_stationary_matches_legacy_networkmodel_bit_for_bit():
    for name in NETWORKS:
        legacy = sample_network(name, np.random.default_rng(11), 512)
        proc = StationaryProcess.named(name).sample_t_input(
            np.random.default_rng(11), 512)
        shim = NetworkModel.named(name).sample_t_input(
            np.random.default_rng(11), 512)
        assert np.array_equal(legacy, proc), name
        assert np.array_equal(legacy, shim), name


def test_nonpositive_lognormal_mean_rejected():
    """log(mean <= 0) would emit NaN draws the clamp can't catch."""
    with pytest.raises(ValueError):
        StationaryProcess("x", 0.0, 5.0)
    with pytest.raises(ValueError):
        StationaryProcess("x", -5.0, 5.0)
    with pytest.raises(ValueError):
        StationaryProcess("x", 5.0, -1.0)
    with pytest.raises(ValueError):
        MarkovProcess([("a", -5.0, 5.0), ("b", 10.0, 5.0)],
                      [[0.5, 0.5], [0.5, 0.5]])
    # A normal-dist model may have any mean — the clamp handles it.
    assert (StationaryProcess("x", -5.0, 5.0, dist="normal")
            .sample_t_input(np.random.default_rng(0), 100)
            >= MIN_T_INPUT_MS).all()


def test_markov_validation():
    with pytest.raises(ValueError):
        MarkovProcess(["campus_wifi"], [[0.5, 0.5]])      # shape mismatch
    with pytest.raises(ValueError):
        MarkovProcess(["campus_wifi", "lte"],
                      [[0.9, 0.2], [0.5, 0.5]])           # rows != 1
    with pytest.raises(ValueError):
        MarkovProcess(["campus_wifi", "no_such_state"],
                      [[0.5, 0.5], [0.5, 0.5]])
    with pytest.raises(ValueError):
        MarkovProcess(["campus_wifi", "lte"],
                      [[0.5, 0.5], [0.5, 0.5]], start=2)


def test_markov_occupancy_converges_to_stationary():
    # Fast-mixing asymmetric chain: occupancy over a long trace matches
    # the analytic stationary distribution.
    mk = MarkovProcess(["campus_wifi", "lte"],
                       [[0.8, 0.2], [0.4, 0.6]], name="mix")
    pi = mk.stationary_distribution()
    np.testing.assert_allclose(pi, [2 / 3, 1 / 3], atol=1e-9)
    _, reg = mk.sample_trace(np.random.default_rng(5), 60000)
    occ = np.bincount(reg, minlength=2) / len(reg)
    np.testing.assert_allclose(occ, pi, atol=0.02)
    assert mk.mean == pytest.approx(
        pi @ [NETWORKS["campus_wifi"]["mean"], NETWORKS["lte"]["mean"]])


def test_markov_regime_means_track_states():
    mk = MarkovProcess.from_scenario("wifi_lte_handoff")
    t, reg = mk.sample_trace(np.random.default_rng(1), 30000)
    wifi, lte = t[reg == 0], t[reg == 1]
    assert len(wifi) and len(lte)
    assert abs(wifi.mean() - NETWORKS["campus_wifi"]["mean"]) < 6.0
    assert abs(lte.mean() - NETWORKS["lte"]["mean"]) < 12.0


def test_trace_replay_cycles_and_jitter():
    tr = TraceReplayProcess([10.0, 20.0, 30.0], jitter_cv=0.0)
    t, reg = tr.sample_trace(np.random.default_rng(0), 7)
    np.testing.assert_allclose(t, [10, 20, 30, 10, 20, 30, 10])
    assert tr.mean == pytest.approx(20.0)
    jit = TraceReplayProcess([50.0] * 4, jitter_cv=0.2)
    t, _ = jit.sample_trace(np.random.default_rng(0), 8000)
    assert abs(t.mean() - 50.0) < 2.0 and t.std() > 5.0
    with pytest.raises(ValueError):
        TraceReplayProcess([])
    with pytest.raises(ValueError):
        TraceReplayProcess([10.0, -1.0])


def test_trace_replay_default_names_cover_labels():
    tr = TraceReplayProcess([10.0, 10.0, 100.0, 100.0], jitter_cv=0.0,
                            name="step", regime_labels=[0, 0, 1, 1])
    assert tr.regime_names() == ["step:0", "step:1"]
    named = TraceReplayProcess([10.0, 100.0], regime_labels=[0, 1],
                               regime_names=["lo", "hi"])
    assert named.regime_names() == ["lo", "hi"]
    with pytest.raises(ValueError):
        TraceReplayProcess([10.0, 100.0], regime_labels=[0, 1],
                           regime_names=["only_one"])


def test_synthetic_traces():
    step = synthetic_trace("wifi_lte_step", 100)
    assert step[0] == NETWORKS["campus_wifi"]["mean"]
    assert step[-1] == NETWORKS["lte"]["mean"]
    diurnal = synthetic_trace("diurnal", 256)
    assert diurnal.min() >= NETWORKS["campus_wifi"]["mean"] - 1e-9
    assert diurnal.max() <= NETWORKS["cellular_hotspot"]["mean"] + 1e-9
    with pytest.raises(ValueError):
        synthetic_trace("no_such_trace")


def test_make_network_resolution():
    assert isinstance(make_network("campus_wifi"), StationaryProcess)
    assert isinstance(make_network("wifi_lte_handoff"), MarkovProcess)
    assert isinstance(make_network("trace:diurnal"), TraceReplayProcess)
    proc = StationaryProcess("x", 10.0, 1.0)
    assert make_network(proc) is proc
    with pytest.raises(ValueError):
        make_network("no_such_network")
    with pytest.raises(ValueError):
        make_network(("campus_wifi",))      # non-str, non-process spec


def test_legacy_estimate_t_input_shim_deprecated():
    """The pre-estimator shim still answers (observed, else the mean)
    but now warns: the estimator API (`make_estimator`) owns budgeting."""
    net = NetworkModel.named("campus_wifi")
    with pytest.deprecated_call():
        assert net.estimate_t_input(42.0) == 42.0
    with pytest.deprecated_call():
        assert net.estimate_t_input() == net.mean_ms
    # The replacements answer identically, warning-free.
    assert make_estimator("observed").estimate(observed=42.0) == 42.0
    assert make_estimator("mean", prior=net.mean).estimate() == net.mean_ms


# -- estimators -------------------------------------------------------------

def test_estimator_registry():
    assert isinstance(make_estimator("observed"), ObservedEstimator)
    assert isinstance(make_estimator("mean", prior=3.0), MeanEstimator)
    e = make_estimator("ewma:0.5")
    assert isinstance(e, EWMAEstimator) and e.alpha == 0.5
    p = make_estimator("pctl:75")
    assert isinstance(p, PercentileEstimator) and p.q == 75.0
    assert make_estimator(None) is None
    inst = EWMAEstimator()
    assert make_estimator(inst) is inst
    with pytest.raises(ValueError):
        make_estimator("kalman")
    with pytest.raises(ValueError):
        make_estimator("ewma:1.5")


def test_mean_estimator_without_prior_fails_fast():
    """A prior-less 'mean' spec can never answer — it must raise, not
    silently degrade to the (adaptive) observed behaviour."""
    with pytest.raises(ValueError):
        make_estimator("mean")
    with pytest.raises(ValueError):
        MeanEstimator().estimate(observed=5.0)
    with pytest.raises(ValueError):
        MeanEstimator().estimate_series(np.ones(3))


def test_estimator_cold_start():
    # Prior wins when cold; the observation is the last resort.
    assert EWMAEstimator(prior=40.0).estimate() == 40.0
    assert EWMAEstimator().estimate(observed=55.0) == 55.0
    with pytest.raises(ValueError):
        EWMAEstimator().estimate()
    assert PercentileEstimator(prior=40.0).estimate() == 40.0
    assert MeanEstimator(prior=63.0).estimate(observed=999.0) == 63.0
    assert ObservedEstimator(prior=63.0).estimate(observed=999.0) == 999.0
    assert ObservedEstimator(prior=63.0).estimate() == 63.0
    # After one observation the state takes over from the prior.
    e = EWMAEstimator(alpha=0.5, prior=40.0)
    e.observe(100.0)
    assert e.estimate() == 100.0
    e.observe(50.0)
    assert e.estimate() == pytest.approx(75.0)


def test_ewma_tracks_step_change_with_lag():
    e = EWMAEstimator(alpha=0.2, prior=63.0)
    xs = np.array([63.0] * 100 + [126.0] * 100)
    series = e.estimate_series(xs)
    # Causal: the estimate at the step index still reflects the old
    # regime, then converges geometrically (1-alpha)^k toward the new.
    assert series[100] == pytest.approx(63.0, abs=1e-6)
    lag = np.argmax(series[100:] > 0.95 * 126.0)
    expected = np.log(0.05 * 126.0 / 63.0) / np.log(0.8)
    assert 0 < lag <= expected + 2
    assert series[-1] == pytest.approx(126.0, rel=0.01)


def test_estimator_series_matches_scalar_protocol():
    xs = np.random.default_rng(3).lognormal(4.0, 0.3, 300)
    for spec in ("observed", "mean", "ewma:0.05", "ewma:0.3", "ewma:0.9",
                 "ewma:1.0", "pctl:85"):
        fast = make_estimator(spec, prior=60.0).estimate_series(xs)
        slow_est = make_estimator(spec, prior=60.0)
        slow = np.empty_like(xs)
        for i, x in enumerate(xs):
            slow[i] = slow_est.estimate(observed=float(x))
            slow_est.observe(float(x))
        np.testing.assert_allclose(fast, slow, rtol=1e-9,
                                   err_msg=spec)


def test_percentile_estimator_window():
    p = PercentileEstimator(q=100.0, window=3)
    for v in (10.0, 50.0, 20.0, 30.0, 5.0):
        p.observe(v)
    # Window keeps the last 3 observations only: max is 30, not 50.
    assert p.estimate() == 30.0


# -- resize_decision boundaries (paper §3.1) --------------------------------

def test_resize_noop_at_or_below_target():
    assert not resize_decision(110.0)
    assert not resize_decision(50.0)
    assert not resize_decision(0.0)


def test_resize_break_even_size():
    # resize wins iff scale*x + up*110 <= up*x, i.e.
    # x >= up*110 / (up - scale) = 0.214*110/0.049 ~ 480.4 KB.
    break_even = 0.214 * 110.0 / (0.214 - 0.165)
    assert not resize_decision(break_even - 1.0)
    assert resize_decision(break_even + 1.0)
    # The boundary is inclusive up to float rounding.
    assert resize_decision(break_even + 1e-9)


def test_resize_custom_cost_coefficients():
    # Free resize: always worth it above the target size.
    assert resize_decision(111.0, scale_ms_per_kb=0.0)
    # Resize slower than the upload saving: never worth it.
    assert not resize_decision(5000.0, scale_ms_per_kb=1.0,
                               upload_ms_per_kb=0.2)
    # Equal-cost knife edge at the <= boundary.
    assert resize_decision(220.0, scale_ms_per_kb=0.107,
                           upload_ms_per_kb=0.214)
