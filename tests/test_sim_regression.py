"""Golden regression harness for the serving simulator.

Pins `SimResult.attainment` / `accuracy` / `mean_latency` for fixed
seeds across every registry policy and two networks, so refactors of
the network/selection/simulator layers cannot silently shift simulator
numbers. The goldens were captured from the pre-NetworkProcess
simulator (PR 1) and reproduced bit-for-bit by the refactor — a change
here must be intentional and called out in CHANGES.md.

Numbers are exact for numpy-driven policies; cnnselect additionally
pins the jax threefry/Gumbel stream, so a jax upgrade that changes RNG
semantics will (by design) trip these tests.
"""

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.serving.simulator import SimConfig, simulate

SLA_MS = 300.0
N_REQUESTS = 400
SEED = 7

# (network, policy) -> (attainment, accuracy, mean_latency)
GOLDEN = {
    ("campus_wifi", "cnnselect"): (1.0, 0.815535, 225.61006766393766),
    ("campus_wifi", "greedy"): (0.9675, 0.826, 233.83041029297434),
    ("campus_wifi", "greedy_nw"): (0.995, 0.82514, 232.85234511588246),
    ("campus_wifi", "random"): (1.0, 0.68475, 172.61296963778324),
    ("campus_wifi", "static:mobilenetv1_10"):
        (1.0, 0.718, 149.76329972073734),
    ("campus_wifi", "oracle"): (1.0, 0.8250774999999999,
                                232.74105129718745),
    ("lte", "cnnselect"): (0.92, 0.72139, 252.3159290445964),
    ("lte", "greedy"): (0.6275, 0.826, 293.4034219661994),
    ("lte", "greedy_nw"): (0.895, 0.7849249999999998, 272.0746307820539),
    ("lte", "random"): (0.855, 0.68475, 232.18598131100833),
    ("lte", "static:mobilenetv1_10"): (0.9175, 0.718, 209.33631139396238),
    ("lte", "oracle"): (0.92, 0.7894249999999998, 271.4706502329876),
}


@pytest.mark.parametrize("network,policy", sorted(GOLDEN),
                         ids=lambda v: str(v))
def test_simulator_numbers_pinned(network, policy):
    att, acc, lat = GOLDEN[(network, policy)]
    r = simulate(paper_profiles(), SimConfig(
        t_sla=SLA_MS, n_requests=N_REQUESTS, network=network,
        policy=policy, seed=SEED))
    assert r.attainment == pytest.approx(att, abs=1e-12)
    assert r.accuracy == pytest.approx(acc, abs=1e-12)
    assert r.mean_latency == pytest.approx(lat, abs=1e-9)


@pytest.mark.parametrize("network,policy", sorted(GOLDEN),
                         ids=lambda v: str(v))
def test_scan_engine_reproduces_goldens(network, policy):
    """The vectorized `engine="scan"` program (DESIGN.md §13) must land
    on the same pinned numbers as the python reference loop."""
    att, acc, lat = GOLDEN[(network, policy)]
    r = simulate(paper_profiles(), SimConfig(
        t_sla=SLA_MS, n_requests=N_REQUESTS, network=network,
        policy=policy, seed=SEED, engine="scan"))
    assert r.attainment == pytest.approx(att, abs=1e-12)
    assert r.accuracy == pytest.approx(acc, abs=1e-12)
    assert r.mean_latency == pytest.approx(lat, abs=1e-9)


def test_fleet_none_is_the_golden_path():
    """`fleet=None` (the default) plus the new hedging/fleet knobs at
    their defaults must be byte-identical to the pinned pre-fleet
    simulator — the golden values above run through exactly this
    config."""
    profs = paper_profiles()
    base = simulate(profs, SimConfig(t_sla=SLA_MS, n_requests=N_REQUESTS,
                                     seed=SEED))
    explicit = simulate(profs, SimConfig(
        t_sla=SLA_MS, n_requests=N_REQUESTS, seed=SEED, fleet=None,
        hedge="none", estimator_lag=0, estimator_scope="device"))
    assert np.array_equal(base.selections, explicit.selections)
    assert np.array_equal(base.latencies, explicit.latencies)
    assert base.fallbacks == explicit.fallbacks == 0


def test_legacy_hedge_at_p95_maps_to_p95_mode():
    """The old boolean knob and hedge="p95" are the same policy."""
    profs = paper_profiles()
    kw = dict(t_sla=SLA_MS, n_requests=300, seed=SEED,
              arrival_rate_hz=30.0, n_servers=2)
    # The legacy boolean now carries a pinned DeprecationWarning
    # (mirroring NetworkModel.estimate_t_input, PR 3).
    with pytest.warns(DeprecationWarning, match="hedge_at_p95"):
        legacy = simulate(profs, SimConfig(**kw, hedge_at_p95=True))
    mode = simulate(profs, SimConfig(**kw, hedge="p95"))
    assert np.array_equal(legacy.latencies, mode.latencies)
    assert legacy.hedges == mode.hedges > 0


def test_estimator_none_is_pre_refactor_path():
    """t_estimator=None must be byte-identical to the legacy observed-
    upload-time budgeting — the explicit 'observed' estimator too."""
    profs = paper_profiles()
    base = simulate(profs, SimConfig(t_sla=SLA_MS, n_requests=N_REQUESTS,
                                     seed=SEED))
    obs = simulate(profs, SimConfig(t_sla=SLA_MS, n_requests=N_REQUESTS,
                                    seed=SEED, t_estimator="observed"))
    assert np.array_equal(base.selections, obs.selections)
    assert np.array_equal(base.latencies, obs.latencies)


def test_estimator_instance_not_mutated_across_runs():
    """simulate() must copy a prebuilt estimator instance — otherwise
    state leaks between runs and identical configs diverge (breaking
    sla_sweep / attainment_improvement determinism)."""
    from repro.serving.network import EWMAEstimator

    profs = paper_profiles()
    est = EWMAEstimator(alpha=0.2)
    cfg = SimConfig(t_sla=SLA_MS, n_requests=200, seed=SEED,
                    network="wifi_lte_handoff", t_estimator=est)
    a = simulate(profs, cfg)
    b = simulate(profs, cfg)
    assert np.array_equal(a.selections, b.selections)
    assert est._est is None              # caller's instance untouched
    assert est.prior is None
    # A prior-less instance gets the same process-mean cold-start prior
    # a string spec would: the two configs are equivalent.
    c = simulate(profs, SimConfig(t_sla=SLA_MS, n_requests=200, seed=SEED,
                                  network="wifi_lte_handoff",
                                  t_estimator="ewma:0.2"))
    assert np.array_equal(a.selections, c.selections)


@pytest.mark.slow
def test_10k_run_statistics_pinned():
    """The full-scale 10k-request run (paper §5.2) — slow suite only."""
    r = simulate(paper_profiles(), SimConfig(
        t_sla=SLA_MS, n_requests=10000, seed=0))
    assert r.attainment == pytest.approx(0.9988, abs=1e-12)
    assert r.accuracy == pytest.approx(0.8093139000000001, abs=1e-12)
    assert r.mean_latency == pytest.approx(228.15808780923885, abs=1e-9)
