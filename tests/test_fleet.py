"""Device-fleet layer tests (serving/fleet.py + simulator integration):
per-device trace determinism, EstimatorBank isolation / lag semantics,
outage-aware hedging firing exactly once per request, and on-device
fallback accounting."""

import numpy as np
import pytest

from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import on_device_fallback_decision
from repro.serving.fleet import (DeviceProfile, EstimatorBank, FleetMixture,
                                 device_tier_profile, make_fleet)
from repro.serving.network import MIN_T_INPUT_MS, EWMAEstimator
from repro.serving.simulator import SimConfig, simulate


def _two_device_fleet(net_b="lte"):
    return FleetMixture([
        DeviceProfile("a", "campus_wifi", weight=0.5),
        DeviceProfile("b", net_b, weight=0.5),
    ])


# -- FleetMixture -----------------------------------------------------------

def test_fleet_trace_determinism_under_fixed_seed():
    fl = _two_device_fleet()
    t1 = fl.sample_trace(np.random.default_rng(7), 2000)
    t2 = fl.sample_trace(np.random.default_rng(7), 2000)
    assert np.array_equal(t1.t_input, t2.t_input)
    assert np.array_equal(t1.device_index, t2.device_index)
    assert np.array_equal(t1.regime, t2.regime)
    assert (t1.t_input >= MIN_T_INPUT_MS).all()


def test_fleet_per_device_streams_are_independent():
    """Changing device B's process must not change device A's draws
    (same seed, same weights -> same assignment, same A stream)."""
    t_lte = _two_device_fleet("lte").sample_trace(
        np.random.default_rng(7), 3000)
    t_hot = _two_device_fleet("cellular_hotspot").sample_trace(
        np.random.default_rng(7), 3000)
    assert np.array_equal(t_lte.device_index, t_hot.device_index)
    a = t_lte.device_index == 0
    assert np.array_equal(t_lte.t_input[a], t_hot.t_input[a])
    assert not np.array_equal(t_lte.t_input[~a], t_hot.t_input[~a])


def test_fleet_regime_names_are_device_prefixed_and_global():
    fl = FleetMixture([DeviceProfile("a", "campus_wifi"),
                       DeviceProfile("b", "lte_outages")])
    names = fl.regime_names()
    assert names[0] == "a:campus_wifi"
    assert names[1:] == ["b:lte", "b:degraded_lte", "b:outage"]
    tr = fl.sample_trace(np.random.default_rng(0), 5000)
    # Device a's requests sit in regime 0; b's occupy the offset block.
    assert (tr.regime[tr.device_index == 0] == 0).all()
    assert (tr.regime[tr.device_index == 1] >= 1).all()
    assert tr.regime.max() < len(names)


def test_fleet_validation_and_priors():
    with pytest.raises(ValueError):
        FleetMixture([])
    with pytest.raises(ValueError):
        FleetMixture([DeviceProfile("a", "lte"),
                      DeviceProfile("a", "campus_wifi")])
    with pytest.raises(ValueError):
        FleetMixture([DeviceProfile("a", "lte", weight=0.0)])
    fl = _two_device_fleet()
    assert fl.priors() == {"a": 63.0, "b": 95.0}
    assert fl.mean == pytest.approx(0.5 * 63.0 + 0.5 * 95.0)


def test_make_fleet_resolution():
    fl = make_fleet("lte_outage_fleet")
    assert [d.tier for d in fl.devices] == ["flagship", "midrange",
                                            "legacy"]
    assert fl.devices[1].network == "lte_outages"     # scenario override
    assert fl.devices[1].on_device_ms == 133.0        # pixel2 mnv1_025
    assert fl.devices[1].on_device_accuracy == pytest.approx(0.497)
    assert fl.devices[2].on_device_ms == 0.0          # legacy: no local CNN
    assert make_fleet(fl) is fl
    assert make_fleet(None) is None
    with pytest.raises(ValueError):
        make_fleet("no_such_fleet")
    with pytest.raises(ValueError):
        device_tier_profile("no_such_tier")


# -- EstimatorBank ----------------------------------------------------------

def test_bank_isolates_devices():
    """One device's outage must not move another device's estimate."""
    bank = EstimatorBank("ewma:0.2", priors={"a": 60.0, "b": 90.0})
    for _ in range(50):
        bank.observe("a", 900.0)          # device a collapses
    assert bank.estimate("a") > 500.0
    assert bank.estimate("b") == 90.0     # b still answers its prior
    bank.observe("b", 100.0)
    assert bank.estimate("b") == 100.0


def test_bank_series_matches_scalar_protocol():
    rng = np.random.default_rng(5)
    xs = rng.lognormal(4.0, 0.4, 400)
    keys = rng.choice(["a", "b", "c"], size=400)
    for spec, lag in (("ewma:0.3", 0), ("ewma:0.3", 1), ("ewma:0.3", 3),
                      ("pctl:85", 1), ("mean", 2), ("ewma:1.0", 1)):
        fast = EstimatorBank(spec, default_prior=55.0, lag=lag)
        out_fast = fast.estimate_series(xs, keys)
        slow = EstimatorBank(spec, default_prior=55.0, lag=lag)
        out_slow = np.empty_like(xs)
        for i, (x, k) in enumerate(zip(xs, keys)):
            out_slow[i] = slow.estimate(k, observed=float(x))
            slow.observe(k, float(x))
        np.testing.assert_allclose(out_fast, out_slow, rtol=1e-9,
                                   err_msg=f"{spec} lag={lag}")


def test_bank_series_streaming_continues_state():
    """Two estimate_series calls must equal one concatenated call
    (pending lag observations carry across the boundary)."""
    xs = np.random.default_rng(1).lognormal(4.0, 0.3, 100)
    keys = ["a"] * 100
    whole = EstimatorBank("ewma:0.4", default_prior=50.0, lag=2)
    ref = whole.estimate_series(xs, keys)
    split = EstimatorBank("ewma:0.4", default_prior=50.0, lag=2)
    got = np.concatenate([split.estimate_series(xs[:37], keys[:37]),
                          split.estimate_series(xs[37:], keys[37:])])
    np.testing.assert_allclose(got, ref, rtol=1e-9)


def test_bank_lag_shifts_observations():
    """lag=1 (ModiPick client-side view): the estimate at position i
    uses observations up to i-2 only."""
    xs = np.array([10.0, 20.0, 40.0, 80.0])
    lag0 = EstimatorBank("ewma:1.0", default_prior=5.0)
    np.testing.assert_allclose(lag0.estimate_series(xs, ["d"] * 4),
                               [5.0, 10.0, 20.0, 40.0])
    lag1 = EstimatorBank("ewma:1.0", default_prior=5.0, lag=1)
    np.testing.assert_allclose(lag1.estimate_series(xs, ["d"] * 4),
                               [5.0, 5.0, 10.0, 20.0])


def test_bank_guards():
    with pytest.raises(ValueError):
        EstimatorBank("observed", lag=1)       # undefined under staleness
    with pytest.raises(ValueError):
        EstimatorBank("ewma:0.2", lag=-1)
    with pytest.raises(ValueError):
        EstimatorBank("ewma:0.2", lag=1).estimate("a")   # no prior
    with pytest.raises(ValueError):
        EstimatorBank(EstimatorBank())         # no nesting
    # A prototype instance is copied per device, prior filled in.
    proto = EWMAEstimator(alpha=0.5)
    bank = EstimatorBank(proto, priors={"a": 40.0})
    assert bank.estimate("a") == 40.0
    bank.observe("a", 100.0)
    assert bank.estimate("a") == 100.0
    assert proto._est is None and proto.prior is None


# -- simulator integration --------------------------------------------------

def test_on_device_fallback_decision_boundaries():
    # Viable locally, cloud infeasible -> fallback.
    assert on_device_fallback_decision(300.0, 200.0, 25.0, 150.0)
    # Cloud feasible -> stay in the cloud.
    assert not on_device_fallback_decision(300.0, 50.0, 25.0, 150.0)
    # Device too slow for the SLA -> no fallback even in an outage.
    assert not on_device_fallback_decision(300.0, 900.0, 25.0, 400.0)
    # No on-device capability (0) -> never.
    assert not on_device_fallback_decision(300.0, 900.0, 25.0, 0.0)
    out = on_device_fallback_decision(
        300.0, np.array([200.0, 50.0]), 25.0, np.array([150.0, 150.0]))
    assert out.tolist() == [True, False]


def test_outage_hedge_fires_exactly_once_per_request():
    """Open loop on two replicas with fallback disabled: every degraded
    cloud-served request hedges exactly once — the hedge counter equals
    the degraded count, never more."""
    r = simulate(paper_profiles(), SimConfig(
        t_sla=350.0, n_requests=1500, seed=3, fleet="lte_outage_fleet",
        t_estimator="ewma:0.2", hedge="outage", on_device_fallback=False,
        arrival_rate_hz=12.0, n_servers=2))
    assert r.fallbacks == 0 and (r.selections >= 0).all()
    assert r.degraded is not None and r.degraded.any()
    assert r.hedges == int(r.degraded.sum())


def test_fallback_accounting():
    r = simulate(paper_profiles(), SimConfig(
        t_sla=350.0, n_requests=1500, seed=3, fleet="lte_outage_fleet",
        t_estimator="ewma:0.2", hedge="outage"))
    assert r.fallbacks == int((r.selections < 0).sum()) > 0
    fb = r.selections < 0
    # Fallbacks only on devices with an on-device profile, and they are
    # charged the device's on-device latency/accuracy.
    fl = make_fleet("lte_outage_fleet")
    od_ms = np.array([d.on_device_ms for d in fl.devices])[r.device_index]
    od_acc = np.array([d.on_device_accuracy
                       for d in fl.devices])[r.device_index]
    assert (od_ms[fb] > 0).all()
    np.testing.assert_allclose(r.accuracies[fb], od_acc[fb])
    assert r.latencies[fb].mean() < 200.0      # pixel2 mnv1_025 ~133ms
    hist = r.selection_histogram([p.name for p in paper_profiles()])
    assert hist["<on-device>"] == pytest.approx(fb.mean())


def test_outage_mode_beats_p95_for_degraded_tier():
    """The acceptance contrast: under lte_outage_fleet the midrange
    tier (radio = lte_outages) attains more under outage-aware
    hedging/fallback than under the p95-only knob."""
    base = dict(t_sla=350.0, n_requests=2000, seed=3,
                fleet="lte_outage_fleet", t_estimator="ewma:0.2",
                arrival_rate_hz=12.0, n_servers=2)
    p95 = simulate(paper_profiles(), SimConfig(**base, hedge="p95"))
    out = simulate(paper_profiles(), SimConfig(**base, hedge="outage"))
    assert (out.per_device()["midrange"]["attainment"]
            > p95.per_device()["midrange"]["attainment"])


def test_fleet_sim_deterministic_and_device_reported():
    cfg = SimConfig(t_sla=320.0, n_requests=800, seed=11,
                    fleet="mixed_fleet", t_estimator="ewma:0.2")
    a = simulate(paper_profiles(), cfg)
    b = simulate(paper_profiles(), cfg)
    assert np.array_equal(a.selections, b.selections)
    assert np.array_equal(a.latencies, b.latencies)
    pd = a.per_device()
    assert set(pd) == {"flagship", "midrange", "budget"}
    assert sum(v["share"] for v in pd.values()) == pytest.approx(1.0)


def test_estimator_scope_global_collapses_bank():
    """estimator_scope='global' must equal a fleet whose every request
    keys one shared estimator (the pre-fleet strawman)."""
    cfg = SimConfig(t_sla=320.0, n_requests=600, seed=2,
                    fleet="mixed_fleet", t_estimator="ewma:0.2",
                    estimator_scope="global")
    r = simulate(paper_profiles(), cfg)
    dev = simulate(paper_profiles(), SimConfig(
        t_sla=320.0, n_requests=600, seed=2, fleet="mixed_fleet",
        t_estimator="ewma:0.2"))
    assert not np.array_equal(r.selections, dev.selections)
    with pytest.raises(ValueError):
        simulate(paper_profiles(), SimConfig(
            t_sla=320.0, n_requests=10, fleet="mixed_fleet",
            t_estimator="ewma:0.2", estimator_scope="nope"))


def test_hedge_knob_validation():
    with pytest.raises(ValueError):
        simulate(paper_profiles(), SimConfig(t_sla=300.0, n_requests=10,
                                             hedge="sometimes"))
    with pytest.warns(DeprecationWarning, match="hedge_at_p95"), \
            pytest.raises(ValueError):
        simulate(paper_profiles(), SimConfig(t_sla=300.0, n_requests=10,
                                             hedge="outage",
                                             hedge_at_p95=True))
