"""Sharding rule unit tests (no multi-device mesh needed: specs only)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import param_logical_axes, cache_logical_axes
from repro.sharding import (ParallelConfig, make_rules, spec_for, tree_specs,
                            moe_mode_for, SCALAR_AXES)
from repro.training.optim import adamw, adafactor, constant_schedule
from repro.training.step import train_state_logical_axes


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def devices(self):
        import numpy as np
        return np.empty(tuple(self.shape.values()))


def mk_parallel(profile="train", shape=None):
    shape = shape or {"data": 16, "model": 16}
    return ParallelConfig(mesh=FakeMesh(shape), data_axes=("data",),
                          fsdp_axes=("data",), tp_axis="model",
                          profile=profile)


def test_train_rules_fsdp_tp():
    cfg = get_config("yi_9b")
    rules = make_rules(mk_parallel("train"), cfg)
    assert rules["hidden_in"] == ("data",)
    assert rules["ff"] == "model"
    assert rules["heads"] == "model"
    assert rules["kv_heads"] is None  # yi kv=4 < 16
    assert rules["cache_seq"] == "model"  # seq-sharded cache instead


def test_kv_divisible_shards_heads():
    cfg = get_config("stablelm_1_6b")  # kv=32
    rules = make_rules(mk_parallel("serve"), cfg)
    assert rules["kv_heads"] == "model"
    assert rules["cache_seq"] is None


def test_vocab_padding_restores_sharding():
    mamba = get_config("mamba2_2_7b")
    rules = make_rules(mk_parallel("train"), mamba)
    assert rules["vocab"] == "model"  # padded 50432 divides 16
    assert mamba.padded_vocab == 50432


def test_spec_dedupes_repeated_axes():
    rules = {"a": ("data", "model"), "b": "model"}
    s = spec_for(("a", "b"), rules)
    # "model" already used by dim 0 -> dim 1 gets nothing
    assert s == P(("data", "model"), None)


def test_scalar_axes_sentinel():
    assert spec_for(SCALAR_AXES, {}) == P()


def test_param_spec_tree_structure_matches_params():
    cfg = get_config("qwen3_moe_235b")
    axes = param_logical_axes(cfg)
    specs = tree_specs(axes, mk_parallel("train"), cfg)
    # same tree structure (empty tail tuple preserved structurally)
    assert (jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))
            .num_leaves == jax.tree.structure(
                axes, is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
                and all(isinstance(a, (str, type(None))) for a in x)).num_leaves)


def test_moe_mode_auto():
    qwen = get_config("qwen3_moe_235b")
    grok = get_config("grok_1_314b")
    par = mk_parallel("train")
    assert moe_mode_for(qwen, par) == "ep"   # 128 % 16 == 0
    assert moe_mode_for(grok, par) == "tp"   # 8 < 16


def test_grok_tp_mode_expert_specs():
    grok = get_config("grok_1_314b")
    axes = param_logical_axes(grok)
    specs = tree_specs(axes, mk_parallel("train"), grok)
    wspec = specs["blocks"][0]["w_up"]  # (layers, E, d, ff)
    assert wspec == P(None, None, ("data",), "model")


def test_qwen_ep_mode_expert_specs():
    qwen = get_config("qwen3_moe_235b")
    axes = param_logical_axes(qwen)
    specs = tree_specs(axes, mk_parallel("train"), qwen)
    wspec = specs["blocks"][0]["w_up"]
    assert wspec == P(None, "model", ("data",), None)


def test_opt_state_specs_cover_every_leaf():
    cfg = get_config("gemma2_9b")
    for opt in (adamw(constant_schedule(1e-3)),
                adafactor(constant_schedule(1e-3))):
        st_axes = train_state_logical_axes(cfg, opt)
        specs = tree_specs(st_axes, mk_parallel("train"), cfg)
        for leaf in jax.tree.leaves(specs,
                                    is_leaf=lambda x: isinstance(x, P)):
            assert isinstance(leaf, P)


def test_divisibility_of_all_arch_dims():
    """Every sharded dim of every arch divides the production axes."""
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.d_model % 16 == 0, arch                  # fsdp
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, arch                 # tp
        assert cfg.q_heads_padded % 16 == 0 or cfg.ssd, arch
        assert cfg.padded_vocab % 16 == 0, arch
        if cfg.moe:
            tpmode = cfg.moe.n_experts % 16 == 0
            assert tpmode or cfg.moe.d_ff_expert % 16 == 0, arch
