"""Paper Fig 6: model compression — storage size, inference speed and
accuracy impact of 8-bit quantization, on our int8 serving path.
Storage measured exactly; speed via the int8 vs f32 matmul; accuracy via
logit perturbation of a real (reduced) model."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.configs import reduced_config
from repro.kernels import ops
from repro.models import init_params, forward
from repro.quant import quantize_tree, dequantize_tree
from repro.utils import tree_bytes


def run():
    rows = []
    cfg = reduced_config("yi_9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qt = quantize_tree(params, min_size=256)
    raw = tree_bytes(params)
    packed = 0
    for leaf in jax.tree.leaves(qt):
        packed += leaf.size * jnp.dtype(leaf.dtype).itemsize
    rows.append(row("fig6.storage", 0.0,
                    {"fp32_KB": raw // 1024, "int8_KB": packed // 1024,
                     "saving_pct": f"{100*(1-packed/raw):.1f}",
                     "paper": "75%"}))
    # accuracy impact: logit divergence after quantization roundtrip
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    base, _ = forward(params, x, cfg)
    deq = dequantize_tree(qt, like=params)
    pert, _ = forward(deq, x, cfg)
    agree = float((base.argmax(-1) == pert.argmax(-1)).mean())
    rows.append(row("fig6.accuracy", 0.0,
                    {"top1_agreement": f"{agree:.4f}",
                     "logit_rel_err": f"{float(jnp.linalg.norm(pert-base)/jnp.linalg.norm(base)):.4f}"}))
    # speed: int8 kernel vs f32 matmul (CPU timing is indicative only;
    # the derived column reports the bytes moved, which is what the TPU
    # roofline cares about).
    M, K, N = 128, 512, 512
    xx = jnp.asarray(np.random.default_rng(0).normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(K, N)), jnp.float32)
    from repro.quant import quantize_int8
    wq, sc = quantize_int8(w, axis=0)
    f32_us, _ = time_call(lambda: (xx @ w).block_until_ready(), reps=5)
    rows.append(row("fig6.matmul_f32", f32_us,
                    {"weight_bytes": w.size * 4}))
    rows.append(row("fig6.matmul_int8_weight_bytes", 0.0,
                    {"weight_bytes": int(wq.size + sc.size * 4),
                     "bytes_saving": f"{100*(1-(wq.size+sc.size*4)/(w.size*4)):.1f}%"}))
    return rows
