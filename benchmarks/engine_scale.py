"""Engine scaling: the python reference loop vs the jit `lax.scan`
column program (serving/scan_engine.py, DESIGN.md §13).

Sweeps fleet sizes through both engines on the same workload — an
`ArrayFleet` of paper Table 4 tiers driving the PR 5 adaptive control
plane (per-device "reactive" controller: EWMA monitor, CUSUM detector,
stationary/degraded mode table) with outage-aware fallback and
greedy_nw selection — and reports end-to-end requests/sec for full
`simulate()` calls (trace sampling, control plan, event phase,
metrics).

Measurement: each scan point runs once un-timed to warm the jit cache
for its exact (rows, devices) shape — compile time is a one-off, not a
throughput cost — then reports the median of `repeats` timed runs.
The python engine needs no warmup and its cost is linear in N at fixed
D, so smaller draws of the same workload give its honest rate where a
full-size run would take hours; the acceptance sweep (`--full`) runs
it at the full 1M requests so the 100k-device speedup is measured on
literally identical workloads.  The 1M-device x 10M-request point runs
the scan engine only.

Rows: ``engine.<engine>.d<devices>`` with requests/sec, plus
``engine.speedup.d<devices>`` where both engines ran (the acceptance
gate: >= 50x at 100k devices).

Trajectory artifact: full runs append a point to
``benchmarks/results/BENCH_engine_scale.json`` (requests/sec per
size), the perf series CI tracks across main pushes from this PR on.

Smoke (CI): ``python benchmarks/engine_scale.py --smoke``.
Full (acceptance): ``python benchmarks/engine_scale.py --full``.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

from benchmarks.common import RESULTS_DIR, emit, row

T_SLA = 350.0
SEED = 11

# (devices, python-engine requests, scan-engine requests).
SWEEP_SMOKE = [(1_000, 20_000, 20_000)]
SWEEP_RUN = [(1_000, 50_000, 50_000), (100_000, 50_000, 1_000_000)]
SWEEP_FULL = [(1_000, 100_000, 100_000), (100_000, 1_000_000, 1_000_000),
              (1_000_000, None, 10_000_000)]


def _sim(devices: int, n_requests: int, engine: str, shards: int):
    from repro.configs.paper_zoo import paper_profiles
    from repro.serving.fleet import ArrayFleet
    from repro.serving.simulator import SimConfig, simulate

    cfg = SimConfig(
        t_sla=T_SLA, n_requests=n_requests, seed=SEED,
        fleet=ArrayFleet(devices, seed=SEED), policy="greedy_nw",
        controller="reactive", engine=engine,
        shards=shards if engine == "scan" else 1)
    t0 = time.perf_counter()
    res = simulate(paper_profiles(), cfg)
    dt = time.perf_counter() - t0
    return dt, res


def bench(sweep, shards: int = 1, trajectory: bool = False):
    rows = []
    points = []
    for devices, n_py, n_scan in sweep:
        rates = {}
        for engine, n in (("python", n_py), ("scan", n_scan)):
            if n is None:
                continue
            if engine == "scan":
                _sim(devices, n, engine, shards)       # warm this shape
                repeats = 2 if devices >= 1_000_000 else 3
                runs = [_sim(devices, n, engine, shards)
                        for _ in range(repeats)]
                dt = statistics.median(d for d, _ in runs)
                res = runs[-1][1]
            else:
                dt, res = _sim(devices, n, engine, shards)
            rates[engine] = n / dt
            rows.append(row(f"engine.{engine}.d{devices}", dt * 1e6,
                            {"devices": devices, "requests": n,
                             "reqs_per_s": f"{n / dt:.0f}",
                             "attainment": f"{res.attainment:.4f}"}))
            points.append({"devices": devices, "requests": n,
                           "engine": engine,
                           "reqs_per_s": round(n / dt, 1)})
        if len(rates) == 2:
            rows.append(row(f"engine.speedup.d{devices}", 0.0,
                            {"devices": devices,
                             "x": f"{rates['scan'] / rates['python']:.1f}"}))
    if trajectory:
        path = os.path.join(RESULTS_DIR, "BENCH_engine_scale.json")
        os.makedirs(RESULTS_DIR, exist_ok=True)
        series = []
        if os.path.exists(path):
            series = json.load(open(path)).get("series", [])
        series.append({"unix_time": int(time.time()),
                       "shards": shards, "points": points})
        with open(path, "w") as f:
            json.dump({"bench": "engine_scale", "series": series}, f,
                      indent=2, sort_keys=True)
        rows.append(row("engine.trajectory", 0.0, {"path": path}))
    return rows


def run():
    """benchmarks.run entry: moderate sizes (CI artifact job)."""
    return bench(SWEEP_RUN)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI fast-job smoke)")
    ap.add_argument("--full", action="store_true",
                    help="acceptance sizes incl. 1M devices x 10M "
                         "requests, and append the BENCH_*.json "
                         "trajectory point")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the scan program's device axis "
                         "(needs host devices; see "
                         "repro.utils.config.configure)")
    args = ap.parse_args()
    if args.shards > 1:
        from benchmarks.common import configure_host
        configure_host(host_devices=args.shards)
    sweep = (SWEEP_SMOKE if args.smoke
             else SWEEP_FULL if args.full else SWEEP_RUN)
    print("name,us_per_call,derived")
    emit(bench(sweep, shards=args.shards, trajectory=args.full))


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    main()
