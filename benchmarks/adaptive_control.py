"""Online adaptive control vs every static configuration (DESIGN.md §12).

The question the control plane answers: can a server that *detects*
regime shifts online (CUSUM over per-device estimator residuals) and
switches its operating mode live match — or beat — the best static
(policy, estimator, hedge) configuration an operator could have picked
offline, without knowing which configuration that is?

Three scenarios, the PR 4 replay harness as ground truth for the third:

1. ``wifi_lte_handoff`` — single radio walking between campus WiFi and
   LTE. The static grid spans {cnnselect, greedy_nw} x {observed,
   ewma:0.2, pctl:90} x {hedge none, outage}; the adaptive controller
   ("reactive": observed budgeting while stationary, pctl:90 +
   outage-hedging + fallback when degradation is detected) must stay
   within ``--tol`` of the best static config.
2. ``lte_outage_fleet`` — the midrange tier walking through LTE
   outages. Adaptivity should *win* outright here: the outage regime
   needs conservative budgeting + on-device fallback that costs the
   stationary regimes accuracy if applied statically.
3. ``capture:reference_fleet`` — the committed recorded workload
   (stationary mixed fleet) rebuilt via `FleetMixture.from_capture`
   and replayed: the do-no-harm check — with nothing to detect, the
   controller must not lose to the best static config by more than
   ``--tol``.

The *stationary-tuned* baseline is the paper's own operating point
(cnnselect, observed upload-time budgeting, no hedging) — what tuning
against stationary offline measurements produces. ``--check`` (the CI
gate) fails unless (a) adaptive >= best static - tol on every gated
scenario and (b) adaptive strictly beats the stationary-tuned config
on at least one regime-shift scenario.

Smoke (CI): ``python benchmarks/adaptive_control.py --n-requests 600
--scenarios handoff,outage_fleet --check``.
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import emit, row
from repro.configs.paper_zoo import paper_profiles
from repro.serving.fleet import FleetMixture
from repro.serving.simulator import SimConfig, simulate
from repro.serving.trace import load_capture

SEED = 5
CONTROLLER = "reactive"
STATIONARY_TUNED = ("cnnselect", None, "none")

# The static grid: every (policy, estimator, hedge) operating point the
# controller's mode table can reach (plus the ewma middle ground).
STATIC_GRID = [
    (pol, est, hedge)
    for pol in ("cnnselect", "greedy_nw")
    for est in (None, "ewma:0.2", "pctl:90")
    for hedge in ("none", "outage")
]


def _sim(base: dict, n_requests: int, **over):
    cfg = SimConfig(n_requests=n_requests, seed=SEED, **base, **over)
    return simulate(paper_profiles(), cfg)


def _scenario_rows(name: str, base: dict, n_requests: int, tol: float,
                   gate: bool, shift_scenario: bool):
    """Run the static grid + the adaptive controller on one scenario;
    returns (rows, failures, adaptive_beats_stationary_tuned)."""
    rows, failures = [], []
    static = {}
    for pol, est, hedge in STATIC_GRID:
        r = _sim(base, n_requests, policy=pol, t_estimator=est,
                 hedge=hedge)
        static[(pol, est, hedge)] = r
        rows.append(row(
            f"adaptive_control.{name}.static.{pol}.{est or 'observed'}"
            f".{hedge}", 0.0,
            {"attainment": f"{r.attainment:.4f}",
             "accuracy": f"{r.accuracy:.4f}",
             "fallbacks": r.fallbacks}))
    adaptive = _sim(base, n_requests, controller=CONTROLLER)
    best_key = max(static, key=lambda k: static[k].attainment)
    best = static[best_key]
    tuned = static[STATIONARY_TUNED]
    margin = adaptive.attainment - best.attainment
    vs_tuned = adaptive.attainment - tuned.attainment
    ok = margin >= -tol
    if gate and not ok:
        failures.append(
            f"{name}: adaptive {adaptive.attainment:.4f} < best static "
            f"{'/'.join(str(k) for k in best_key)} "
            f"{best.attainment:.4f} - {tol}")
    per_mode = {
        f"mode[{k}]": f"{v['share']:.2f}@{v['attainment']:.3f}"
        for k, v in adaptive.per_mode().items()}
    rows.append(row(f"adaptive_control.{name}.adaptive", 0.0, {
        "attainment": f"{adaptive.attainment:.4f}",
        "accuracy": f"{adaptive.accuracy:.4f}",
        "switches": len(adaptive.switch_events or []),
        "fallbacks": adaptive.fallbacks, **per_mode}))
    rows.append(row(f"adaptive_control.{name}.headline", 0.0, {
        "best_static": "/".join(str(k) for k in best_key),
        "best_static_att": f"{best.attainment:.4f}",
        "adaptive_att": f"{adaptive.attainment:.4f}",
        "margin": f"{margin:+.4f}", "within_tol": ok,
        "stationary_tuned_att": f"{tuned.attainment:.4f}",
        "vs_stationary_tuned": f"{vs_tuned:+.4f}",
        "adaptive_accuracy_vs_best": f"{adaptive.accuracy - best.accuracy:+.4f}"}))
    beats_tuned = shift_scenario and vs_tuned > 0.0
    return rows, failures, beats_tuned


def _reference_base(n_requests: int) -> dict:
    """The recorded reference workload (PR 4 harness) as a fleet: each
    captured device's radio replays its own recorded subsequence."""
    trace = load_capture("reference_fleet")
    return dict(t_sla=float(trace.meta["t_sla"]),
                fleet=FleetMixture.from_capture(trace, mode="loop"))


SCENARIOS = {
    # name -> (base-config builder, gated, is-regime-shift-scenario)
    "handoff": (lambda n: dict(t_sla=320.0,
                               network="wifi_lte_handoff"), True, True),
    "outage_fleet": (lambda n: dict(t_sla=350.0,
                                    fleet="lte_outage_fleet"), True,
                     True),
    "reference_fleet": (_reference_base, True, False),
}


def run_checked(n_requests: int = 3000, tol: float = 0.01,
                scenarios=("handoff", "outage_fleet",
                           "reference_fleet"), strict_win: bool = True):
    rows, failures = [], []
    any_beats_tuned = False
    any_shift = False
    for name in scenarios:
        builder, gate, shift = SCENARIOS[name]
        r, f, beats = _scenario_rows(name, builder(n_requests),
                                     n_requests, tol, gate, shift)
        rows += r
        failures += f
        any_beats_tuned |= beats
        any_shift |= shift
    # The strict-win criterion needs enough requests for several full
    # regime dwells; the CI smoke (small n) disables it and gates only
    # on the best-static margin.
    if strict_win and any_shift and not any_beats_tuned:
        failures.append(
            "adaptive does not strictly beat the stationary-tuned "
            f"config ({'/'.join(str(k) for k in STATIONARY_TUNED)}) on "
            "any regime-shift scenario")
    return rows, failures


def run(n_requests: int = 3000):
    """benchmarks.run entry point. The full-size acceptance gate
    (best-static margin + strict win over the stationary-tuned config)
    is enforced here too: benchmarks.run counts a raising module as a
    failure and exits non-zero, so the main-push slow job guards the
    criterion the small-n CI smoke cannot (--no-strict-win)."""
    rows, failures = run_checked(n_requests)
    if failures:
        emit(rows)               # surface the rows before failing
        raise AssertionError("adaptive_control gate failed: "
                             + "; ".join(failures))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=3000)
    ap.add_argument("--tol", type=float, default=0.01,
                    help="max attainment shortfall vs the best static "
                         "configuration")
    ap.add_argument("--scenarios",
                    default="handoff,outage_fleet,reference_fleet")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when adaptive loses to the "
                         "best static config by more than --tol, or "
                         "never strictly beats the stationary-tuned "
                         "config on a shift scenario")
    ap.add_argument("--strict-win", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="require a strict adaptive win over the "
                         "stationary-tuned config on some shift "
                         "scenario (--no-strict-win for small-n "
                         "smokes)")
    args = ap.parse_args()
    rows, failures = run_checked(args.n_requests, args.tol,
                                 args.scenarios.split(","),
                                 strict_win=args.strict_win)
    emit(rows)
    if failures:
        print("\n".join(f"FAIL {f}" for f in failures), file=sys.stderr)
        if args.check:
            sys.exit(1)


if __name__ == "__main__":
    main()
