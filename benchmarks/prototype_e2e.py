"""Paper Fig 12: end-to-end prototype — a real 2-model server (tiny +
small engines actually executing on this host), SLA sweep, measuring SLA
attainment and the automatic transition between models as the budget
grows. (The trained-accuracy version lives in examples/serve_e2e.py;
here accuracies are configured so the bench stays fast.)"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import reduced_config
from repro.core.selection import make_policy
from repro.models import init_params
from repro.serving.batching import Request
from repro.serving.engine import InferenceEngine
from repro.serving.server import CNNSelectServer, ServedModel


def _server(policy="cnnselect"):
    models = []
    cfg_t = reduced_config("stablelm_1_6b")
    cfg_s = dataclasses.replace(reduced_config("stablelm_1_6b"),
                                n_layers=6, d_model=192, n_heads=6,
                                n_kv_heads=6, head_dim=32, d_ff=384)
    for name, cfg, acc in [("tiny", cfg_t, 0.62), ("small", cfg_s, 0.88)]:
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
        models.append(ServedModel(name=name, engine=eng, accuracy=acc))
    srv = CNNSelectServer(models, t_threshold=30.0, n_tokens=4,
                          policy=policy)
    srv.profile_models(prompt_len=8, reps=3)
    return srv


def _sweep(srv, slas, n_requests, tag, rows):
    rng = np.random.default_rng(0)
    for sla in slas:
        srv.metrics = type(srv.metrics)()
        for i in range(n_requests):
            req = Request(arrival=0.0, rid=i,
                          prompt=rng.integers(0, 50, 8).astype(np.int32),
                          t_input_ms=float(rng.normal(8, 2)))
            srv.handle(req, t_sla=float(sla))
        s = srv.metrics.summary()
        rows.append(row(f"fig12.{tag}.sla{int(sla)}ms", s["mean_ms"] * 1000.0,
                        {"attainment": f"{s['attainment']:.2f}",
                         "accuracy": f"{s['accuracy']:.2f}",
                         "selections": str(s["selections"]).replace(",", "/")}))


def run(n_requests: int = 12):
    srv = _server()
    profs = {p.name: p for p in srv.current_profiles()}
    rows = [row("fig12.profiles", 0.0,
                {n: f"{p.mu:.0f}±{p.sigma:.0f}ms" for n, p in profs.items()})]
    tiny_mu = profs["tiny"].mu
    small_mu = profs["small"].mu
    slas = (tiny_mu * 2, (tiny_mu + small_mu) * 1.2, small_mu * 6)
    _sweep(srv, slas, n_requests, "cnnselect", rows)
    # Same engines and profiles, greedy policy hot-swapped through the
    # registry: the live analogue of the Fig 13 baseline comparison.
    srv.router.policy = make_policy("greedy")
    _sweep(srv, slas[1:2], n_requests, "greedy", rows)
    return rows
