"""Benchmark runner: one module per paper table/figure + assignment
artifacts. Prints ``name,us_per_call,derived`` CSV rows; ``--json``
additionally writes the rows as structured JSON (the CI benchmark
artifact).

    PYTHONPATH=src python -m benchmarks.run [--only fig13,roofline] [--fast]
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    ("fig3_device_vs_cloud", "benchmarks.device_vs_cloud"),
    ("fig4_startup_latency", "benchmarks.startup_latency"),
    ("fig5_model_sweep", "benchmarks.model_sweep"),
    ("fig6_quantization", "benchmarks.quantization"),
    ("fig9_server_capacity", "benchmarks.server_capacity"),
    ("measured_serving", "benchmarks.measured_serving"),
    ("fig10_network_conditions", "benchmarks.network_conditions"),
    ("fig10x_network_dynamics", "benchmarks.network_dynamics"),
    ("table4x_fleet_dynamics", "benchmarks.fleet_dynamics"),
    ("ctrl_adaptive_control", "benchmarks.adaptive_control"),
    ("engine_scale", "benchmarks.engine_scale"),
    ("cluster_scale", "benchmarks.cluster_scale"),
    ("sim2real_trace_replay", "benchmarks.trace_replay"),
    ("fig12_prototype_e2e", "benchmarks.prototype_e2e"),
    ("fig13_selection_vs_greedy", "benchmarks.selection_vs_greedy"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline_pod", "benchmarks.roofline"),
    ("table5_zoo", "benchmarks.zoo_table"),
    ("lmzoo_selection", "benchmarks.lm_zoo_selection"),
]


def parse_row(line: str) -> dict:
    """``name,us_per_call,k=v;k=v`` -> structured dict (the --json
    artifact shape)."""
    name, us, derived = line.split(",", 2)
    out: dict = {"name": name, "us_per_call": float(us)}
    if "=" in derived:
        out["derived"] = dict(kv.split("=", 1)
                              for kv in derived.split(";") if "=" in kv)
    else:
        out["derived"] = derived
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--fast", action="store_true",
                    help="skip the engine-executing benches")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as structured JSON "
                         "(uploaded as a CI artifact on main pushes)")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None
    # measured_serving executes the zoo engines; under --fast its rows
    # still surface once via fig9_server_capacity (memoized), so the
    # standalone entry is skipped rather than run twice.
    slow = {"fig3_device_vs_cloud", "fig4_startup_latency",
            "fig5_model_sweep", "sim2real_trace_replay",
            "fig12_prototype_e2e", "kernels", "measured_serving"}
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for name, mod in MODULES:
        if only and not any(o in name for o in only):
            continue
        if args.fast and name in slow:
            continue
        try:
            import importlib
            m = importlib.import_module(mod)
            rows = m.run()
            emit(rows)
            records.extend(parse_row(r) for r in rows)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR", flush=True)
            records.append({"name": name, "us_per_call": 0.0,
                            "derived": "ERROR"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records, "failures": failures}, f,
                      indent=2, sort_keys=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
