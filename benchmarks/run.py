"""Benchmark runner: one module per paper table/figure + assignment
artifacts. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig13,roofline] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    ("fig3_device_vs_cloud", "benchmarks.device_vs_cloud"),
    ("fig4_startup_latency", "benchmarks.startup_latency"),
    ("fig5_model_sweep", "benchmarks.model_sweep"),
    ("fig6_quantization", "benchmarks.quantization"),
    ("fig9_server_capacity", "benchmarks.server_capacity"),
    ("fig10_network_conditions", "benchmarks.network_conditions"),
    ("fig10x_network_dynamics", "benchmarks.network_dynamics"),
    ("table4x_fleet_dynamics", "benchmarks.fleet_dynamics"),
    ("fig12_prototype_e2e", "benchmarks.prototype_e2e"),
    ("fig13_selection_vs_greedy", "benchmarks.selection_vs_greedy"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline_pod", "benchmarks.roofline"),
    ("table5_zoo", "benchmarks.zoo_table"),
    ("lmzoo_selection", "benchmarks.lm_zoo_selection"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--fast", action="store_true",
                    help="skip the engine-executing benches")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None
    slow = {"fig3_device_vs_cloud", "fig4_startup_latency",
            "fig5_model_sweep", "fig12_prototype_e2e", "kernels"}
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if only and not any(o in name for o in only):
            continue
        if args.fast and name in slow:
            continue
        try:
            import importlib
            m = importlib.import_module(mod)
            emit(m.run())
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
