"""Fig 10 extended to *time-varying* mobile networks (beyond-paper;
the MDInference/ModiPick regime): regime-switching Markov networks and
trace replay wreck policies that budget from stationary means, and the
online T_input estimators recover most of the lost SLA attainment.
(Known nuance, visible in the rows: on `lte_outages` the stationary
mean is dragged up by the outage state and is accidentally
conservative, so the mean-tracking EWMA trades a little attainment for
accuracy there while the conservative rolling-p90 matches the mean
variant — the handoff/congestion/trace scenarios are where online
estimation wins outright.)

Rows:
- ``dyn.<scenario>.<policy>`` — overall + per-regime attainment for
  cnnselect under each budget source (observed / stationary-mean /
  ewma / rolling-p90) vs the greedy / static baselines.
- ``dyn.trace.*`` — the same contrast on a replayed wifi->lte step
  trace.
- ``dyn.overhead`` — 10k-request simulation wall-clock with and
  without an estimator attached (the acceptance bar is ~1.2x the plain
  chunked-admission path).
"""

from __future__ import annotations

from benchmarks.common import row, time_call
from repro.configs.paper_zoo import paper_profiles
from repro.serving.simulator import SimConfig, simulate

SCENARIOS = ("wifi_lte_handoff", "wifi_congestion_bursts", "lte_outages")

# (label, policy, t_estimator): cnnselect under each budget source,
# then the paper baselines (greedy ignores the network entirely).
VARIANTS = (
    ("cnnselect+obs", "cnnselect", None),
    ("cnnselect+mean", "cnnselect", "mean"),
    ("cnnselect+ewma", "cnnselect", "ewma:0.2"),
    ("cnnselect+p90", "cnnselect", "pctl:90"),
    ("greedy", "greedy", None),
    ("greedy_nw", "greedy_nw", None),
    ("static:mnv1_10", "static:mobilenetv1_10", None),
)


def _variant_rows(tag: str, network, t_sla: float, n_requests: int,
                  seed: int):
    rows, att = [], {}
    for label, policy, est in VARIANTS:
        r = simulate(paper_profiles(), SimConfig(
            t_sla=t_sla, n_requests=n_requests, network=network,
            policy=policy, t_estimator=est, seed=seed))
        att[label] = r.attainment
        per = {f"att[{k}]": f"{v['attainment']:.3f}"
               for k, v in r.per_regime().items()}
        rows.append(row(f"{tag}.{label}", 0.0, {
            "attainment": f"{r.attainment:.3f}",
            "accuracy": f"{r.accuracy:.3f}",
            "p95_ms": f"{r.p95_latency:.1f}", **per}))
    # The headline contrast: online estimation vs stationary-mean
    # budgeting under the same time-varying network.
    rows.append(row(f"{tag}.ewma_vs_mean", 0.0, {
        "ewma_att": f"{att['cnnselect+ewma']:.3f}",
        "mean_att": f"{att['cnnselect+mean']:.3f}",
        "recovered": f"{att['cnnselect+ewma'] - att['cnnselect+mean']:.3f}",
        "ewma_ge_mean": att["cnnselect+ewma"] >= att["cnnselect+mean"]}))
    return rows


def overhead_rows(n_requests: int = 10000):
    """Isolate each cost: stationary no-estimator (the pre-refactor
    path), the Markov trace alone, then the Markov trace + estimator —
    `est_over_markov_x` is the estimator's own overhead and
    `total_over_plain_x` is the whole dynamic path vs the plain one
    (the ISSUE's ~1.2x acceptance bar)."""
    profs = paper_profiles()
    cfg = dict(t_sla=300.0, n_requests=n_requests, seed=0)
    plain_us, _ = time_call(
        lambda: simulate(profs, SimConfig(**cfg)), reps=5)
    markov_us, _ = time_call(
        lambda: simulate(profs, SimConfig(**cfg,
                                          network="wifi_lte_handoff")),
        reps=5)
    out = []
    for est in ("ewma:0.2", "pctl:90"):
        est_us, _ = time_call(
            lambda: simulate(profs, SimConfig(
                **cfg, network="wifi_lte_handoff", t_estimator=est)),
            reps=5)
        out.append(row("dyn.overhead", 0.0, {
            "estimator": est, "n": n_requests,
            "plain_ms": f"{plain_us / 1e3:.1f}",
            "markov_ms": f"{markov_us / 1e3:.1f}",
            "dynamic_ms": f"{est_us / 1e3:.1f}",
            "est_over_markov_x": f"{est_us / markov_us:.2f}",
            "total_over_plain_x": f"{est_us / plain_us:.2f}"}))
    return out


def run(n_requests: int = 4000):
    rows = []
    for scenario in SCENARIOS:
        rows.extend(_variant_rows(f"dyn.{scenario}", scenario,
                                  t_sla=320.0, n_requests=n_requests,
                                  seed=3))
    rows.extend(_variant_rows("dyn.trace.wifi_lte_step",
                              "trace:wifi_lte_step", t_sla=320.0,
                              n_requests=n_requests, seed=3))
    rows.extend(overhead_rows())
    return rows
