"""Beyond-paper: CNNSelect over the LM zoo at pod scale.

The 10 assigned architectures become the model zoo: per-request latency
profiles are the roofline-derived decode step estimates (per generated
token x a response budget), accuracies are a capability proxy
(log-active-params scaled to [0,1] — a stand-in for downstream quality;
the serving algorithm only needs a monotone score). CNNSelect then
answers: given an end-to-end SLA and live network conditions, which LM
should serve this request? — the paper's question, three orders of
magnitude up in model size."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, load_dryrun_results
from repro.configs import ARCH_IDS, get_config
from repro.core.selection import ModelProfile
from repro.serving.simulator import SimConfig, simulate

N_TOKENS = 32          # response budget per request
SIGMA_FRAC = 0.15      # serving jitter on the roofline estimate


def lm_zoo_profiles(mesh: str = "pod"):
    res = load_dryrun_results(mesh)
    profs = []
    caps = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        d = res.get((cfg.name, "decode_32k"))
        if not d or d.get("skipped"):
            continue
        step_ms = d["step_time_est_s"] * 1000.0
        mu = step_ms * N_TOKENS
        caps[cfg.name] = np.log(cfg.active_param_count())
        profs.append((cfg.name, mu))
    if not caps:
        # No dry-run results on this host (fresh checkout / CI): run()
        # reports the lmzoo.missing row instead of crashing on min().
        return []
    lo = min(caps.values())
    hi = max(caps.values())
    out = []
    for name, mu in profs:
        acc = 0.4 + 0.55 * (caps[name] - lo) / (hi - lo)
        out.append(ModelProfile(name=name, accuracy=float(acc), mu=mu,
                                sigma=mu * SIGMA_FRAC))
    return out


def run():
    rows = []
    profs = lm_zoo_profiles()
    if not profs:
        return [row("lmzoo.missing", 0.0, {"note": "run the dry-run first"})]
    for p in sorted(profs, key=lambda p: p.mu):
        rows.append(row(f"lmzoo.profile.{p.name}", p.mu * 1000.0,
                        {"mu_ms": f"{p.mu:.0f}",
                         "quality_proxy": f"{p.accuracy:.2f}"}))
    for sla in (200, 600, 1500, 4000):
        per_policy = {
            pol: simulate(profs, SimConfig(t_sla=sla, n_requests=1500,
                                           t_threshold=100.0, policy=pol,
                                           seed=0))
            for pol in ("cnnselect", "greedy", "oracle")}
        ours = per_policy["cnnselect"]
        top = max(ours.selection_histogram([p.name for p in profs]).items(),
                  key=lambda kv: kv[1])
        rows.append(row(f"lmzoo.sla{sla}ms", 0.0,
                        {"ours_att": f"{ours.attainment:.3f}",
                         "greedy_att":
                         f"{per_policy['greedy'].attainment:.3f}",
                         "oracle_att":
                         f"{per_policy['oracle'].attainment:.3f}",
                         "ours_quality": f"{ours.accuracy:.3f}",
                         "top_pick": f"{top[0]}:{top[1]:.2f}"}))
    return rows
