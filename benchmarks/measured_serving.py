"""Measured serving capacity: tokens/s and SLA attainment from engines
that actually execute (DESIGN.md §14).

One row per `MEASURED_ZOO` candidate: decode tokens/s and prefill
latency from `InferenceEngine.measured_profile` (prefill/per-token split),
SLA attainment of the requests CNNSelect routed to it on a short served
trace, and whether the candidate sits on the accuracy/latency frontier.
The int8 variants are the paper-adjacent "Smart at what cost?" story:
`lm_base_int8` trades quantization error for a bigger model inside the
storage budget and should hold a frontier slot over its fp32 peers."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row

N_REQUESTS = 48
SEED = 11

# fig9_server_capacity embeds these rows on its axis; memoize per
# request count so a full `benchmarks.run` pass (which hits both entry
# points) builds and profiles the zoo engines only once.
_cache: dict = {}


def _frontier(profiles):
    """Names NOT dominated in (accuracy up, mu down) by another model."""
    out = set()
    for p in profiles:
        dominated = any(
            q.accuracy >= p.accuracy and q.mu <= p.mu
            and (q.accuracy > p.accuracy or q.mu < p.mu)
            for q in profiles)
        if not dominated:
            out.add(p.name)
    return out


def run(n_requests: int = N_REQUESTS):
    if n_requests in _cache:
        return _cache[n_requests]
    from repro.serving.batching import Request
    from repro.serving.measured import (build_zoo, measured_profiles,
                                        served_models)
    from repro.serving.server import CNNSelectServer
    from repro.serving.trace import TraceRecorder

    zoo = build_zoo(batch_size=2, max_seq=64)
    detail: dict = {}
    profs = measured_profiles(zoo, prompt_len=8, n_tokens=4, reps=3,
                              detail=detail)
    frontier = _frontier(profs)

    # Serve a short trace so attainment shares the axis with tokens/s.
    # t_threshold sits on the engines' own mu scale (cnnselect stage 1
    # needs t_budget - t_threshold above the candidate mus, else every
    # request falls back to argmin-mu).
    srv = CNNSelectServer(served_models(zoo), t_threshold=10.0, n_tokens=4)
    for p in profs:
        srv.router.set_profile(p.name, p.mu, p.sigma)
    srv.router.prewarm()
    rng = np.random.default_rng(SEED)
    # Upload times sweep 0.5x..2x a campus-wifi-ish mean so the latency
    # budget left after T_input walks the whole accuracy/mu frontier.
    t_ins = rng.uniform(6.0, 26.0, n_requests)
    t_sla = float(2.2 * t_ins.mean()
                  + 1.1 * max(p.mu for p in profs))
    with TraceRecorder(name="measured_capacity").attach(srv) as rec:
        for i in range(n_requests):
            srv.handle(Request(
                arrival=float(i), rid=i,
                prompt=rng.integers(0, 50, 8).astype(np.int32),
                t_input_ms=float(t_ins[i])), t_sla=t_sla)
    trace = rec.to_trace(source="server")

    rows = []
    for p in profs:
        d = detail[p.name]
        eng = zoo[p.name].engine
        toks_s = eng.batch_size * 1000.0 / max(d["per_token_ms"], 1e-9)
        sel = trace.model == p.name
        att = (float((trace.sla_ok[sel] == 1).mean())
               if sel.any() else float("nan"))
        rows.append(row(
            f"measured.{p.name}", d["per_token_ms"] * 1e3, {
                "tokens_s": f"{toks_s:.0f}",
                "prefill_ms": f"{d['prefill_ms']:.2f}",
                "mu_ms": f"{p.mu:.2f}",
                "accuracy": f"{p.accuracy:.3f}",
                "size_mb": f"{p.size_bytes / 1e6:.2f}",
                "int8": zoo[p.name].quant == "int8",
                "frontier": p.name in frontier,
                "served": int(sel.sum()),
                "sla_attainment": "n/a" if sel.sum() == 0 else f"{att:.3f}",
            }))
    rows.append(row("measured.overall", 0.0, {
        "n": len(trace), "sla_ms": f"{t_sla:.0f}",
        "attainment": f"{trace.attainment:.3f}",
        "int8_on_frontier": bool(
            {n for n in frontier if zoo[n].quant == "int8"}),
    }))
    _cache[n_requests] = rows
    return rows
