"""Measured serving capacity: tokens/s and SLA attainment from engines
that actually execute (DESIGN.md §14), plus the kernel-path perf matrix
(naive vs masked-pallas × fp32 vs int8, DESIGN.md §15).

One row per `MEASURED_ZOO` candidate: decode tokens/s and prefill
latency from `InferenceEngine.measured_profile` (prefill/per-token split),
SLA attainment of the requests CNNSelect routed to it on a short served
trace, and whether the candidate sits on the accuracy/latency frontier.
The int8 variants are the paper-adjacent "Smart at what cost?" story:
`lm_base_int8` trades quantization error for a bigger model inside the
storage budget and should hold a frontier slot over its fp32 peers.

The perf matrix re-runs each zoo row under every attention impl and
reports tokens/s, prefill_ms and the live resident bytes (int8 engines
hold (int8, scale) trees). ``--full`` appends the matrix to
``benchmarks/results/BENCH_measured_serving.json`` as a trajectory
point. NOTE: on CPU the pallas kernels run in *interpret mode* — the
matrix measures dispatch/masking correctness-at-speed there, while the
Mosaic-compiled ratios only mean anything on real TPU.

Smoke (CI fast job): ``python benchmarks/measured_serving.py --smoke``.
Full (acceptance): ``python benchmarks/measured_serving.py --full``."""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit, row

N_REQUESTS = 48
SEED = 11

# fig9_server_capacity embeds these rows on its axis; memoize per
# request count so a full `benchmarks.run` pass (which hits both entry
# points) builds and profiles the zoo engines only once.
_cache: dict = {}


def _frontier(profiles):
    """Names NOT dominated in (accuracy up, mu down) by another model."""
    out = set()
    for p in profiles:
        dominated = any(
            q.accuracy >= p.accuracy and q.mu <= p.mu
            and (q.accuracy > p.accuracy or q.mu < p.mu)
            for q in profiles)
        if not dominated:
            out.add(p.name)
    return out


def run(n_requests: int = N_REQUESTS):
    if n_requests in _cache:
        return _cache[n_requests]
    from repro.serving.batching import Request
    from repro.serving.measured import (build_zoo, measured_profiles,
                                        served_models)
    from repro.serving.server import CNNSelectServer
    from repro.serving.trace import TraceRecorder

    zoo = build_zoo(batch_size=2, max_seq=64)
    detail: dict = {}
    profs = measured_profiles(zoo, prompt_len=8, n_tokens=4, reps=3,
                              detail=detail)
    frontier = _frontier(profs)

    # Serve a short trace so attainment shares the axis with tokens/s.
    # t_threshold sits on the engines' own mu scale (cnnselect stage 1
    # needs t_budget - t_threshold above the candidate mus, else every
    # request falls back to argmin-mu).
    srv = CNNSelectServer(served_models(zoo), t_threshold=10.0, n_tokens=4)
    for p in profs:
        srv.router.set_profile(p.name, p.mu, p.sigma)
    srv.router.prewarm()
    rng = np.random.default_rng(SEED)
    # Upload times sweep 0.5x..2x a campus-wifi-ish mean so the latency
    # budget left after T_input walks the whole accuracy/mu frontier.
    t_ins = rng.uniform(6.0, 26.0, n_requests)
    t_sla = float(2.2 * t_ins.mean()
                  + 1.1 * max(p.mu for p in profs))
    with TraceRecorder(name="measured_capacity").attach(srv) as rec:
        for i in range(n_requests):
            srv.handle(Request(
                arrival=float(i), rid=i,
                prompt=rng.integers(0, 50, 8).astype(np.int32),
                t_input_ms=float(t_ins[i])), t_sla=t_sla)
    trace = rec.to_trace(source="server")

    rows = []
    for p in profs:
        d = detail[p.name]
        eng = zoo[p.name].engine
        toks_s = eng.batch_size * 1000.0 / max(d["per_token_ms"], 1e-9)
        sel = trace.model == p.name
        att = (float((trace.sla_ok[sel] == 1).mean())
               if sel.any() else float("nan"))
        rows.append(row(
            f"measured.{p.name}", d["per_token_ms"] * 1e3, {
                "tokens_s": f"{toks_s:.0f}",
                "prefill_ms": f"{d['prefill_ms']:.2f}",
                "mu_ms": f"{p.mu:.2f}",
                "accuracy": f"{p.accuracy:.3f}",
                "size_mb": f"{p.size_bytes / 1e6:.2f}",
                "int8": zoo[p.name].quant == "int8",
                "frontier": p.name in frontier,
                "served": int(sel.sum()),
                "sla_attainment": "n/a" if sel.sum() == 0 else f"{att:.3f}",
            }))
    rows.append(row("measured.overall", 0.0, {
        "n": len(trace), "sla_ms": f"{t_sla:.0f}",
        "attainment": f"{trace.attainment:.3f}",
        "int8_on_frontier": bool(
            {n for n in frontier if zoo[n].quant == "int8"}),
    }))
    _cache[n_requests] = rows
    return rows


IMPLS = ("naive", "pallas")


def perf_matrix(names=None, *, batch_size: int = 4, max_seq: int = 64,
                prompt_len: int = 16, n_tokens: int = 8, reps: int = 3,
                impls=IMPLS):
    """(rows, points): every requested zoo row × attention impl, timed
    on this host. Each point carries tokens/s, prefill_ms, per_token_ms
    and the engine's live resident bytes; per-model speedup rows compare
    the pallas fast path against the naive reference."""
    from repro.configs.paper_zoo import MEASURED_ZOO, measured_zoo_names
    from repro.serving.measured import build_model

    rows, points = [], []
    for i, name in enumerate(measured_zoo_names(names)):
        per = {}
        for impl in impls:
            m = build_model(name, batch_size=batch_size, max_seq=max_seq,
                            seed=SEED + i, attn_impl=impl)
            m.engine.warmup(prompt_len)
            p = m.engine.measured_profile(prompt_len, n_tokens, reps)
            toks_s = batch_size * 1000.0 / max(p["per_token_ms"], 1e-9)
            per[impl] = toks_s
            rows.append(row(f"measured.perf.{name}.{impl}",
                            p["per_token_ms"] * 1e3, {
                                "tokens_s": f"{toks_s:.0f}",
                                "prefill_ms": f"{p['prefill_ms']:.2f}",
                                "resident_mb":
                                    f"{p['resident_bytes'] / 1e6:.2f}",
                                "int8": MEASURED_ZOO[name]["quant"] == "int8",
                            }))
            points.append({
                "model": name, "impl": impl,
                "tokens_s": round(toks_s, 1),
                "prefill_ms": round(p["prefill_ms"], 3),
                "per_token_ms": round(p["per_token_ms"], 4),
                "resident_bytes": int(p["resident_bytes"]),
                "int8": MEASURED_ZOO[name]["quant"] == "int8",
            })
        if "naive" in per and "pallas" in per:
            rows.append(row(f"measured.perf.{name}.speedup", 0.0, {
                "pallas_vs_naive": f"{per['pallas'] / per['naive']:.2f}x"}))
    return rows, points


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny model, 1 rep (CI fast-job smoke)")
    ap.add_argument("--full", action="store_true",
                    help="full zoo matrix + capacity rows, and append "
                         "the BENCH_*.json trajectory point")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        rows, _ = perf_matrix(["lm_tiny"], batch_size=2, max_seq=32,
                              prompt_len=8, n_tokens=2, reps=1)
        emit(rows)
        return
    rows, points = perf_matrix(batch_size=args.batch_size,
                               max_seq=args.max_seq)
    if args.full:
        path = os.path.join(RESULTS_DIR, "BENCH_measured_serving.json")
        os.makedirs(RESULTS_DIR, exist_ok=True)
        series = []
        if os.path.exists(path):
            series = json.load(open(path)).get("series", [])
        series.append({"unix_time": int(time.time()),
                       "batch_size": args.batch_size,
                       "max_seq": args.max_seq, "points": points})
        with open(path, "w") as f:
            json.dump({"bench": "measured_serving", "series": series}, f,
                      indent=2, sort_keys=True)
        rows.append(row("measured.perf.trajectory", 0.0, {"path": path}))
        rows += run()
    emit(rows)


if __name__ == "__main__":
    main()
