"""Paper Fig 4 / Table 5 cold-start: model loading + first-inference time
vs hot inference. The serving analogue of "loading the CNN into (GPU)
memory" is checkpoint load + weight placement + first-call compilation;
measured on real CPU engines for two reduced models, and DERIVED for the
LM zoo (weight bytes / HBM bandwidth per pod)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import init_params
from repro.serving.engine import InferenceEngine
from repro.training.checkpoint import save_checkpoint, restore_checkpoint

HBM_BW = 819e9
CHIPS = 256


def run(tmpdir: str = "/tmp/repro_bench_ckpt"):
    rows = []
    for arch in ("stablelm_1_6b", "yi_9b"):
        cfg = reduced_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        # cold: checkpoint load + engine warmup (compile)
        save_checkpoint(tmpdir + arch, {"params": params}, step=0)
        t0 = time.perf_counter()
        restored, _ = restore_checkpoint(tmpdir + arch, {"params": params})
        load_ms = (time.perf_counter() - t0) * 1000.0
        eng = InferenceEngine(cfg, restored["params"], batch_size=2,
                              max_seq=64)
        compile_s = eng.warmup(prompt_len=8)
        prof = eng.measured_profile(prompt_len=8, n_tokens=4, reps=3)
        cold_ms = load_ms + compile_s * 1000.0 + prof["mu"]
        rows.append(row(
            f"fig4.measured.{arch}", prof["mu"] * 1000.0,
            {"hot_ms": f"{prof['mu']:.1f}",
             "cold_ms": f"{cold_ms:.1f}",
             "load_ms": f"{load_ms:.1f}",
             "compile_ms": f"{compile_s*1000:.1f}",
             "cold_over_hot": f"{cold_ms/max(prof['mu'],1e-9):.1f}x"}))
    # Derived cold-start for the LM zoo: weight movement HBM-bound.
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        bytes_bf16 = cfg.param_count() * 2
        load_s = bytes_bf16 / (CHIPS * HBM_BW)
        # DCN fetch at ~25 GB/s/host aggregate x 32 hosts as upper layer.
        fetch_s = bytes_bf16 / (32 * 25e9)
        rows.append(row(
            f"fig4.derived.{cfg.name}", load_s * 1e6,
            {"weights_GB": f"{bytes_bf16/1e9:.0f}",
             "hbm_place_s": f"{load_s:.3f}",
             "dcn_fetch_s": f"{fetch_s:.2f}"}))
    return rows
