"""Assignment §Roofline: the full baseline table from the dry-run JSONs —
three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, memory fit."""

from __future__ import annotations

from benchmarks.common import row, load_dryrun_results


def run(mesh: str = "pod", tag: str = "baseline"):
    rows = []
    res = load_dryrun_results(mesh, tag)
    for (arch, shape), d in sorted(res.items()):
        if d.get("skipped"):
            rows.append(row(f"roofline.{mesh}.{arch}.{shape}", 0.0,
                            {"skipped": "subquadratic-required"}))
            continue
        m = d["memory"]
        peak = (m["argument_bytes"] + m["output_bytes"] + m["temp_bytes"]
                - m["alias_bytes"]) / 1e9
        t = d["terms"]
        rows.append(row(
            f"roofline.{mesh}.{arch}.{shape}",
            d["step_time_est_s"] * 1e6,
            {"compute_s": f"{t['compute_s']:.4f}",
             "memory_s": f"{t['memory_s']:.4f}",
             "collective_s": f"{t['collective_s']:.4f}",
             "dominant": d["dominant"],
             "useful_ratio": f"{d['useful_flops_ratio']:.3f}",
             "peak_GB": f"{peak:.1f}",
             "fits16GB": peak <= 16.0}))
    return rows
