"""Paper Fig 3 / Table 4: on-device vs cloud-based inference.

Analogue: the "device" is a single CPU host running a small engine (this
container); the "cloud" is the TPU pod with roofline-derived step times.
Includes the paper's measured numbers for reference and reproduces the
decision rule: older/smaller devices should offload, capable devices can
run small models locally."""

from __future__ import annotations

import jax

from benchmarks.common import row, load_dryrun_results
from repro.configs import reduced_config
from repro.configs.paper_zoo import TABLE5
from repro.models import init_params
from repro.serving.engine import InferenceEngine


def run():
    rows = []
    # Paper's measured device/cloud numbers (reference points).
    rows.append(row("fig3.paper.pixel2_mobilenet_025", 133.0 * 1000,
                    {"source": "paper Fig5"}))
    rows.append(row("fig3.paper.p2xlarge_inceptionv4_hot",
                    TABLE5["inceptionv4"][2] * 1000,
                    {"source": "paper Table5",
                     "note": "GPU cloud beats on-device MobileNet by 2.5x"}))
    # Our measured "device": CPU engine, small LM.
    cfg = reduced_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
    eng.warmup(8)
    prof = eng.measured_profile(prompt_len=8, n_tokens=8, reps=3)
    rows.append(row("fig3.device.cpu_tiny_lm", prof["mu"] * 1000.0,
                    {"per_token_ms": f"{prof['per_token_ms']:.2f}"}))
    # Our derived "cloud": pod decode step estimates per arch.
    res = load_dryrun_results("pod")
    for (arch, shape), d in sorted(res.items()):
        if shape != "decode_32k" or d.get("skipped"):
            continue
        step_ms = d["step_time_est_s"] * 1000.0
        rows.append(row(f"fig3.cloud.{arch}", step_ms * 1000.0,
                        {"decode_step_ms": f"{step_ms:.2f}",
                         "batch": 128, "context": 32768}))
    return rows
