"""Kernel microbenchmarks: interpret-mode timing is NOT hardware-
representative — the derived column reports the roofline-relevant
quantities (FLOPs, bytes, arithmetic intensity) per kernel call, plus
the call's throughput as tokens/s so the kernel rows share an axis with
the measured serving rows (fig9 `measured.*` / benchmarks
.measured_serving): flash_attention processes B*T prompt tokens per
call, decode_attention B tokens, int8_matmul M activation rows."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.kernels import ops
from repro.quant import quantize_int8


def run():
    rows = []
    rng = np.random.default_rng(0)
    # flash attention
    B, H, KV, T, hd = 1, 4, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    us, _ = time_call(lambda: ops.flash_attention_btHd(
        q, k, v, block_q=64, block_k=64).block_until_ready(), reps=3)
    flops = 4 * B * H * T * T * hd
    bytes_ = 2 * B * T * (H + 2 * KV) * hd * 4
    rows.append(row("kernel.flash_attention", us,
                    {"flops": flops, "bytes": bytes_,
                     "intensity": f"{flops/bytes_:.1f}",
                     "tokens_s": f"{B * T * 1e6 / us:.0f}"}))
    # decode attention
    S = 1024
    qd = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.asarray(np.arange(S), jnp.int32)
    us, _ = time_call(lambda: ops.decode_attention(
        qd, kd, vd, pos, jnp.int32(S - 1), block_s=128).block_until_ready(),
        reps=3)
    flops = 4 * B * H * S * hd
    bytes_ = 2 * B * S * KV * hd * 4
    rows.append(row("kernel.decode_attention", us,
                    {"flops": flops, "bytes": bytes_,
                     "intensity": f"{flops/bytes_:.2f}",
                     "tokens_s": f"{B * 1e6 / us:.0f}",
                     "note": "memory-bound (reads whole cache)"}))
    # int8 matmul
    M, K, N = 256, 512, 512
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    wq, sc = quantize_int8(w, axis=0)
    us, _ = time_call(lambda: ops.int8_matmul(
        x, wq, sc.reshape(-1), block_m=128, block_n=128,
        block_k=128).block_until_ready(), reps=3)
    rows.append(row("kernel.int8_matmul", us,
                    {"flops": 2 * M * K * N,
                     "weight_bytes_vs_bf16": f"{K*N}/{K*N*2}",
                     "tokens_s": f"{M * 1e6 / us:.0f}"}))
    return rows
