"""Paper Fig 5: impact of CNN models / device capability — LM analogue:
decode & prefill cost across the 10-arch zoo (roofline pod numbers) and
measured CPU latency across reduced model sizes (the 'device capability'
axis: one CPU host standing in for phone tiers)."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row, load_dryrun_results
from repro.configs import reduced_config
from repro.models import init_params
from repro.serving.engine import InferenceEngine


def run():
    rows = []
    # "device tiers": widths of a reduced model on this host.
    base = reduced_config("stablelm_1_6b")
    for name, d_model, layers in [("xs", 32, 2), ("s", 64, 4), ("m", 128, 6)]:
        cfg = dataclasses.replace(base, d_model=d_model, n_layers=layers,
                                  n_heads=4, n_kv_heads=4,
                                  head_dim=d_model // 4, d_ff=d_model * 2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
        eng.warmup(8)
        p = eng.measured_profile(prompt_len=8, n_tokens=8, reps=3)
        rows.append(row(f"fig5.device.{name}", p["mu"] * 1000.0,
                        {"params": cfg.param_count(),
                         "ms_per_req": f"{p['mu']:.1f}"}))
    # zoo sweep from the roofline (pod).
    res = load_dryrun_results("pod")
    for (arch, shape), d in sorted(res.items()):
        if shape != "prefill_32k" or d.get("skipped"):
            continue
        rows.append(row(f"fig5.zoo_prefill.{arch}",
                        d["step_time_est_s"] * 1e6,
                        {"prefill_s": f"{d['step_time_est_s']:.2f}",
                         "dominant": d["dominant"]}))
    return rows
