"""Paper Table 5: the model zoo's statistics. Two zoos:
- the paper's CNN zoo (seed data, echoed for reference), and
- the LM zoo = the 10 assigned architectures with roofline-DERIVED
  decode/prefill latency profiles per mesh (this is what CNNSelect
  selects over at pod scale)."""

from __future__ import annotations

from benchmarks.common import row, load_dryrun_results
from repro.configs import ARCH_IDS, get_config
from repro.configs.paper_zoo import TABLE5
from repro.utils import human_count


def run():
    rows = []
    for name, (t1, t5, mu, sg, cmu, csg) in TABLE5.items():
        rows.append(row(f"table5.cnn.{name}", mu * 1000.0,
                        {"top1": t1, "hot_ms": mu, "cold_ms": cmu}))
    res = load_dryrun_results("pod")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        dec = res.get((cfg.name, "decode_32k"))
        pre = res.get((cfg.name, "prefill_32k"))
        if not dec or dec.get("skipped"):
            continue
        dec_ms = dec["step_time_est_s"] * 1000.0
        pre_ms = pre["step_time_est_s"] * 1000.0 if pre else 0.0
        rows.append(row(
            f"table5.lm.{cfg.name}", dec_ms * 1000.0,
            {"params": human_count(cfg.param_count()),
             "active": human_count(cfg.active_param_count()),
             "decode_step_ms": f"{dec_ms:.2f}",
             "prefill_s": f"{pre_ms/1000.0:.2f}",
             "dominant": dec["dominant"]}))
    return rows
