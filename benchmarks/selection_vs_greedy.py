"""Paper Fig 13 (+ headline claim): CNNSelect vs greedy over an SLA sweep,
10k-request simulations seeded with Table 5 profiles + paper network
measurements. Reports SLA attainment, effective accuracy, latency, and
the "maintains attainment in X% more cases" aggregate across the
(SLA x network) grid (paper: 88.5%)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import CNNSelectPolicy, cnnselect
from repro.serving.simulator import (SimConfig, simulate,
                                     attainment_improvement)

# Paper Fig 12/13 sweep the 0-500 ms band; attainment target 0.9.
SLAS = np.arange(60, 501, 20)
NETWORKS = ("campus_wifi", "lte", "cellular_hotspot")


def run(n_requests: int = 2000):
    profs = paper_profiles()
    rows = []
    # Fig 13a/b analogue at three representative SLAs.
    for sla in (115, 250, 600):
        ours = simulate(profs, SimConfig(t_sla=sla, n_requests=n_requests,
                                         seed=0))
        grd = simulate(profs, SimConfig(t_sla=sla, n_requests=n_requests,
                                        policy="greedy", seed=0))
        lat_red = 100.0 * (1 - ours.mean_latency / grd.mean_latency)
        rows.append(row(
            f"fig13.sla{sla}", 0.0,
            {"ours_att": f"{ours.attainment:.3f}",
             "greedy_att": f"{grd.attainment:.3f}",
             "ours_acc": f"{ours.accuracy:.3f}",
             "greedy_acc": f"{grd.accuracy:.3f}",
             "latency_reduction_pct": f"{lat_red:.1f}"}))
    # Headline aggregate across the (SLA x network) grid.
    total_ours = total_base = total_more = 0
    for net in NETWORKS:
        res = attainment_improvement(profs, SLAS, n_requests=n_requests // 4,
                                     target=0.9, network=net, seed=1)
        total_ours += res["ours_ok_cases"]
        total_base += res["base_ok_cases"]
        rows.append(row(f"fig13.grid.{net}", 0.0,
                        {"ours_ok": res["ours_ok_cases"],
                         "greedy_ok": res["base_ok_cases"],
                         "n_slas": len(SLAS)}))
    more = 100.0 * (total_ours - total_base) / max(total_base, 1)
    rows.append(row("fig13.headline_more_cases_pct", 0.0,
                    {"ours": total_ours, "greedy": total_base,
                     "more_pct": f"{more:.1f}", "paper_claims": "88.5"}))
    # Selection histogram shift (Fig 13b).
    names = [p.name for p in profs]
    tight = simulate(profs, SimConfig(t_sla=160, n_requests=n_requests,
                                      seed=0)).selection_histogram(names)
    loose = simulate(profs, SimConfig(t_sla=900, n_requests=n_requests,
                                      seed=0)).selection_histogram(names)
    top_t = max(tight, key=tight.get)
    top_l = max(loose, key=loose.get)
    rows.append(row("fig13.selection_shift", 0.0,
                    {"tight_top": top_t, "loose_top": top_l}))
    rows.extend(policy_layer_timing(profs))
    return rows


def policy_layer_timing(profs, n: int = 10000):
    """Wall-clock of the policy layer itself: per-request numpy
    `cnnselect` vs the chunked jit `select_batch` admission path the
    simulator now runs on (DESIGN.md §3)."""
    rng = np.random.default_rng(0)
    t_sla = rng.uniform(100.0, 600.0, n)
    t_input = rng.uniform(20.0, 150.0, n)

    t0 = time.perf_counter()
    for i in range(n):
        cnnselect(profs, float(t_sla[i]), float(t_input[i]), 50.0, rng)
    scalar_s = time.perf_counter() - t0

    pol = CNNSelectPolicy(t_threshold=50.0, seed=0)
    pol.select_batch(profs, t_sla, t_input)      # jit compile warmup
    t0 = time.perf_counter()
    pol.select_batch(profs, t_sla, t_input)
    batch_s = time.perf_counter() - t0

    return [
        row("policy.scalar_cnnselect", scalar_s / n * 1e6,
            {"n": n, "total_ms": f"{scalar_s * 1e3:.1f}"}),
        row("policy.batched_jit", batch_s / n * 1e6,
            {"n": n, "chunk": pol.chunk,
             "total_ms": f"{batch_s * 1e3:.1f}",
             "speedup_x": f"{scalar_s / batch_s:.1f}"}),
    ]
