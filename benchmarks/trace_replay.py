"""Sim-to-real trace replay (ROADMAP "Trace capture"; DESIGN.md §11).

Four sections, each a capture→persist→replay round trip:

1. **Prototype capture** (``trace_replay.proto.*``) — a real 2-model
   `CNNSelectServer` (tiny + small engines executing on this host)
   serves a time-varying upload trace per registry policy while a
   `TraceRecorder` captures it; the capture is saved, reloaded
   (bit-exact round trip asserted), and replayed through the simulator:
   profiles fitted from the capture's measured execution times, the
   captured T_input sequence replayed bit-for-bit
   (`CapturedTraceProcess(mode="exact")`), and the measured execution
   time of each captured selection injected (`simulate`'s
   ``exec_override``). The row reports the sim-vs-real attainment gap.
1b. **Measured zoo** (``trace_replay.measured.*``) — the same loop over
   the runnable `MEASURED_ZOO` engines (fp32 + int8 variants as
   distinct selection candidates, `serving/measured.py`): measured
   per-request exec_ms is captured and replayed through
   ``simulate(exec_override=…)``, pinning sim against *executed*
   models (DESIGN.md §14; the CI measured-serving smoke).
2. **Simulator round trip** (``trace_replay.sim.*``) — every registry
   policy (oracle included, which a live server cannot run) on the
   `lte_outages` regime-switching scenario: capture a run with
   `Trace.from_sim`, replay it exactly. Deterministic policies
   reproduce the captured attainment to the request.
3. **Reference fleet** (``trace_replay.reference_fleet``) — the
   committed capture (`configs/traces/reference_fleet.jsonl`) rebuilt
   into a device fleet (`FleetMixture.from_capture`) and replayed
   through the device-keyed `EstimatorBank` path.

``--check`` exits non-zero when any gap exceeds ``--tol`` (the CI
trace-roundtrip step); ``--write-reference`` regenerates the committed
reference capture (numpy-only policy, bit-for-bit reproducible).

Smoke (CI): ``python benchmarks/trace_replay.py --n-requests 200
--policies cnnselect,greedy_nw --check``.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import tempfile

import numpy as np

from benchmarks.common import emit, row
from repro.configs.paper_zoo import (capture_path, paper_profiles,
                                     synthetic_trace)
from repro.core.selection import make_policy
from repro.serving.fleet import FleetMixture
from repro.serving.network import TraceReplayProcess
from repro.serving.simulator import SimConfig, simulate
from repro.serving.trace import (CapturedTraceProcess, Trace,
                                 TraceRecorder, load_capture)

# Policies a live server can run (oracle needs realized times).
PROTO_POLICIES = ("cnnselect", "greedy", "greedy_nw", "random",
                  "static:small")
# The full registry, exercised on the simulator round trip.
SIM_POLICIES = ("cnnselect", "greedy", "greedy_nw", "random",
                "static:mobilenetv1_10", "oracle")
SEED = 11


def _roundtrip(trace: Trace, tmpdir: str) -> Trace:
    """save → load → assert bit-exact; returns the reloaded capture."""
    path = os.path.join(tmpdir, f"{trace.name.replace(':', '_')}.jsonl")
    trace.save(path)
    back = Trace.load(path)
    for col in ("t_arrival", "device_id", "t_input_ms", "regime_id",
                "model", "sla_ok"):
        if not np.array_equal(getattr(trace, col), getattr(back, col)):
            raise AssertionError(f"trace column {col} drifted through "
                                 f"save/load")
    if back.meta != trace.meta or back.regime_names != trace.regime_names:
        raise AssertionError("trace header drifted through save/load")
    return back


def _exec_override(trace: Trace, order) -> np.ndarray:
    """(N, K) measured-execution injection matrix: the captured
    selection's measured time per request, NaN (= sample from profile)
    elsewhere."""
    n = len(trace)
    out = np.full((n, len(order)), np.nan)
    exec_ms = np.asarray(trace.meta["exec_ms"], np.float64)
    index = {name: k for k, name in enumerate(order)}
    for i in range(n):
        k = index.get(str(trace.model[i]))
        if k is not None:
            out[i, k] = exec_ms[i]
    return out


# --------------------------------------------------------------------------
# Section 1: prototype-server capture → simulator replay
# --------------------------------------------------------------------------

def _build_server():
    import jax

    from repro.configs import reduced_config
    from repro.models import init_params
    from repro.serving.engine import InferenceEngine
    from repro.serving.server import CNNSelectServer, ServedModel

    models = []
    cfg_t = reduced_config("stablelm_1_6b")
    cfg_s = dataclasses.replace(cfg_t, n_layers=6, d_model=192, n_heads=6,
                                n_kv_heads=6, head_dim=32, d_ff=384)
    for name, cfg, acc in [("tiny", cfg_t, 0.62), ("small", cfg_s, 0.88)]:
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, params, batch_size=1, max_seq=64)
        models.append(ServedModel(name=name, engine=eng, accuracy=acc))
    srv = CNNSelectServer(models, t_threshold=30.0, n_tokens=2)
    srv.profile_models(prompt_len=8, reps=3)
    return srv


def _capture_profiles(trace: Trace, fallback) -> list:
    """Per-model profiles fitted from the capture's measured execution
    times (the distribution the replay should sample for selections the
    capture did not make), falling back to the server's live profile
    for models the capture never ran."""
    out = []
    exec_ms = np.asarray(trace.meta["exec_ms"], np.float64)
    for p in fallback:
        mask = trace.model == p.name
        if mask.sum() >= 2:
            mu = float(exec_ms[mask].mean())
            sigma = max(float(exec_ms[mask].std()), 0.5)
            out.append(dataclasses.replace(p, mu=mu, sigma=sigma))
        else:
            out.append(p)
    return out


def _capture_and_replay(srv, spec, n_requests: int, t_sla: float,
                        tin_proc, tmpdir: str, label: str):
    """Serve n_requests through `srv` under policy `spec` while a
    recorder captures measured exec_ms; round-trip the capture through
    disk, then replay it through the simulator with the captured T_input
    sequence (`mode="exact"`) and the measured execution times injected
    (`exec_override`). Returns (trace, sim_result)."""
    from repro.serving.batching import Request

    srv.metrics = type(srv.metrics)()
    srv.router.policy = make_policy(spec, t_threshold=30.0, seed=SEED)
    live_profiles = srv.current_profiles()
    t_inputs = tin_proc.sample_t_input(
        np.random.default_rng(SEED), n_requests)
    rng = np.random.default_rng(SEED + 1)
    with TraceRecorder(name=f"{label}-{spec}").attach(srv) as rec:
        for i in range(n_requests):
            req = Request(
                arrival=float(i), rid=i,
                prompt=rng.integers(0, 50, 8).astype(np.int32),
                t_input_ms=float(t_inputs[i]))
            srv.handle(req, t_sla=t_sla)
        trace = rec.to_trace(
            name=f"{label}-{spec}", source="server",
            meta={"policy": spec, "t_sla": t_sla,
                  "models": [p.name for p in live_profiles]})
    trace = _roundtrip(trace, tmpdir)
    profs = _capture_profiles(trace, live_profiles)
    sim = simulate(profs, SimConfig(
        t_sla=t_sla, n_requests=len(trace),
        network=CapturedTraceProcess(trace, mode="exact"),
        policy=make_policy(spec, t_threshold=30.0, seed=SEED),
        seed=SEED),
        exec_override=_exec_override(trace, [p.name for p in profs]))
    return trace, sim


def proto_rows(n_requests: int, policies, tol: float, tmpdir: str):
    srv = _build_server()
    live_profiles = srv.current_profiles()
    # Time-varying uploads: the wifi→lte step trace scaled to this
    # host's engine latencies, jittered per request.
    tin_proc = TraceReplayProcess(
        0.2 * synthetic_trace("wifi_lte_step", n_requests),
        jitter_cv=0.15, name="wifi_lte_step*0.2")
    mus = {p.name: p.mu for p in live_profiles}
    t_sla = float(2.2 * tin_proc.mean + 1.25 * mus["small"])
    rows, failures = [], []
    for spec in policies:
        trace, sim = _capture_and_replay(srv, spec, n_requests, t_sla,
                                         tin_proc, tmpdir, "proto")
        gap = sim.attainment - trace.attainment
        ok = abs(gap) <= tol
        if not ok:
            failures.append(f"proto.{spec}: gap {gap:+.3f} > {tol}")
        rows.append(row(f"trace_replay.proto.{spec}", 0.0, {
            "n": len(trace), "sla_ms": f"{t_sla:.0f}",
            "cap_att": f"{trace.attainment:.3f}",
            "sim_att": f"{sim.attainment:.3f}", "gap": f"{gap:+.3f}",
            "within_tol": ok, "roundtrip": "bit-exact"}))
    return rows, failures


# --------------------------------------------------------------------------
# Section 1b: measured zoo (fp32 + int8 engines) → simulator replay
# --------------------------------------------------------------------------

def measured_rows(n_requests: int, tol: float, tmpdir: str,
                  policies=("cnnselect", "greedy_nw"),
                  impl: str = "pallas"):
    """The measured-serving gate (DESIGN.md §14): a CNNSelectServer over
    the live `MEASURED_ZOO` engines (fp32 + int8 candidates) captures
    executed per-request exec_ms; the capture replays through
    `simulate(exec_override=…)` and the sim-vs-measured attainment gap
    is the row. This pins the control stack against *executed* models,
    not Table 5 lookups."""
    from repro.serving.measured import build_zoo, served_models
    from repro.serving.server import CNNSelectServer

    zoo = build_zoo(batch_size=1, max_seq=64, attn_impl=impl)
    srv = CNNSelectServer(served_models(zoo), t_threshold=30.0, n_tokens=2)
    srv.profile_models(prompt_len=8, reps=3)
    live = srv.current_profiles()
    tin_proc = TraceReplayProcess(
        0.2 * synthetic_trace("wifi_lte_step", n_requests),
        jitter_cv=0.15, name="wifi_lte_step*0.2")
    # SLA between the fastest and slowest engines so selection matters.
    t_sla = float(2.2 * tin_proc.mean
                  + 1.25 * np.median([p.mu for p in live]))
    rows, failures = [], []
    for spec in policies:
        trace, sim = _capture_and_replay(srv, spec, n_requests, t_sla,
                                         tin_proc, tmpdir, "measured")
        gap = sim.attainment - trace.attainment
        ok = abs(gap) <= tol
        if not ok:
            failures.append(f"measured.{spec}: gap {gap:+.3f} > {tol}")
        sel = {m: int((trace.model == m).sum()) for m in zoo}
        int8_share = sum(v for m, v in sel.items()
                         if zoo[m].quant == "int8") / max(1, len(trace))
        rows.append(row(f"trace_replay.measured.{spec}", 0.0, {
            "impl": impl, "n": len(trace), "sla_ms": f"{t_sla:.0f}",
            "cap_att": f"{trace.attainment:.3f}",
            "sim_att": f"{sim.attainment:.3f}", "gap": f"{gap:+.3f}",
            "within_tol": ok, "int8_share": f"{int8_share:.2f}",
            "sel": "/".join(f"{m}:{v}" for m, v in sel.items() if v)}))
    return rows, failures


# --------------------------------------------------------------------------
# Section 2: simulator capture → exact replay (every registry policy)
# --------------------------------------------------------------------------

def sim_rows(n_requests: int, tol: float, tmpdir: str):
    profs = paper_profiles()
    names = [p.name for p in profs]
    rows, failures = [], []
    for spec in SIM_POLICIES:
        cap = simulate(profs, SimConfig(
            t_sla=300.0, n_requests=n_requests, seed=SEED,
            network="lte_outages", policy=spec, t_estimator="ewma:0.2"))
        trace = Trace.from_sim(cap, name=f"sim-{spec.replace(':', '_')}",
                               meta={"models": names, "policy": spec})
        trace.meta["exec_ms"] = [
            float(v) for v in cap.latencies - 2.0 * cap.t_inputs]
        trace = _roundtrip(trace, tmpdir)
        sim = simulate(profs, SimConfig(
            t_sla=300.0, n_requests=len(trace),
            network=CapturedTraceProcess(trace, mode="exact"),
            policy=spec, seed=SEED, t_estimator="ewma:0.2"),
            exec_override=_exec_override(trace, names))
        gap = sim.attainment - trace.attainment
        ok = abs(gap) <= tol
        if not ok:
            failures.append(f"sim.{spec}: gap {gap:+.3f} > {tol}")
        rows.append(row(f"trace_replay.sim.{spec}", 0.0, {
            "n": len(trace), "cap_att": f"{trace.attainment:.3f}",
            "sim_att": f"{sim.attainment:.3f}", "gap": f"{gap:+.3f}",
            "within_tol": ok}))
    return rows, failures


# --------------------------------------------------------------------------
# Section 3: the committed reference-fleet capture
# --------------------------------------------------------------------------

REFERENCE_CFG = dict(t_sla=350.0, n_requests=256, seed=0,
                     fleet="mixed_fleet", policy="greedy_nw",
                     t_estimator="ewma:0.2")


def write_reference(path: str) -> Trace:
    """Regenerate the committed reference capture. greedy_nw is
    numpy-only, so the file is bit-for-bit reproducible across jax
    versions (pinned by tests/test_trace.py)."""
    profs = paper_profiles()
    r = simulate(profs, SimConfig(**REFERENCE_CFG))
    trace = Trace.from_sim(
        r, name="reference_fleet",
        meta={"models": [p.name for p in profs], **REFERENCE_CFG})
    trace.save(path)
    return trace


def reference_rows(n_requests: int):
    trace = load_capture("reference_fleet")
    fleet = FleetMixture.from_capture(trace, mode="loop")
    r = simulate(paper_profiles(), SimConfig(
        t_sla=float(trace.meta["t_sla"]), n_requests=n_requests,
        seed=SEED, fleet=fleet, policy=str(trace.meta["policy"]),
        t_estimator=str(trace.meta["t_estimator"])))
    per_dev = {f"att[{k}]": f"{v['attainment']:.3f}"
               for k, v in r.per_device().items()}
    return [row("trace_replay.reference_fleet", 0.0, {
        "cap_att": f"{trace.attainment:.3f}",
        "replay_att": f"{r.attainment:.3f}",
        "gap": f"{r.attainment - trace.attainment:+.3f}",
        "devices": "/".join(trace.device_ids()), **per_dev})]


def run_checked(n_requests: int = 400, policies=PROTO_POLICIES,
                tol: float = 0.02,
                sections=("proto", "measured", "sim", "reference"),
                measured_policies=("cnnselect", "greedy_nw"),
                measured_impl: str = "pallas"):
    rows, failures = [], []
    with tempfile.TemporaryDirectory() as tmpdir:
        if "proto" in sections:
            r, f = proto_rows(n_requests, policies, tol, tmpdir)
            rows += r
            failures += f
        if "measured" in sections:
            r, f = measured_rows(n_requests, tol, tmpdir,
                                 policies=measured_policies,
                                 impl=measured_impl)
            rows += r
            failures += f
        if "sim" in sections:
            r, f = sim_rows(max(10 * n_requests, 2000), tol, tmpdir)
            rows += r
            failures += f
    if "reference" in sections:
        rows += reference_rows(max(8 * n_requests, 2000))
    return rows, failures


def run(n_requests: int = 400):
    """benchmarks.run entry point (rows only; gaps are reported, not
    gated — the CI smoke uses --check)."""
    rows, _ = run_checked(n_requests)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=400)
    ap.add_argument("--policies", default=",".join(PROTO_POLICIES),
                    help="comma-separated registry specs for the "
                         "prototype section")
    ap.add_argument("--tol", type=float, default=0.02,
                    help="max |sim - capture| attainment gap")
    ap.add_argument("--sections", default="proto,measured,sim,reference")
    ap.add_argument("--measured-policies", default="cnnselect,greedy_nw",
                    help="comma-separated registry specs for the "
                         "measured-zoo section (the CI gate pins "
                         "cnnselect; greedy_nw's online-profile drift "
                         "makes its selections replay-divergent at "
                         "small n)")
    ap.add_argument("--measured-impl", default="pallas",
                    help="attn_impl for the measured-zoo engines "
                         "(pallas = the masked kernel fast path; "
                         "naive/jax_chunked for A/B)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when any gap exceeds --tol "
                         "(the CI sim-to-real smoke)")
    ap.add_argument("--write-reference", action="store_true",
                    help="regenerate the committed reference capture")
    args = ap.parse_args()
    if args.write_reference:
        path = capture_path("reference_fleet")
        trace = write_reference(path)
        print(f"wrote {path} ({len(trace)} requests, "
              f"attainment {trace.attainment:.3f})")
        return
    rows, failures = run_checked(
        args.n_requests, args.policies.split(","), args.tol,
        args.sections.split(","),
        measured_policies=args.measured_policies.split(","),
        measured_impl=args.measured_impl)
    emit(rows)
    if failures:
        print("\n".join(f"FAIL {f}" for f in failures), file=sys.stderr)
        if args.check:
            sys.exit(1)


if __name__ == "__main__":
    main()
