"""Cluster scaling: the python multi-tenant `Cluster` loop vs the jit
`lax.scan` cluster program (serving/cluster_engine.py, DESIGN.md §17).

Sweeps tenant-fleet sizes through both engines on the same workload —
a three-SLA-class tenant mix (`scale_tenant_mix`) whose array fleets
total 1k / 100k / 1M devices, served by a 3-replica cluster with the
full control plane live: per-device adaptive controllers feeding
cluster scale switches, least-queue-delay placement over the active
prefix, SLA-class-priority shedding, and degraded-regime hedging.
Rate points run without a cluster memory budget (placement without
global-LRU churn — the budgeted compile path is covered by the check
row below and benchmarks/server_capacity.py); the scan engine is timed
with ``collect_rows=False``, its columnar-result fleet-scale path.

Measurement mirrors benchmarks/engine_scale.py: each scan point runs
once un-timed to warm the jit cache, then reports the median of
`repeats` timed runs; the python engine needs no warmup. The
acceptance sweep (`--full`) runs python at the full request count so
the 100k-device speedup is measured on literally identical workloads;
the 1M-device point runs the scan engine only.

Rows: ``cluster.<engine>.d<devices>`` with requests/sec, plus
``cluster.speedup.d<devices>`` where both engines ran (the acceptance
gate: >= 20x at 100k tenant-devices) and one ``cluster.check.d1000``
row — python vs scan events + metrics bitwise, and the
place/evict/scale/shed event log replayed through `replay_events`,
under a tight memory budget so eviction is exercised.

Trajectory artifact: full runs append a point to
``benchmarks/results/BENCH_cluster_scale.json`` (requests/sec per
size), the perf series CI tracks across main pushes from this PR on.

Smoke (CI): ``python benchmarks/cluster_scale.py --smoke``.
Full (acceptance): ``python benchmarks/cluster_scale.py --full``.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

from benchmarks.common import RESULTS_DIR, emit, row

MODELS = ["mobilenetv1_025", "mobilenetv1_10", "inceptionv3"]
N_REPLICAS = 3
RATE_HZ = 12.0
SEED = 7
CHECK_BUDGET = int(250e6)     # ~2 of 3 hot sets: forces eviction

# (devices, python-engine requests, scan-engine requests).
SWEEP_SMOKE = [(1_000, 3_000, 3_000)]
SWEEP_RUN = [(1_000, 20_000, 20_000), (100_000, 50_000, 200_000)]
SWEEP_FULL = [(1_000, 100_000, 100_000),
              (100_000, 200_000, 200_000),
              (1_000_000, None, 1_000_000)]


def _replicas(seed: int = 100):
    from repro.configs.paper_zoo import paper_profiles
    from repro.serving.stack import SimReplicaStack
    return [SimReplicaStack(paper_profiles(MODELS), seed=seed + i,
                            name=f"r{i}") for i in range(N_REPLICAS)]


def _cluster(mix, engine: str, shards: int, budget=None):
    from repro.serving.cluster import Cluster
    return Cluster(_replicas(), mix, memory_budget_bytes=budget,
                   engine=engine, shards=shards)


def _scan_once(mix, wl, shards: int):
    from repro.serving.cluster_engine import scan_cluster_run
    cl = _cluster(mix, "scan", shards)
    t0 = time.perf_counter()
    res = scan_cluster_run(cl, wl, shards=shards, collect_rows=False)
    return time.perf_counter() - t0, res, cl


def _check(n_requests: int, shards: int):
    """Equality + replay pin at 1k devices under a tight budget: scan
    events and metrics rows must be bitwise the python engine's, and
    the python event log must replay exactly."""
    from repro.configs.paper_zoo import scale_tenant_mix
    from repro.serving.cluster import (capture_run, make_tenant_workload,
                                       replay_events)
    mix = scale_tenant_mix(1_000)
    wl = make_tenant_workload(mix, n_requests=n_requests,
                              rate_hz=RATE_HZ, seed=SEED)
    mk = lambda: _cluster(mix, "python", 1, budget=CHECK_BUDGET)
    cp = mk()
    trace = capture_run(cp, wl)
    replay_ok = replay_events(trace, mk)
    cs = _cluster(mix, "scan", shards, budget=CHECK_BUDGET)
    cs.run(wl)
    scan_ok = (cp.events == cs.events
               and cp.metrics.records == cs.metrics.records)
    return (row("cluster.check.d1000", 0.0,
                {"requests": n_requests, "events": len(cp.events),
                 "scan_exact": scan_ok, "replay_exact": replay_ok}),
            scan_ok and replay_ok)


def bench(sweep, shards: int = 1, trajectory: bool = False,
          check: bool = False):
    from repro.configs.paper_zoo import scale_tenant_mix
    from repro.serving.cluster import make_tenant_columns
    rows = []
    points = []
    for devices, n_py, n_scan in sweep:
        mix = scale_tenant_mix(devices)
        rates = {}
        for engine, n in (("python", n_py), ("scan", n_scan)):
            if n is None:
                continue
            wl = make_tenant_columns(mix, n_requests=n,
                                     rate_hz=RATE_HZ, seed=SEED)
            if engine == "scan":
                _scan_once(mix, wl, shards)            # warm this shape
                repeats = 2 if devices >= 1_000_000 else 3
                runs = [_scan_once(mix, wl, shards)
                        for _ in range(repeats)]
                dt = statistics.median(d for d, _, _ in runs)
                _, res, cl = runs[-1]
                att = float(res.ok.mean())
                extra = {"sheds": int(res.shed.sum()),
                         "hedges": int(res.hedged.sum()),
                         "events": len(cl.events)}
            else:
                cl = _cluster(mix, "python", 1)
                t0 = time.perf_counter()
                cl.run(wl)
                dt = time.perf_counter() - t0
                s = cl.metrics.summary()
                att = s["attainment"]
                extra = {"sheds": s.get("fallbacks", 0),
                         "hedges": s.get("hedges", 0),
                         "events": len(cl.events)}
            rates[engine] = n / dt
            rows.append(row(f"cluster.{engine}.d{devices}", dt * 1e6,
                            dict({"devices": devices, "requests": n,
                                  "reqs_per_s": f"{n / dt:.0f}",
                                  "attainment": f"{att:.4f}"}, **extra)))
            points.append({"devices": devices, "requests": n,
                           "engine": engine,
                           "reqs_per_s": round(n / dt, 1)})
        if len(rates) == 2:
            rows.append(row(
                f"cluster.speedup.d{devices}", 0.0,
                {"devices": devices,
                 "x": f"{rates['scan'] / rates['python']:.1f}"}))
    check_row, check_ok = _check(n_requests=2_000, shards=shards)
    rows.append(check_row)
    if check and not check_ok:
        raise SystemExit("cluster_scale check FAILED: " + check_row)
    if trajectory:
        path = os.path.join(RESULTS_DIR, "BENCH_cluster_scale.json")
        os.makedirs(RESULTS_DIR, exist_ok=True)
        series = []
        if os.path.exists(path):
            series = json.load(open(path)).get("series", [])
        series.append({"unix_time": int(time.time()),
                       "shards": shards, "points": points})
        with open(path, "w") as f:
            json.dump({"bench": "cluster_scale", "series": series}, f,
                      indent=2, sort_keys=True)
        rows.append(row("cluster.trajectory", 0.0, {"path": path}))
    return rows


def run():
    """benchmarks.run entry: moderate sizes (CI artifact job)."""
    return bench(SWEEP_RUN)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI fast-job smoke); exits "
                         "non-zero if the scan/replay check fails")
    ap.add_argument("--full", action="store_true",
                    help="acceptance sizes incl. 1M tenant-devices, "
                         "and append the BENCH_*.json trajectory point")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the controller program's device axis "
                         "(needs host devices; see "
                         "repro.utils.config.configure)")
    args = ap.parse_args()
    if args.shards > 1:
        from benchmarks.common import configure_host
        configure_host(host_devices=args.shards)
    sweep = (SWEEP_SMOKE if args.smoke
             else SWEEP_FULL if args.full else SWEEP_RUN)
    print("name,us_per_call,derived")
    emit(bench(sweep, shards=args.shards, trajectory=args.full,
               check=args.smoke))


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    main()
