"""Paper Fig 10: cloud-based inference under different mobile network
conditions — end-to-end classification time distribution per network,
plus CNNSelect's attainment per network at a fixed SLA. (The
time-varying extension of this figure lives in network_dynamics.py.)"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.configs.paper_zoo import paper_profiles
from repro.core.selection import T_NW_FACTOR
from repro.serving.network import make_network
from repro.serving.simulator import SimConfig, simulate


def run(n_requests: int = 2000):
    profs = paper_profiles()
    rows = []
    rng = np.random.default_rng(0)
    for net in ("edge_wired", "campus_wifi", "lte", "cellular_hotspot"):
        t_in = make_network(net).sample_t_input(rng, 4000)
        r = simulate(profs, SimConfig(t_sla=400, n_requests=n_requests,
                                      network=net, seed=0))
        nw_frac = T_NW_FACTOR * t_in.mean() / r.mean_latency
        rows.append(row(
            f"fig10.{net}", 0.0,
            {"t_input_mean_ms": f"{t_in.mean():.1f}",
             "t_input_p95_ms": f"{np.percentile(t_in, 95):.1f}",
             "e2e_mean_ms": f"{r.mean_latency:.1f}",
             "network_share": f"{nw_frac:.2f}",
             "attainment@400ms": f"{r.attainment:.3f}"}))
    return rows
