"""Paper Fig 9: inference time across cloud server capacities.

Server tiers -> mesh slices: 1 chip, 4x4 slice, 16x16 pod, 2x16x16
multi-pod. Per-arch decode-step estimates scale the roofline terms with
chip count (compute/memory scale 1/n; collective grows with ring size:
we reuse the measured pod/multipod cells where present and scale
analytically for the small slices).

The analytic fig9 rows are joined by `measured.*` rows from the zoo
engines that actually execute on this host (benchmarks.measured_serving)
so estimated and measured capacity land on the same tokens/s +
SLA-attainment axis.

``--multi-tenant`` (or `run_multi_tenant`) benchmarks the cluster
control plane (serving/cluster.py, DESIGN.md §16): each TENANT_MIXES
workload at ``--rate-mult`` times the 4 Hz single-replica baseline rate
is served by an N-replica shared cluster and by every static
tenant->replica pinning (each pinned replica gets budget/N memory).
Reported per mix: cluster SLA attainment vs the best static
assignment, event counts, whether the placement/eviction/scale event
log replays bit-for-bit from the captured trace, and whether the scan
cluster engine (serving/cluster_engine.py) reproduces the python run
bit-for-bit. ``--check`` exits non-zero unless the cluster beats
best-static on every mix AND every replay and scan run is exact — the
CI acceptance gate."""

from __future__ import annotations

import itertools

from benchmarks.common import row, load_dryrun_results
from repro.configs import ARCH_IDS, get_config

TIERS = {"1chip": 1, "4x4": 16, "pod_16x16": 256}

# Multi-tenant scenario: the 3-model TABLE5 frontier subset on 3
# replicas under one cluster-wide budget that holds ~2 of the 3 full
# per-replica hot sets — tight enough to force cross-replica eviction,
# loose enough that placement isn't pure cold-start thrash.
CLUSTER_MODELS = ["mobilenetv1_025", "mobilenetv1_10", "inceptionv3"]
CLUSTER_BUDGET = int(250e6)
N_REPLICAS = 3
BASE_RATE_HZ = 4.0           # single-replica measured-serving scale
MIXES = ("consumer_burst", "enterprise_degraded")


def _replicas(seed: int):
    from repro.configs.paper_zoo import paper_profiles
    from repro.serving.stack import SimReplicaStack
    return [SimReplicaStack(paper_profiles(CLUSTER_MODELS),
                            seed=seed + i, name=f"replica{i}")
            for i in range(N_REPLICAS)]


def _best_static(reqs, tenants, seed: int):
    """Best static tenant->replica pinning: enumerate assignments;
    each pinned replica runs alone on budget/N memory (a fair split of
    the cluster budget)."""
    best, best_assign = -1.0, None
    ordered = sorted(reqs, key=lambda r: r.arrival)
    for assign in itertools.product(range(N_REPLICAS),
                                    repeat=len(tenants)):
        reps = _replicas(seed)
        for r in reps:
            r.router.zoo.memory_budget = CLUSTER_BUDGET // N_REPLICAS
        t2r = {t.name: assign[k] for k, t in enumerate(tenants)}
        ok = 0
        for req in ordered:
            out = reps[t2r[req.tenant]].submit(req, now=req.arrival)
            ok += bool(out.ok)
        att = ok / max(len(ordered), 1)
        if att > best:
            best, best_assign = att, assign
    return best, best_assign


def run_multi_tenant(mixes=MIXES, *, n_requests: int = 600,
                     rate_mult: float = 10.0, seed: int = 100,
                     check: bool = False):
    """One row per tenant mix: shared cluster vs best static pinning
    at ``rate_mult`` x the single-replica baseline rate."""
    from collections import Counter
    from repro.serving.cluster import (Cluster, capture_run,
                                       make_tenant_workload,
                                       make_tenants, replay_events)
    rate_hz = BASE_RATE_HZ * rate_mult
    rows, failures = [], []
    for mix in mixes:
        reqs = make_tenant_workload(mix, n_requests=n_requests,
                                    rate_hz=rate_hz, seed=0)
        mk = lambda: Cluster(_replicas(seed), mix,
                             memory_budget_bytes=CLUSTER_BUDGET)
        cluster = mk()
        trace = capture_run(cluster, reqs)
        s = cluster.metrics.summary()
        replay_ok = replay_events(trace, mk)
        # The scan engine (serving/cluster_engine.py) must reproduce
        # the python loop bit-for-bit on the same workload: every
        # event and every metrics row.
        scl = Cluster(_replicas(seed), mix,
                      memory_budget_bytes=CLUSTER_BUDGET, engine="scan")
        scl.run(reqs)
        scan_ok = (scl.events == cluster.events
                   and scl.metrics.records == cluster.metrics.records)
        static, assign = _best_static(reqs, make_tenants(mix), seed)
        kinds = Counter(e["kind"] for e in cluster.events)
        rows.append(row(
            f"fig9.cluster.{mix}", s["mean_ms"] * 1e3, {
                "rate_hz": f"{rate_hz:.0f}",
                "attainment": f"{s['attainment']:.3f}",
                "best_static": f"{static:.3f}",
                "best_assign": "/".join(map(str, assign)),
                "hedges": s.get("hedges", 0),
                "sheds": kinds.get("shed", 0),
                "places": kinds.get("place", 0),
                "evicts": kinds.get("evict", 0),
                "scales": (kinds.get("scale_up", 0)
                           + kinds.get("scale_down", 0)),
                "replay_exact": replay_ok,
                "scan_exact": scan_ok}))
        if s["attainment"] <= static:
            failures.append(f"{mix}: cluster {s['attainment']:.3f} "
                            f"<= static {static:.3f}")
        if not replay_ok:
            failures.append(f"{mix}: event replay diverged")
        if not scan_ok:
            failures.append(f"{mix}: scan engine diverged from python")
    if check and failures:
        raise SystemExit("multi-tenant check FAILED: "
                         + "; ".join(failures))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--multi-tenant", action="store_true",
                    help="run only the multi-tenant cluster benchmark")
    ap.add_argument("--rate-mult", type=float, default=10.0,
                    help="request-rate multiplier over the 4 Hz "
                         "single-replica baseline (default 10x)")
    ap.add_argument("--n-requests", type=int, default=600)
    ap.add_argument("--seed", type=int, default=100)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the cluster beats best "
                         "static pinning on every mix and every event "
                         "log replays bit-for-bit")
    args = ap.parse_args()
    rows = (run_multi_tenant(rate_mult=args.rate_mult,
                             n_requests=args.n_requests,
                             seed=args.seed, check=args.check)
            if args.multi_tenant else run())
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


def run():
    rows = []
    pod = load_dryrun_results("pod")
    multi = load_dryrun_results("multipod")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        d = pod.get((cfg.name, "decode_32k"))
        if not d or d.get("skipped"):
            continue
        base = d["terms"]
        hbm_bytes = d["hlo"]["traffic_bytes"] * 256  # global
        for tier, chips in TIERS.items():
            # memory/compute scale with chips; collectives vanish at 1 chip.
            mem_gb = (d["memory"]["argument_bytes"] * 256 / chips) / 1e9
            fits = mem_gb <= 16 * chips / chips  # per-chip budget
            est = (base["compute_s"] * 256 / chips
                   + base["memory_s"] * 256 / chips
                   + (base["collective_s"] if chips > 1 else 0.0))
            rows.append(row(
                f"fig9.{cfg.name}.{tier}", est * 1e6,
                {"est_decode_s": f"{est:.4f}",
                 "per_chip_GB": f"{mem_gb:.1f}",
                 "fits": mem_gb <= 16.0}))
        m = multi.get((cfg.name, "decode_32k"))
        if m and not m.get("skipped"):
            rows.append(row(
                f"fig9.{cfg.name}.multipod_2x256", m["step_time_est_s"] * 1e6,
                {"est_decode_s": f"{m['step_time_est_s']:.4f}",
                 "dominant": m["dominant"]}))
    # Measured counterpart: tokens/s + SLA attainment from engines that
    # actually run here, on the same row axis as the estimates above.
    from benchmarks import measured_serving
    rows += measured_serving.run()
    # Multi-tenant cluster rows (small config; full sweep via
    # `python -m benchmarks.server_capacity --multi-tenant`).
    rows += run_multi_tenant(n_requests=400)
    return rows


if __name__ == "__main__":
    main()
