"""Paper Fig 9: inference time across cloud server capacities.

Server tiers -> mesh slices: 1 chip, 4x4 slice, 16x16 pod, 2x16x16
multi-pod. Per-arch decode-step estimates scale the roofline terms with
chip count (compute/memory scale 1/n; collective grows with ring size:
we reuse the measured pod/multipod cells where present and scale
analytically for the small slices).

The analytic fig9 rows are joined by `measured.*` rows from the zoo
engines that actually execute on this host (benchmarks.measured_serving)
so estimated and measured capacity land on the same tokens/s +
SLA-attainment axis."""

from __future__ import annotations

from benchmarks.common import row, load_dryrun_results
from repro.configs import ARCH_IDS, get_config

TIERS = {"1chip": 1, "4x4": 16, "pod_16x16": 256}


def run():
    rows = []
    pod = load_dryrun_results("pod")
    multi = load_dryrun_results("multipod")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        d = pod.get((cfg.name, "decode_32k"))
        if not d or d.get("skipped"):
            continue
        base = d["terms"]
        hbm_bytes = d["hlo"]["traffic_bytes"] * 256  # global
        for tier, chips in TIERS.items():
            # memory/compute scale with chips; collectives vanish at 1 chip.
            mem_gb = (d["memory"]["argument_bytes"] * 256 / chips) / 1e9
            fits = mem_gb <= 16 * chips / chips  # per-chip budget
            est = (base["compute_s"] * 256 / chips
                   + base["memory_s"] * 256 / chips
                   + (base["collective_s"] if chips > 1 else 0.0))
            rows.append(row(
                f"fig9.{cfg.name}.{tier}", est * 1e6,
                {"est_decode_s": f"{est:.4f}",
                 "per_chip_GB": f"{mem_gb:.1f}",
                 "fits": mem_gb <= 16.0}))
        m = multi.get((cfg.name, "decode_32k"))
        if m and not m.get("skipped"):
            rows.append(row(
                f"fig9.{cfg.name}.multipod_2x256", m["step_time_est_s"] * 1e6,
                {"est_decode_s": f"{m['step_time_est_s']:.4f}",
                 "dominant": m["dominant"]}))
    # Measured counterpart: tokens/s + SLA attainment from engines that
    # actually run here, on the same row axis as the estimates above.
    from benchmarks import measured_serving
    rows += measured_serving.run()
    return rows
