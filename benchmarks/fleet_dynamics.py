"""Device-fleet serving dynamics (beyond-paper; the MDInference/ModiPick
regime composed with the paper's Table 4 device tiers).

Three questions, each reported per device tier and per network regime:

1. **Outage-aware hedging/fallback vs the p95 queue mark** — on
   `lte_outage_fleet` (midrange tier walking through `lte_outages`),
   `hedge="outage"` re-issues degraded requests to the second replica
   and falls back on-device when the estimated cloud path cannot meet
   the SLA at all; `hedge="p95"` only reacts to queueing. The headline
   row contrasts the *degraded-regime tier's* attainment under both.
2. **Device-keyed estimation vs one global estimator** — on
   `mixed_fleet`, a per-device `EstimatorBank` budgets each tier from
   its own radio history; a single shared EWMA smears WiFi and hotspot
   observations together.
3. **Client-side (stale) estimation** — `estimator_lag=1` feeds each
   device only its one-RTT-stale observations (ModiPick's pre-upload
   view); the rows report how much attainment the staleness costs.

Rows:
- ``fleet.<scenario>.<variant>`` — overall + per-device attainment.
- ``fleet.<scenario>.regimes.<variant>`` — per-regime attainment for
  the fleet variants (regime names are device-prefixed).
- ``fleet.outage_headline`` — degraded-tier attainment: outage vs p95.
- ``fleet.lag`` — lag=0 vs lag=1 attainment (the staleness cost).

Smoke (CI): ``python benchmarks/fleet_dynamics.py --n-requests 200``.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, row
from repro.configs.paper_zoo import paper_profiles
from repro.serving.simulator import SimConfig, simulate

T_SLA = 350.0
SEED = 3

# (label, SimConfig overrides): the hedging contrast runs open-loop on
# two replicas at moderate utilization so the p95 queue mark has
# something to react to; greedy/static are the paper baselines.
OUTAGE_VARIANTS = (
    ("cnnselect+none", dict(policy="cnnselect", t_estimator="ewma:0.2",
                            hedge="none")),
    ("cnnselect+p95", dict(policy="cnnselect", t_estimator="ewma:0.2",
                           hedge="p95")),
    ("cnnselect+outage", dict(policy="cnnselect", t_estimator="ewma:0.2",
                              hedge="outage")),
    ("greedy", dict(policy="greedy", hedge="none")),
    ("static:mnv1_10", dict(policy="static:mobilenetv1_10", hedge="none")),
)

MIXED_VARIANTS = (
    ("obs", dict(policy="cnnselect", t_estimator=None)),
    ("bank_ewma", dict(policy="cnnselect", t_estimator="ewma:0.2")),
    ("bank_ewma_lag1", dict(policy="cnnselect", t_estimator="ewma:0.2",
                            estimator_lag=1)),
    ("greedy_nw", dict(policy="greedy_nw", t_estimator=None)),
)


def _fmt(stats: dict) -> dict:
    return {k: f"{v['attainment']:.3f}" for k, v in stats.items()}


def _run(fleet: str, n_requests: int, **overrides):
    cfg = SimConfig(t_sla=T_SLA, n_requests=n_requests, seed=SEED,
                    fleet=fleet, **overrides)
    return simulate(paper_profiles(), cfg)


def outage_rows(n_requests: int):
    """lte_outage_fleet: hedging/fallback policy contrast, open loop on
    two replicas. The degraded-regime tier is `midrange` (its radio is
    the `lte_outages` Markov scenario)."""
    rows, results = [], {}
    for label, over in OUTAGE_VARIANTS:
        r = _run("lte_outage_fleet", n_requests,
                 arrival_rate_hz=15.0, n_servers=2, **over)
        results[label] = r
        rows.append(row(f"fleet.lte_outage_fleet.{label}", 0.0, {
            "attainment": f"{r.attainment:.3f}",
            "accuracy": f"{r.accuracy:.3f}",
            "hedges": r.hedges, "fallbacks": r.fallbacks,
            **{f"att[{k}]": v
               for k, v in _fmt(r.per_device()).items()}}))
    for label in ("cnnselect+p95", "cnnselect+outage"):
        rows.append(row(
            f"fleet.lte_outage_fleet.regimes.{label}", 0.0,
            {f"att[{k}]": v
             for k, v in _fmt(results[label].per_regime()).items()}))
    # Acceptance headline: the degraded-regime device tier under
    # outage-aware hedging/fallback vs the p95-only knob.
    p95 = results["cnnselect+p95"].per_device()["midrange"]["attainment"]
    outage = results["cnnselect+outage"].per_device()["midrange"][
        "attainment"]
    rows.append(row("fleet.outage_headline", 0.0, {
        "tier": "midrange(lte_outages)",
        "p95_att": f"{p95:.3f}", "outage_att": f"{outage:.3f}",
        "recovered": f"{outage - p95:.3f}",
        "outage_gt_p95": outage > p95}))
    return rows


def mixed_rows(n_requests: int):
    """mixed_fleet (closed loop): per-device estimation vs the raw
    observation, and the ModiPick client-side staleness cost."""
    rows, att = [], {}
    for label, over in MIXED_VARIANTS:
        r = _run("mixed_fleet", n_requests, **over)
        att[label] = r.attainment
        rows.append(row(f"fleet.mixed_fleet.{label}", 0.0, {
            "attainment": f"{r.attainment:.3f}",
            "accuracy": f"{r.accuracy:.3f}",
            **{f"att[{k}]": v
               for k, v in _fmt(r.per_device()).items()}}))
    # One global EWMA over the same interleaved trace
    # (estimator_scope="global"): the smeared-estimator strawman a
    # device-keyed bank replaces.
    r = _run("mixed_fleet", n_requests, policy="cnnselect",
             t_estimator="ewma:0.2", estimator_scope="global")
    rows.append(row("fleet.mixed_fleet.shared_ewma", 0.0, {
        "attainment": f"{r.attainment:.3f}",
        "accuracy": f"{r.accuracy:.3f}",
        "bank_minus_shared": f"{att['bank_ewma'] - r.attainment:.3f}",
        **{f"att[{k}]": v for k, v in _fmt(r.per_device()).items()}}))
    rows.append(row("fleet.lag", 0.0, {
        "lag0_att": f"{att['bank_ewma']:.3f}",
        "lag1_att": f"{att['bank_ewma_lag1']:.3f}",
        "staleness_cost": f"{att['bank_ewma'] - att['bank_ewma_lag1']:.3f}",
    }))
    return rows


def run(n_requests: int = 4000):
    return outage_rows(n_requests) + mixed_rows(n_requests)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=4000)
    args = ap.parse_args()
    emit(run(args.n_requests))


if __name__ == "__main__":
    main()
