"""Shared benchmark plumbing: timing, CSV rows, result-dir access."""

from __future__ import annotations

import glob
import json
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def configure_host(host_devices: int | None = None, x64: bool | None = None,
                   platform: str | None = None) -> None:
    """Benchmark entry points call this before touching jax so XLA
    flags (fake host device count, platform pin) actually apply —
    see repro.utils.config.configure for the rules."""
    from repro.utils.config import configure
    configure(platform=platform, x64=x64, host_devices=host_devices)


def time_call(fn, *args, reps: int = 5, warmup: int = 1):
    """Returns (mean_us, std_us) of fn(*args)."""
    import numpy as np
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.mean(ts)), float(np.std(ts))


def row(name: str, us_per_call: float, derived) -> str:
    if isinstance(derived, dict):
        derived = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{us_per_call:.1f},{derived}"


def emit(rows):
    for r in rows:
        print(r, flush=True)


def load_dryrun_results(mesh: str = "pod", tag: str = "baseline"):
    out = {}
    for f in glob.glob(os.path.join(RESULTS_DIR, f"*_{mesh}_{tag}.json")):
        d = json.load(open(f))
        out[(d["arch"], d["shape"])] = d
    return out
